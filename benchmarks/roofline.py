"""Roofline analysis from the dry-run's compiled artifacts.

For every (arch x shape x mesh) JSON produced by repro.launch.dryrun:

  compute term    = HLO_flops_total / (chips * 197 TF/s bf16)
  memory term     = HLO_bytes_total / (chips * 819 GB/s)
  collective term = collective_bytes_total / (chips * 50 GB/s)

cost_analysis() reports *per-device* numbers on a partitioned module, so
totals are per-device * chips; the ratios below therefore reduce to
per-device quantities over per-chip peak rates.  MODEL_FLOPS uses
6*N*D for training (2*N_active*D per decoded token for decode) and the
useful ratio MODEL_FLOPS / HLO_FLOPS exposes remat/padding/dispatch
waste.  The dominant term is the bottleneck the perf loop iterates on.

Usage: python -m benchmarks.roofline [--artifacts artifacts] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyze(rec: dict) -> dict:
    """Three terms per the spec formulas.

    flops: analytic accounting (XLA cost_analysis counts while bodies
    once — verified; see repro.launch.accounting).  bytes: analytic HBM
    traffic model.  collectives: HLO text with while-trip correction
    (repro.launch.hlo), a per-device quantity.
    """
    chips = rec["chips"]
    fl_dev = rec["analytic_flops_total"] / chips
    by_dev = rec["analytic_bytes_per_device"]
    co_dev = rec.get("collective_bytes_corrected",
                     rec["collective_bytes_per_device"])["total"]
    t_c = fl_dev / PEAK_FLOPS
    t_m = by_dev / HBM_BW
    t_x = co_dev / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = rec["model_flops"]
    useful = mf / rec["analytic_flops_total"] if rec["analytic_flops_total"] else 0.0
    # roofline fraction: ideal time for useful work / dominant-term time
    t_star = max(t_c, t_m, t_x)
    frac = (mf / (chips * PEAK_FLOPS)) / t_star if t_star else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful, "roofline_frac": frac,
        "temp_gb": rec["memory"]["temp_gb"],
    }


def load(artifacts: str, mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifacts, "*.json"))):
        if path.endswith("summary.json"):
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        rows.append(analyze(rec))
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--artifacts", default="artifacts")
    p.add_argument("--mesh", default="single")
    p.add_argument("--csv", action="store_true")
    args = p.parse_args(argv)
    rows = load(args.artifacts, args.mesh)
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_frac,temp_gb")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
                  f"{r['collective_s']:.3e},{r['dominant']},"
                  f"{r['useful_ratio']:.3f},{r['roofline_frac']:.3f},"
                  f"{r['temp_gb']:.2f}")
        return 0
    hdr = (f"{'arch':26s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dom':>10s} {'useful':>7s} {'roofL':>6s} "
           f"{'temp':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['roofline_frac']:6.3f} {r['temp_gb']:6.1f}G")
    return 0


if __name__ == "__main__":
    sys.exit(main())
