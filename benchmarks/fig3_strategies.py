"""Paper Fig. 3: strategy ladder from reading Edgelist to building CSR.

  edgelist            read per-block Edgelists only
  degree-global       + degrees into one shared accumulator
  degree-partition4   + degrees into rho=4 partition accumulators
  csr-global          + single-stage CSR (one global sort)
  csr-partition4      + staged CSR (GVEL: 4 local sorts + disjoint merge)
"""
import jax.numpy as jnp

from .common import dataset, emit, timeit


def run():
    from repro.core import degrees, build, read_edgelist_numpy
    path, v, e = dataset("web_rmat")
    el = read_edgelist_numpy(path, num_vertices=v)
    n = int(el.num_edges)
    src = jnp.asarray(el.src[:n])
    dst = jnp.asarray(el.dst[:n])

    t_read = timeit(lambda: read_edgelist_numpy(path, num_vertices=v))
    emit("fig3.edgelist", t_read, "rel=1.00x")

    def deg_global():
        degrees.degrees_global(src, v).block_until_ready()

    def deg_part():
        degrees.combine_degrees(
            degrees.degrees_partitioned(src, v, 4)).block_until_ready()

    def csr_global():
        o, t, _ = build.csr_global(src, dst, None, v)
        t.block_until_ready()

    def csr_staged():
        o, t, _ = build.csr_staged(src, dst, None, v, rho=4)
        t.block_until_ready()

    for name, extra in [("degree-global", deg_global),
                        ("degree-partition4", deg_part),
                        ("csr-global", csr_global),
                        ("csr-partition4", csr_staged)]:
        t_extra = timeit(extra)
        total = t_read + t_extra
        emit(f"fig3.{name}", total,
             f"rel={total / t_read:.2f}x;stage_only_us={t_extra * 1e6:.1f}")


if __name__ == "__main__":
    run()
