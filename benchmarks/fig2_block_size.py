"""Paper Fig. 2: block-size (beta) sweep for the block-parallel reader."""
from .common import dataset, emit, timeit


def run():
    from repro.core import load_edgelist
    path, v, e = dataset("web_rmat")
    for beta in [4 << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20]:
        t = timeit(lambda b=beta: load_edgelist(path, engine="device",
                                                num_vertices=v, beta=b),
                   repeat=2)
        emit(f"fig2.beta_{beta // 1024}k", t, f"edges_per_s={e / t:.3e}")


if __name__ == "__main__":
    run()
