"""End-to-end ``load_csr``: streaming fused device engine vs the old
batch round-trip pipeline vs binary snapshots, same input.

The baseline below reproduces the pre-loader device path verbatim:
synchronous block staging, jitted parse, per-batch compaction
(``_compact_edges``, the historical ``parse.compact_edges`` kept here
as part of the frozen baseline), a device->host copy of every batch,
``np.concatenate``, a host EdgeList, and only then a device CSR build —
all at the historical fixed geometry (beta=256 KiB, batch_blocks=8,
padded tail batch).  The streaming path
(``loader.load_csr(engine="device")``) double-buffers arena staging
behind one fused parse+accumulate program per batch (donated in-place
accumulators, remainder-sized tail batch) that feeds the CSR build
directly; the ``_tuned`` row additionally lets ``core.tune``'s measured
per-host profile pick the block geometry (full runs only — the first
run on a host pays the sweep, later runs hit its cache).

The snapshot rows measure GVEL's "write once, load many" story: the
same graph converted once to a ``.gvel`` binary snapshot
(``core.snapshot``), then loaded with zero parsing — either packed
edgelist sections feeding the device CSR build (``snapshot_el``), or an
embedded prebuilt CSR served straight from mmap (``snapshot_csr``).

The compressed rows measure the trade the codec layer (``core.codecs``)
buys: bytes on disk vs load time, with decompression overlapped with
the parse in the prefetch thread (gzip / framed-zlib text in the
streaming engine, zlib-framed ``.gvel`` v2 sections in the snapshot
engine).  Each row's ``mb=`` field is its input's size on disk, so the
ratio/throughput trade-off is measured, not asserted.

The lazy rows measure what the ``GraphSource`` front door buys on a
*both-sections* compressed snapshot: the old eager reader
(``read_snapshot(path)``) decompresses and checksums the edgelist AND
CSR sections at open, while ``open_graph(path).csr()`` decodes only
the CSR sections (per-section lazy decompression, this PR's ROADMAP
item).

The build row (``e2e.csr_build_binned``) isolates the CSR build on the
loader-shaped packed device arrays (parse excluded): the sort-free
binned build (``build.csr_binned``, propagation-blocking-style
cumulative-count ranks) vs the rank-based staged build it replaces as
the fast path.  Its ``speedup`` field is staged/binned — not the
baseline axis — so the verify.sh floor pins "binned never slower than
staged" directly.

The sharded rows measure the byte-range-sharded streaming load
(``core.distributed.load_csr_sharded_stream`` /
``GraphSource.csr_sharded``) at d=2 and d=4, in one subprocess forced
to 4 CPU host devices.  XLA splits the host threadpool across forced
devices, so the subprocess re-times single-device streaming and the
d=1 sharded pipeline under the same split, and each sharded row's
``speedup`` field is its gain over the frozen batch-roundtrip
baseline *like every other row*, chained through that same-split
streaming time (``t_old/t_streaming x t_streaming_same_split/t_dN``)
so the cross-process normalization is measured, not assumed.  The
derived fields carry the raw same-split diagnostics
(``vs_stream_same_cfg``, ``vs_sharded_d1``, ``cores``): on a
single-core container forced host devices execute serially and d>1
does strictly more total work than d=1 (the exchange is extra), so
those ratios sit below 1.0 by construction — real scaling needs real
cores, the same caveat ``benchmarks/fig9_scaling.py`` documents for
its worker sweep.  The gate in scripts/verify.sh
(``e2e.load_csr_sharded_d4 >= 1.0``) pins the sharded path to the
baseline axis, which catches genuine work regressions: a
retrace-per-load bug in the exchange showed up at ~0.14x on this
metric before being fixed.

``--quick`` (used by scripts/verify.sh) runs the same pipeline on a
small graph with repeat=1 so the benchmark code itself cannot rot
unexecuted.  ``--json OUT.json`` additionally writes machine-readable
rows ``{name, seconds, mb, speedup}`` — ``mb`` is the input's size on
disk and ``speedup`` is this row's gain over the batch-roundtrip
baseline row (baseline = 1.0) — so the perf trajectory is diffable
across PRs.
"""
import gzip
import json
import os
import sys

import numpy as np

from .common import dataset, emit, timeit


def _compact_edges(src_b, dst_b, w_b, counts, total_cap):
    """The historical ``parse.compact_edges`` (deleted from the library
    when the fused ``parse_accumulate`` replaced it), preserved verbatim
    so the baseline row keeps measuring the pre-loader pipeline."""
    import jax.numpy as jnp
    nb, cap = src_b.shape
    starts = jnp.cumsum(counts) - counts
    within = jnp.arange(cap, dtype=jnp.int32)[None, :]
    valid = within < counts[:, None]
    dest = jnp.where(valid, starts[:, None] + within, total_cap)
    dest = dest.reshape(-1)
    out_src = jnp.full((total_cap,), -1, jnp.int32).at[dest].set(
        src_b.reshape(-1), mode="drop")
    out_dst = jnp.full((total_cap,), -1, jnp.int32).at[dest].set(
        dst_b.reshape(-1), mode="drop")
    out_w = None
    if w_b is not None:
        out_w = jnp.zeros((total_cap,), jnp.float32).at[dest].set(
            w_b.reshape(-1), mode="drop")
    return out_src, out_dst, out_w, jnp.sum(counts)


def _batch_roundtrip_csr(path, v, *, beta=256 * 1024, overlap=64,
                         batch_blocks=8):
    """The old pipeline: per-batch host round-trip + EdgeList detour."""
    import jax.numpy as jnp
    from repro.core.blocks import owned_range, plan_blocks, stage_blocks
    from repro.core.csr import convert_to_csr
    from repro.core.parse import parse_blocks
    from repro.core.types import EdgeList

    data = np.memmap(path, dtype=np.uint8, mode="r")
    plan = plan_blocks(len(data), beta=beta, overlap=overlap)
    os_, oe = owned_range(plan)
    edge_cap = plan.edge_cap
    total_cap = batch_blocks * edge_cap
    chunks_src, chunks_dst = [], []
    total = 0
    for start in range(0, plan.num_blocks, batch_blocks):
        ids = np.arange(start, min(start + batch_blocks, plan.num_blocks))
        bufs = stage_blocks(data, plan, ids)
        if len(ids) < batch_blocks:
            pad = np.full((batch_blocks - len(ids), plan.buf_len), 10, np.uint8)
            bufs = np.concatenate([bufs, pad])
        ostart = jnp.full((batch_blocks,), os_, jnp.int32)
        oend = jnp.full((batch_blocks,), oe, jnp.int32)
        src_b, dst_b, w_b, counts = parse_blocks(
            jnp.asarray(bufs), ostart, oend,
            weighted=False, base=1, edge_cap=edge_cap)
        src, dst, w, n = _compact_edges(src_b, dst_b, w_b, counts, total_cap)
        n = int(n)
        chunks_src.append(np.asarray(src[:n]))     # device -> host, every batch
        chunks_dst.append(np.asarray(dst[:n]))
        total += n
    el = EdgeList(np.concatenate(chunks_src), np.concatenate(chunks_dst),
                  None, np.int64(total), v)
    return convert_to_csr(el, method="staged", rho=4)


def _snapshots(path, v):
    """Convert the benchmark graph to .gvel once (cached beside it):
    an edgelist-only snapshot and a CSR-embedded one."""
    from repro.core import convert_to_csr, load_edgelist, save_snapshot

    el_snap, csr_snap = path + ".el.gvel", path + ".csr.gvel"
    if not (os.path.exists(el_snap) and os.path.exists(csr_snap)):
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        save_snapshot(el_snap, edgelist=el)
        save_snapshot(csr_snap, edgelist=el,
                      csr=convert_to_csr(el, method="staged", rho=4))
    return el_snap, csr_snap


def _compressed(path, v):
    """Compressed variants of the benchmark inputs (cached beside them):
    gzip text, framed-zlib text, and a zlib-compressed CSR snapshot."""
    from repro.core import (compress_file_framed, convert_to_csr,
                            load_edgelist, save_snapshot)

    gz, fz, zsnap = path + ".gz", path + ".elz", path + ".z.gvel"
    if not os.path.exists(gz):
        with open(path, "rb") as fin, open(gz, "wb") as fout:
            fout.write(gzip.compress(fin.read(), 6))
    if not os.path.exists(fz):
        compress_file_framed(path, fz, codec="zlib")
    if not os.path.exists(zsnap):
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        save_snapshot(zsnap, edgelist=el,
                      csr=convert_to_csr(el, method="staged", rho=4),
                      compress="zlib")
    return gz, fz, zsnap


def _mb(path):
    return f"mb={os.path.getsize(path) / 1e6:.2f}"


def _build_times(path, v, repeat):
    """(staged, binned) build-only seconds on the same packed device
    arrays the streaming loader hands the build — loader-shaped input
    (pow-2 capacity, ``-1`` padding), parse excluded, so the row
    isolates the CSR build the binned method replaces."""
    import jax
    import jax.numpy as jnp
    from repro.core import load_edgelist
    from repro.core.build import csr_binned, csr_staged

    el = load_edgelist(path, engine="numpy", num_vertices=v)
    n = int(el.num_edges)
    cap = 1 << max(n - 1, 1).bit_length()
    src = np.full(cap, -1, np.int32)
    dst = np.full(cap, -1, np.int32)
    src[:n] = np.asarray(el.src[:n])
    dst[:n] = np.asarray(el.dst[:n])
    bsrc, bdst = jnp.asarray(src), jnp.asarray(dst)
    t_staged = timeit(lambda: jax.block_until_ready(
        csr_staged(bsrc, bdst, None, v, rho=4)), repeat=repeat)
    t_binned = timeit(lambda: jax.block_until_ready(
        csr_binned(bsrc, bdst, None, v)), repeat=repeat)
    return t_staged, t_binned


_SHARDED_CODE = """
import json, sys, time
import numpy as np, jax
from repro.core import open_graph
from repro.core.compat import device_mesh
from repro.core.distributed import load_csr_sharded_stream

path, v, repeat = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])

def best_of(fn, repeat):
    fn()                                  # compile warmup
    b = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter(); fn(); b = min(b, time.perf_counter() - t0)
    return b

out = {"stream": best_of(
    lambda: open_graph(path, engine="device", num_vertices=v).csr(), repeat)}
for d in (1, 2, 4):
    mesh = device_mesh(np.array(jax.devices()[:d]), ("data",))
    out[f"d{d}"] = best_of(
        lambda: load_csr_sharded_stream(mesh, "data", path, num_vertices=v),
        repeat)
print("SHARDED_JSON " + json.dumps(out))
"""


def _sharded_times(path, v, repeat):
    """(stream, d1, d2, d4) seconds, all measured in one subprocess under
    ``--xla_force_host_platform_device_count=4`` so the threadpool split
    is identical across the four timings."""
    import subprocess

    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_CODE, path, str(v), str(repeat)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded benchmark subprocess failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("SHARDED_JSON ")][-1]
    t = json.loads(line[len("SHARDED_JSON "):])
    return t["stream"], t["d1"], t["d2"], t["d4"]


def run(quick: bool = False, json_path: str = None):
    from repro.core import get_engine, open_graph, read_snapshot

    path, v, e = dataset("quick_rmat" if quick else "web_rmat")
    repeat = 1 if quick else 3
    el_snap, csr_snap = _snapshots(path, v)
    gz, fz, zsnap = _compressed(path, v)
    snap_eng = get_engine("snapshot")

    def cold(p, **kw):
        # measure a fresh open (validation + any decompression), not a
        # hit on the engine's stat-validated in-process memo; every row
        # goes through the GraphSource front door
        snap_eng.clear_memo()
        return open_graph(p, engine="snapshot", num_vertices=v).csr(**kw)

    def stream_csr(p, **kw):
        return open_graph(p, engine="device",
                          num_vertices=v, **kw).csr(method="staged")

    def eager_zsnap_csr():
        # the pre-GraphSource contract: read_snapshot() decompresses and
        # checksums EVERY section at open, edgelist included
        snap_eng.clear_memo()
        return read_snapshot(zsnap).csr()

    t_old = timeit(lambda: _batch_roundtrip_csr(path, v), repeat=repeat)
    t_new = timeit(lambda: stream_csr(path), repeat=repeat)
    # measured per-host geometry (core.tune); quick mode skips it so
    # verify.sh never pays a tuning sweep
    t_tuned = None if quick else timeit(
        lambda: stream_csr(path, tune=True), repeat=repeat)
    t_sel = timeit(lambda: cold(el_snap, method="staged"), repeat=repeat)
    t_scsr = timeit(lambda: cold(csr_snap), repeat=repeat)
    t_gz = timeit(lambda: stream_csr(gz), repeat=repeat)
    t_fz = timeit(lambda: stream_csr(fz), repeat=repeat)
    t_zeager = timeit(eager_zsnap_csr, repeat=repeat)
    t_zlazy = timeit(lambda: cold(zsnap), repeat=repeat)

    rows = []

    def row(name, seconds, in_path, derived=""):
        emit(name, seconds, derived + (";" if derived else "") + _mb(in_path))
        rows.append({"name": name, "seconds": round(seconds, 6),
                     "mb": round(os.path.getsize(in_path) / 1e6, 3),
                     "speedup": round(t_old / seconds, 2)})

    row("e2e.load_csr_batch_roundtrip", t_old, path,
        f"edges_per_s={e / t_old:.3e}")
    row("e2e.load_csr_streaming", t_new, path,
        f"edges_per_s={e / t_new:.3e};speedup={t_old / t_new:.2f}x")
    if t_tuned is not None:
        row("e2e.load_csr_streaming_tuned", t_tuned, path,
            f"edges_per_s={e / t_tuned:.3e};vs_default={t_new / t_tuned:.2f}x")
    row("e2e.load_csr_snapshot_el", t_sel, el_snap,
        f"edges_per_s={e / t_sel:.3e};vs_streaming={t_new / t_sel:.2f}x")
    row("e2e.load_csr_snapshot_csr", t_scsr, csr_snap,
        f"edges_per_s={e / t_scsr:.3e};vs_streaming={t_new / t_scsr:.2f}x")
    row("e2e.load_csr_text_gz", t_gz, gz,
        f"edges_per_s={e / t_gz:.3e};vs_raw_text={t_new / t_gz:.2f}x")
    row("e2e.load_csr_text_framed_zlib", t_fz, fz,
        f"edges_per_s={e / t_fz:.3e};vs_raw_text={t_new / t_fz:.2f}x")
    # both-sections compressed snapshot, cold .csr(): eager decodes the
    # edgelist frames it never serves, lazy decodes CSR sections only
    row("e2e.load_csr_snapshot_zlib_eager", t_zeager, zsnap,
        f"edges_per_s={e / t_zeager:.3e}")
    row("e2e.load_csr_snapshot_zlib_lazy", t_zlazy, zsnap,
        f"edges_per_s={e / t_zlazy:.3e};vs_eager={t_zeager / t_zlazy:.2f}x")
    # build-only row: binned vs staged on the loader-shaped packed
    # arrays.  Unlike the load rows, speedup here is staged/binned — the
    # verify.sh floor (>= 1.0) pins the binned build to never regress
    # behind the staged build it's meant to beat.
    t_staged_b, t_binned_b = _build_times(path, v, repeat)
    emit("e2e.csr_build_binned", t_binned_b,
         f"edges_per_s={e / t_binned_b:.3e};"
         f"vs_staged={t_staged_b / t_binned_b:.2f}x;" + _mb(path))
    rows.append({"name": "e2e.csr_build_binned",
                 "seconds": round(t_binned_b, 6),
                 "mb": round(os.path.getsize(path) / 1e6, 3),
                 "speedup": round(t_staged_b / t_binned_b, 2)})
    # sharded rows: speedup is vs the batch-roundtrip baseline like every
    # other row, chained through the same-split streaming re-timing so
    # the subprocess threadpool split is normalized out (module docstring)
    t_s1, t_sd1, t_d2, t_d4 = _sharded_times(path, v, repeat)
    for name, secs in (("e2e.load_csr_sharded_d2", t_d2),
                       ("e2e.load_csr_sharded_d4", t_d4)):
        emit(name, secs,
             f"edges_per_s={e / secs:.3e};"
             f"vs_stream_same_cfg={t_s1 / secs:.2f}x;"
             f"vs_sharded_d1={t_sd1 / secs:.2f}x;"
             f"cores={os.cpu_count()};" + _mb(path))
        rows.append({"name": name, "seconds": round(secs, 6),
                     "mb": round(os.path.getsize(path) / 1e6, 3),
                     "speedup": round((t_old / t_new) * (t_s1 / secs), 2)})
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.e2e_load_csr "
                     "[--quick] [--json OUT.json]")
        out = argv[i + 1]
    run(quick="--quick" in argv, json_path=out)
