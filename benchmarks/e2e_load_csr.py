"""End-to-end ``load_csr``: streaming fused device engine vs the old
batch round-trip pipeline vs binary snapshots, same input.

The baseline below reproduces the pre-loader device path verbatim:
synchronous block staging, jitted parse, a device->host copy of every
batch, ``np.concatenate``, a host EdgeList, and only then a device CSR
build.  The streaming path (``loader.load_csr(engine="device")``)
double-buffers staging behind the parse dispatch and accumulates every
batch in a packed device buffer that feeds the CSR build directly.

The snapshot rows measure GVEL's "write once, load many" story: the
same graph converted once to a ``.gvel`` binary snapshot
(``core.snapshot``), then loaded with zero parsing — either packed
edgelist sections feeding the device CSR build (``snapshot_el``), or an
embedded prebuilt CSR served straight from mmap (``snapshot_csr``).
"""
import os

import numpy as np

from .common import dataset, emit, timeit


def _batch_roundtrip_csr(path, v, *, beta=256 * 1024, overlap=64,
                         batch_blocks=8):
    """The old pipeline: per-batch host round-trip + EdgeList detour."""
    import jax.numpy as jnp
    from repro.core.blocks import owned_range, plan_blocks, stage_blocks
    from repro.core.csr import convert_to_csr
    from repro.core.parse import compact_edges, parse_blocks
    from repro.core.types import EdgeList

    data = np.memmap(path, dtype=np.uint8, mode="r")
    plan = plan_blocks(len(data), beta=beta, overlap=overlap)
    os_, oe = owned_range(plan)
    edge_cap = plan.edge_cap
    total_cap = batch_blocks * edge_cap
    chunks_src, chunks_dst = [], []
    total = 0
    for start in range(0, plan.num_blocks, batch_blocks):
        ids = np.arange(start, min(start + batch_blocks, plan.num_blocks))
        bufs = stage_blocks(data, plan, ids)
        if len(ids) < batch_blocks:
            pad = np.full((batch_blocks - len(ids), plan.buf_len), 10, np.uint8)
            bufs = np.concatenate([bufs, pad])
        ostart = jnp.full((batch_blocks,), os_, jnp.int32)
        oend = jnp.full((batch_blocks,), oe, jnp.int32)
        src_b, dst_b, w_b, counts = parse_blocks(
            jnp.asarray(bufs), ostart, oend,
            weighted=False, base=1, edge_cap=edge_cap)
        src, dst, w, n = compact_edges(src_b, dst_b, w_b, counts, total_cap)
        n = int(n)
        chunks_src.append(np.asarray(src[:n]))     # device -> host, every batch
        chunks_dst.append(np.asarray(dst[:n]))
        total += n
    el = EdgeList(np.concatenate(chunks_src), np.concatenate(chunks_dst),
                  None, np.int64(total), v)
    return convert_to_csr(el, method="staged", rho=4)


def _snapshots(path, v):
    """Convert the benchmark graph to .gvel once (cached beside it):
    an edgelist-only snapshot and a CSR-embedded one."""
    from repro.core import convert_to_csr, load_edgelist, save_snapshot

    el_snap, csr_snap = path + ".el.gvel", path + ".csr.gvel"
    if not (os.path.exists(el_snap) and os.path.exists(csr_snap)):
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        save_snapshot(el_snap, edgelist=el)
        save_snapshot(csr_snap, edgelist=el,
                      csr=convert_to_csr(el, method="staged", rho=4))
    return el_snap, csr_snap


def run():
    from repro.core import load_csr

    path, v, e = dataset("web_rmat")
    el_snap, csr_snap = _snapshots(path, v)
    t_old = timeit(lambda: _batch_roundtrip_csr(path, v), repeat=3)
    t_new = timeit(lambda: load_csr(path, engine="device", num_vertices=v,
                                    method="staged"), repeat=3)
    t_sel = timeit(lambda: load_csr(el_snap, engine="snapshot",
                                    num_vertices=v, method="staged"), repeat=3)
    t_scsr = timeit(lambda: load_csr(csr_snap, engine="snapshot",
                                     num_vertices=v), repeat=3)
    emit("e2e.load_csr_batch_roundtrip", t_old, f"edges_per_s={e / t_old:.3e}")
    emit("e2e.load_csr_streaming", t_new,
         f"edges_per_s={e / t_new:.3e};speedup={t_old / t_new:.2f}x")
    emit("e2e.load_csr_snapshot_el", t_sel,
         f"edges_per_s={e / t_sel:.3e};vs_streaming={t_new / t_sel:.2f}x")
    emit("e2e.load_csr_snapshot_csr", t_scsr,
         f"edges_per_s={e / t_scsr:.3e};vs_streaming={t_new / t_scsr:.2f}x")


if __name__ == "__main__":
    run()
