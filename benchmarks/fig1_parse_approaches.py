"""Paper Fig. 1: Edgelist-reading approach ladder.

CPU/TPU mapping of the paper's ladder:
  fstream-plain  -> naive python line loop (stream extraction)
  fopen-*        -> np.loadtxt (library C parser, line-at-a-time)
  (PIGO two-pass)-> read_edgelist_pigo (equal split + count pass + parse)
  mmap-custom    -> GVEL single-pass vectorized numpy engine
  mmap-custom    -> GVEL jitted block engine (device pipeline)
"""
from .common import dataset, emit, timeit


def run():
    from repro.core import baselines, load_edgelist
    path, v, e = dataset("web_rmat")

    cases = {
        "fig1.naive_stream": lambda: baselines.read_edgelist_naive(
            path, num_vertices=v),
        "fig1.loadtxt": lambda: baselines.read_edgelist_loadtxt(
            path, num_vertices=v),
        "fig1.pigo_twopass": lambda: baselines.read_edgelist_pigo(
            path, num_vertices=v),
        "fig1.gvel_numpy": lambda: load_edgelist(
            path, engine="numpy", num_vertices=v),
        "fig1.gvel_jax": lambda: load_edgelist(
            path, engine="device", num_vertices=v, beta=256 * 1024),
    }
    base = None
    for name, fn in cases.items():
        repeat = 1 if "naive" in name or "loadtxt" in name else 3
        t = timeit(fn, repeat=repeat, warmup=0 if repeat == 1 else 1)
        if base is None:
            base = t
        emit(name, t, f"edges_per_s={e / t:.3e};rel_to_naive={base / t:.2f}x")


if __name__ == "__main__":
    run()
