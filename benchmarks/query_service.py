"""Graph query service: thousands of mixed point/range/full requests
against a snapshot corpus, served through the hot-graph cache.

This is the "millions of users" serving scenario (ParaGrapher's
selective-loading motivation) made measurable: production traffic
against a loaded graph is mostly *point reads* — the neighbors of one
vertex, a row range for one worker — not full CSR loads.  The service
path this drives (``repro.core.cache.query``) answers those through

  * a bounded LRU of open ``GraphSource`` handles (open/validate once,
    stat-revalidate per hit), and
  * selective section reads: ``neighbors(v)`` / ``csr(rows=)`` slice
    the mmap'd CSR sections of raw snapshots without touching the rest
    of the file, and decode only the overlapping frames of compressed
    ones (``docs/query.md``).

The workload is a deterministic mixed stream over a corpus of raw and
zlib-compressed both-sections ``.gvel`` snapshots: ~70% point lookups
(``neighbors``/``degree``), ~25% row ranges, and a sprinkle of ``info``
and full-CSR requests.  The baseline (``e2e.query_naive``) is the same
request stream answered the only way the pre-query API allowed — open
the file, materialize the FULL CSR, slice it — timed per-request on a
sample and scaled (running thousands of cold full loads would take
minutes for a number that's constant per request).  ``speedup`` on the
``e2e.query_mixed`` row is naive-per-request / served-per-request; the
verify.sh gate pins it ≥ 1.0 — if serving a point read ever costs more
than a full load, the selective path has rotted.

``--quick`` (used by scripts/verify.sh) runs the same pipeline on a
small corpus so the service code cannot rot unexecuted.  ``--json
OUT.json`` writes machine-readable rows ``{name, seconds, mb,
speedup}`` — ``seconds`` is the whole request stream, ``mb`` the
corpus size on disk — so the perf trajectory is diffable across PRs.
"""
import json
import os
import shutil
import sys

import numpy as np

from .common import dataset, emit, timeit


def _corpus(quick):
    """Raw + zlib both-sections snapshots of the benchmark graph
    (cached beside it); copies give the cache distinct paths."""
    from repro.core import convert_to_csr, load_edgelist, save_snapshot

    path, v, e = dataset("quick_rmat" if quick else "web_rmat")
    raw0, z0 = path + ".qraw.gvel", path + ".qz.gvel"
    if not (os.path.exists(raw0) and os.path.exists(z0)):
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        csr = convert_to_csr(el, method="staged", rho=4)
        save_snapshot(raw0, edgelist=el, csr=csr)
        save_snapshot(z0, edgelist=el, csr=csr, compress="zlib")
    paths = [raw0, z0]
    for i in range(1 if quick else 2):         # distinct paths, same graph
        for src in (raw0, z0):
            dup = f"{src}.{i}"
            if not os.path.exists(dup):
                shutil.copyfile(src, dup)
            paths.append(dup)
    return paths, v, e


def _requests(paths, v, n, seed=7):
    """Deterministic mixed stream: ~60% neighbors, ~10% degree,
    ~25% row ranges, ~4% info, ~1% full CSR."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(
        ["neighbors", "degree", "rows", "info", "csr"], size=n,
        p=[0.60, 0.10, 0.25, 0.04, 0.01])
    which = rng.integers(0, len(paths), size=n)
    verts = rng.integers(0, v, size=n)
    spans = rng.integers(1, max(2, v // 64), size=n)
    reqs = []
    for k, w, u, s in zip(kinds, which, verts, spans):
        if k in ("neighbors", "degree"):
            reqs.append((paths[w], k, int(u), 0))
        elif k == "rows":
            lo = int(u)
            reqs.append((paths[w], k, lo, min(v, lo + int(s))))
        else:
            reqs.append((paths[w], k, 0, 0))
    return reqs


def _serve(cache, reqs):
    for path, op, a, b in reqs:
        if op in ("neighbors", "degree"):
            cache.query(path, op, vertex=a)
        elif op == "rows":
            cache.query(path, "rows", rows=(a, b))
        else:
            cache.query(path, op)


def _naive_per_request(reqs, v, sample):
    """Per-request seconds for the pre-query answer: open, build the
    FULL CSR, slice.  Cold per request — no handle reuse, no partial
    reads — timed on a sample of the same stream."""
    from repro.core import get_engine, open_graph

    eng = get_engine("snapshot")

    def one(path, op, a, b):
        eng.clear_memo()
        csr = open_graph(path, engine="snapshot", num_vertices=v).csr()
        if op == "neighbors":
            csr.targets[csr.offsets[a]:csr.offsets[a + 1]]
        elif op == "degree":
            int(csr.offsets[a + 1]) - int(csr.offsets[a])
        elif op == "rows":
            csr.targets[csr.offsets[a]:csr.offsets[b]]

    picks = reqs[:: max(1, len(reqs) // sample)][:sample]
    total = timeit(lambda: [one(*r) for r in picks], repeat=1, warmup=1)
    return total / len(picks)


def run(quick: bool = False, json_path: str = None):
    from repro.core.cache import SourceCache

    paths, v, e = _corpus(quick)
    n = 2000 if quick else 10000
    reqs = _requests(paths, v, n)
    n_point = sum(1 for r in reqs if r[1] in ("neighbors", "degree"))
    n_range = sum(1 for r in reqs if r[1] == "rows")

    cache = SourceCache(capacity=len(paths))
    t_mixed = timeit(lambda: _serve(cache, reqs), repeat=1 if quick else 3)
    per_req = t_mixed / n
    st = cache.stats()

    # hot point reads only, zlib snapshot: the pure selective-decode path
    zp = [p for p in paths if ".qz." in p][0]
    pts = [(zp, "neighbors", int(u), 0)
           for u in np.random.default_rng(11).integers(0, v, 1000)]
    t_pts = timeit(lambda: _serve(cache, pts), repeat=1 if quick else 3)

    naive = _naive_per_request(reqs, v, sample=5 if quick else 10)

    corpus_mb = sum(os.path.getsize(p) for p in paths) / 1e6
    rows = []

    def row(name, seconds, speedup, derived=""):
        emit(name, seconds,
             derived + (";" if derived else "") + f"mb={corpus_mb:.2f}")
        rows.append({"name": name, "seconds": round(seconds, 6),
                     "mb": round(corpus_mb, 3), "speedup": round(speedup, 2)})

    row("e2e.query_naive", naive * n, 1.0,
        f"per_req={naive * 1e6:.0f}us;scaled_from_sample")
    row("e2e.query_mixed", t_mixed, naive / per_req,
        f"n={n};point={n_point};range={n_range};per_req={per_req * 1e6:.1f}us;"
        f"req_per_s={n / t_mixed:.3e};hits={st['hits']};misses={st['misses']};"
        f"vs_naive={naive / per_req:.1f}x")
    row("e2e.query_neighbors_zlib_hot", t_pts, naive / (t_pts / len(pts)),
        f"n={len(pts)};per_req={t_pts / len(pts) * 1e6:.1f}us;"
        f"req_per_s={len(pts) / t_pts:.3e}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.query_service "
                     "[--quick] [--json OUT.json]")
        out = argv[i + 1]
    run(quick="--quick" in argv, json_path=out)
