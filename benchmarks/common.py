"""Shared benchmark utilities: datasets, timing, CSV output."""
from __future__ import annotations

import functools
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import make_graph_file  # noqa: E402

_CACHE = os.environ.get("REPRO_BENCH_CACHE",
                        os.path.join(tempfile.gettempdir(), "repro_bench"))

# Stand-ins for the paper's Table 1 graph classes, scaled to this host.
# (SuiteSparse is unavailable offline; shapes match the classes' character:
#  web = power-law high degree, social = uniform-ish denser, road = grid.)
DATASETS = {
    "web_rmat": dict(kind="rmat", scale=15, edge_factor=16),      # ~524k edges
    "social_uniform": dict(kind="uniform", scale=15, edge_factor=8),
    "road_grid": dict(kind="grid", scale=16, edge_factor=0),
    # small twin of web_rmat for --quick smoke runs (verify.sh)
    "quick_rmat": dict(kind="rmat", scale=12, edge_factor=8),     # ~32k edges
}


@functools.lru_cache(maxsize=None)
def dataset(name: str, weighted: bool = False):
    os.makedirs(_CACHE, exist_ok=True)
    spec = DATASETS[name]
    path = os.path.join(
        _CACHE, f"{name}{'_w' if weighted else ''}.el")
    meta = path + ".meta"
    if not (os.path.exists(path) and os.path.exists(meta)):
        v, e = make_graph_file(path, spec["kind"], scale=spec["scale"],
                               edge_factor=spec["edge_factor"],
                               weighted=weighted, seed=42)
        with open(meta, "w") as f:
            f.write(f"{v} {e}")
    v, e = (int(x) for x in open(meta).read().split())
    return path, v, e


def timeit(fn, *, repeat: int = 3, warmup: int = 1) -> float:
    """Median seconds over `repeat` runs (paper averages 5; we use
    median-of-3 to bound suite runtime)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
