"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline analysis
(benchmarks/roofline.py) is separate because it consumes dry-run
artifacts rather than wall-clock timings.

  PYTHONPATH=src python -m benchmarks.run [fig1 fig2 ...]
"""
from __future__ import annotations

import sys

from . import (e2e_load_csr, fig1_parse_approaches, fig2_block_size,
               fig3_strategies, fig4_partitions, fig5_csr_frameworks,
               fig7_edgelist, fig8_breakdown, fig9_scaling)

SUITES = {
    "fig1": fig1_parse_approaches.run,
    "fig2": fig2_block_size.run,
    "fig3": fig3_strategies.run,
    "fig4": fig4_partitions.run,
    "fig5": fig5_csr_frameworks.run,
    "fig7": fig7_edgelist.run,
    "fig8": fig8_breakdown.run,
    "fig9": fig9_scaling.run,
    "e2e": e2e_load_csr.run,
}


def main() -> None:
    want = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for name in want:
        SUITES[name]()


if __name__ == "__main__":
    main()
