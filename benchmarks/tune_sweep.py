"""Block-geometry sweep for the streaming loader (GVEL Figure 2).

Times the fused parse+accumulate streaming step over a ``beta x
batch_blocks`` grid — the measurement behind ``core.tune``'s per-host
profile — and prints one CSV row per combo (fastest first).  By default
the sweep runs on the autotuner's synthetic sample so the numbers match
what ``open_graph(path, tune=True)`` would cache; ``--dataset`` sweeps
a generated benchmark graph instead, and ``--file`` any edgelist file.

    python -m benchmarks.tune_sweep --json sweep.json
    python -m benchmarks.tune_sweep --dataset web_rmat --weighted
    python -m benchmarks.tune_sweep --apply     # persist winner to the
                                                # per-host tune cache

``--json`` emits the machine-readable rows ``{beta, batch_blocks,
seconds, mb_per_s}`` (plus a ``best`` marker) for cross-host diffing.
"""
import argparse
import json
import sys

import numpy as np

from .common import dataset, emit


def main(argv=None) -> int:
    from repro.core import tune

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.tune_sweep",
        description="Sweep streaming block geometry (beta x batch_blocks)")
    ap.add_argument("--dataset", help="benchmarks.common dataset name "
                    "(e.g. web_rmat) instead of the synthetic sample")
    ap.add_argument("--file", help="sweep an existing edgelist file")
    ap.add_argument("--weighted", action="store_true")
    ap.add_argument("--sample-mb", type=float, default=4.0,
                    help="synthetic sample size (default 4 MB)")
    ap.add_argument("--betas", default=None,
                    help="comma-separated beta values in KiB "
                    "(default 64,256,1024)")
    ap.add_argument("--batch-blocks", default=None,
                    help="comma-separated batch_blocks values (default 2,4,8)")
    ap.add_argument("--repeat", type=int, default=2)
    ap.add_argument("--json", dest="json_out", metavar="OUT.json")
    ap.add_argument("--apply", action="store_true",
                    help="persist the winner to the per-host tune cache "
                    "(what tune=True loads)")
    args = ap.parse_args(argv)

    if args.dataset and args.file:
        ap.error("--dataset and --file are mutually exclusive")
    if args.dataset:
        path, _, _ = dataset(args.dataset, weighted=args.weighted)
        data = np.fromfile(path, np.uint8)
    elif args.file:
        data = np.fromfile(args.file, np.uint8)
    else:
        data = tune.synthetic_sample(int(args.sample_mb * 1e6),
                                     weighted=args.weighted)

    betas = tuple(int(b) * 1024 for b in args.betas.split(",")) \
        if args.betas else tune.DEFAULT_BETAS
    bbs = tuple(int(b) for b in args.batch_blocks.split(",")) \
        if args.batch_blocks else tune.DEFAULT_BATCH_BLOCKS

    rows = tune.run_sweep(data, betas=betas, batch_blocks=bbs,
                          weighted=args.weighted, repeat=args.repeat)
    best = tune.best_geometry(rows)
    for r in rows:
        r["best"] = (r["beta"] == best["beta"]
                     and r["batch_blocks"] == best["batch_blocks"])
        emit(f"tune.beta{r['beta'] // 1024}k_bb{r['batch_blocks']}",
             r["seconds"],
             f"mb_per_s={r['mb_per_s']}{';best' if r['best'] else ''}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
    if args.apply:
        tune.save_geometry(rows, weighted=args.weighted)
        print(f"applied: {best} -> {tune.cache_path()}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
