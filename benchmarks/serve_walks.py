"""Graph-walk serving benchmark: sustained walk-LM traffic against a
snapshot-backed corpus through the ServeRuntime, vs a naive
reload-per-request baseline, plus the corpus resume-vs-replay payoff.

The end-to-end scenario the loader exists for (ROADMAP open item 2):
requests name a graph; the runtime resolves it through the hot-graph
cache (open/validate once, mtime-revalidated per request), derives a
deterministic walk prompt from the CSR, and decodes with continuous
batching — slots shared across requests, freed slots refilled the same
tick.  The baseline answers the same request stream the pre-runtime
way: reopen the snapshot and materialize the full CSR **per request**,
then decode alone on a single-slot engine (no batching, no handle
reuse), timed on a sample and scaled.

Rows (``{name, seconds, mb, speedup}``; ``mb`` = snapshot size):

* ``e2e.serve_naive`` — the scaled reload-per-request baseline (1.0x).
* ``e2e.serve_walks_tokens`` — the served stream; ``speedup`` is
  naive-per-request / served-per-request.  verify.sh gates it >= 1.0:
  if serving a request through the runtime ever costs more than a
  cold reload + solo decode, the serving path has rotted.
* ``e2e.serve_resume`` — producing corpus batches [k, k+m) by resuming
  at the checkpointed cursor vs replaying a sequential stream from 0
  (what a non-step-indexed pipeline must do after a kill).

``--quick`` (used by scripts/verify.sh) runs the same pipeline on the
small corpus + reduced model so the serving code cannot rot
unexecuted; ``--json OUT.json`` writes the machine-readable rows.
The run also prints ``runtime.stats()`` — requests/tokens/s, batch
occupancy, cache + frame-cache hits — the subsystem's observability
surface (docs/serving.md).
"""
import json
import os
import sys

import numpy as np

from .common import dataset, emit, timeit


def _snapshot(quick):
    from repro.core import convert_to_csr, load_edgelist, save_snapshot

    path, v, e = dataset("quick_rmat" if quick else "web_rmat")
    gv = path + ".serve.gvel"
    if not os.path.exists(gv):
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        csr = convert_to_csr(el, method="staged", rho=4)
        save_snapshot(gv, edgelist=el, csr=csr)
    return gv, v, e


def _naive_per_request(cfg, params, gv, v, rids, *, prompt_len, max_new):
    """Reload-per-request baseline: fresh open + FULL CSR + solo
    batch=1 decode, no cache, no batching."""
    import jax.numpy as jnp

    from repro.core import get_engine, open_graph
    from repro.data.walks import I32, random_walks
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, batch=1, max_seq=64)
    snap_engine = get_engine("snapshot")

    def one(rid):
        snap_engine.clear_memo()
        csr = open_graph(gv).csr()         # cold full load, every request
        import jax
        walk = random_walks(jnp.asarray(np.asarray(csr.offsets), I32),
                            jnp.asarray(np.asarray(csr.targets), I32),
                            jax.random.key(0), num_walks=1,
                            length=prompt_len, num_vertices=v,
                            walk_offset=rid)
        prompt = np.asarray(walk[0] % cfg.vocab_size, np.int32)
        eng.submit(Request(rid, prompt, max_new))
        eng.run()

    total = timeit(lambda: [one(r) for r in rids], repeat=1, warmup=1)
    return total / len(rids)


def run(quick: bool = False, json_path: str = None):
    import jax

    from repro.configs import reduced_config
    from repro.core.cache import SourceCache
    from repro.data.corpus import CorpusConfig, WalkCorpus
    from repro.core.source import open_graph
    from repro.ft.coordinator import FTConfig
    from repro.models import init_params
    from repro.serve.runtime import ServeRuntime

    gv, v, e = _snapshot(quick)
    mb = os.path.getsize(gv) / 1e6
    cfg = reduced_config("phi4-mini-3.8b")
    params = init_params(jax.random.key(0), cfg)

    n_req = 16 if quick else 48
    prompt_len, max_new = 6, 8 if quick else 16
    ft = FTConfig(straggler_policy="degrade", straggler_factor=16.0,
                  straggler_window=8)

    def runtime():
        return ServeRuntime(cfg, params, batch=4, max_seq=64,
                            cache=SourceCache(capacity=4), ft=ft,
                            prompt_len=prompt_len)

    # warm the jit caches (prefill + decode + walk shapes) off the clock
    runtime().serve([gv] * 4, max_new=max_new)

    rt = runtime()
    t_served = timeit(lambda: rt.serve([gv] * n_req, max_new=max_new),
                      repeat=1, warmup=0)
    st = rt.stats()
    served_per_req = t_served / n_req

    naive = _naive_per_request(cfg, params, gv, v,
                               list(range(3 if quick else 6)),
                               prompt_len=prompt_len, max_new=max_new)

    # corpus resume-vs-replay: batches [k, k+m) from the cursor vs a
    # sequential replay from 0 (non-step-indexed restart)
    cc = CorpusConfig(batch=8, seq=32, vocab_size=cfg.vocab_size, seed=5)
    corpus = WalkCorpus(open_graph(gv), cc)
    k, m = (16, 4) if quick else (64, 8)
    corpus.batch_at(0)                     # warm walk jit for this shape

    def consume(start, count):
        with corpus.batches(start) as stream:
            for _ in range(count):
                next(stream)

    t_replay = timeit(lambda: consume(0, k + m), repeat=1, warmup=0)
    t_resume = timeit(lambda: consume(k, m), repeat=1, warmup=0)

    rows = []

    def row(name, seconds, speedup, derived=""):
        emit(name, seconds, derived + (";" if derived else "") + f"mb={mb:.2f}")
        rows.append({"name": name, "seconds": round(seconds, 6),
                     "mb": round(mb, 3), "speedup": round(speedup, 2)})

    toks = st["tokens"]
    row("e2e.serve_naive", naive * n_req, 1.0,
        f"per_req={naive * 1e6:.0f}us;scaled_from_sample")
    row("e2e.serve_walks_tokens", t_served, naive / served_per_req,
        f"n={n_req};tokens={toks};tok_per_s={toks / t_served:.1f};"
        f"req_per_s={n_req / t_served:.2f};occupancy={st['occupancy']};"
        f"vs_naive={naive / served_per_req:.1f}x")
    row("e2e.serve_resume", t_resume, t_replay / t_resume,
        f"k={k};m={m};replay={t_replay:.3f}s;"
        f"vs_replay={t_replay / t_resume:.1f}x")
    print(f"runtime.stats: {json.dumps(st)}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.serve_walks "
                     "[--quick] [--json OUT.json]")
        out = argv[i + 1]
    run(quick="--quick" in argv, json_path=out)
