"""Paper Fig. 7: Edgelist reading, GVEL vs PIGO, per graph class.
Reports the edges/s read rate (the paper's headline: 1.9 B edges/s on
64 Xeon cores + RAID SSDs; this host is 1 core — rates scale with cores
because the path is pleasingly parallel, see fig9)."""
from .common import DATASETS, dataset, emit, timeit


def run():
    from repro.core import baselines, read_edgelist_numpy

    for ds in DATASETS:
        path, v, e = dataset(ds)
        t_p = timeit(lambda: baselines.read_edgelist_pigo(path, num_vertices=v))
        t_g = timeit(lambda: read_edgelist_numpy(path, num_vertices=v))
        emit(f"fig7.{ds}.pigo", t_p, f"edges_per_s={e / t_p:.3e}")
        emit(f"fig7.{ds}.gvel", t_g,
             f"edges_per_s={e / t_g:.3e};vs_pigo={t_p / t_g:.2f}x")


if __name__ == "__main__":
    run()
