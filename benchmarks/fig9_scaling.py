"""Paper Fig. 9: strong scaling with worker count (1..16) and, for the
sharded streaming loader, with mesh shard count (1, 2, 4).

Workers are threads over newline-aligned chunks (reading) and over
partition-local sorts (CSR build) — numpy's C kernels release the GIL,
so on a multicore host this scales like the paper's OpenMP loops.  The
shard sweep times ``core.distributed.load_csr_sharded_stream`` over
meshes of 1, 2 and 4 forced host devices inside one subprocess (the
device count is fixed at 4 so XLA's threadpool split is identical
across mesh widths).  This container exposes a single core: the
harness still sweeps both grids and reports the (necessarily flat or
declining) curves; the derived field carries cores_available so the
result is interpretable.  On real cores the shard sweep is the
end-to-end strong-scaling figure — every stage including the parse
runs on the mesh.
"""
import json
import os
import subprocess
import sys

import numpy as np

from .common import dataset, emit, timeit

_SHARD_SWEEP_CODE = """
import json, sys, time
import numpy as np, jax
from repro.core.compat import device_mesh
from repro.core.distributed import load_csr_sharded_stream

path, v = sys.argv[1], int(sys.argv[2])
out = {}
for d in (1, 2, 4):
    mesh = device_mesh(np.array(jax.devices()[:d]), ("data",))
    fn = lambda: load_csr_sharded_stream(mesh, "data", path, num_vertices=v)
    fn()                                   # compile warmup
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter(); fn(); best = min(best, time.perf_counter() - t0)
    out[f"d{d}"] = best
print("SWEEP_JSON " + json.dumps(out))
"""


def _shard_sweep(path, v):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SWEEP_CODE, path, str(v)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"shard sweep subprocess failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("SWEEP_JSON ")][-1]
    return json.loads(line[len("SWEEP_JSON "):])


def run():
    from repro.core import load_edgelist
    from repro.core.build import csr_binned_np, csr_staged_np

    path, v, e = dataset("web_rmat")
    cores = os.cpu_count()
    el = load_edgelist(path, engine="threads", num_vertices=v, num_workers=1)
    n = int(el.num_edges)
    src = np.asarray(el.src[:n])
    dst = np.asarray(el.dst[:n])

    base_el = base_csr = base_bin = None
    for w in [1, 2, 4, 8, 16]:
        t_el = timeit(lambda ww=w: load_edgelist(
            path, engine="threads", num_vertices=v, num_workers=ww), repeat=2)
        t_csr = timeit(lambda ww=w: csr_staged_np(
            src, dst, None, v, rho=max(4, ww), num_workers=ww), repeat=2)
        t_bin = timeit(lambda ww=w: csr_binned_np(
            src, dst, None, v, num_workers=ww), repeat=2)
        base_el = base_el or t_el
        base_csr = base_csr or t_csr
        base_bin = base_bin or t_bin
        emit(f"fig9.edgelist_w{w}", t_el,
             f"speedup={base_el / t_el:.2f}x;cores_available={cores}")
        emit(f"fig9.csr_w{w}", t_csr,
             f"speedup={base_csr / t_csr:.2f}x;cores_available={cores}")
        emit(f"fig9.csr_binned_w{w}", t_bin,
             f"speedup={base_bin / t_bin:.2f}x;cores_available={cores}")

    sweep = _shard_sweep(path, v)
    base = sweep["d1"]
    for d in (1, 2, 4):
        t = sweep[f"d{d}"]
        emit(f"fig9.sharded_d{d}", t,
             f"speedup={base / t:.2f}x;cores_available={cores}")


if __name__ == "__main__":
    run()
