"""Paper Fig. 9: strong scaling with worker count (1..16).

Workers are threads over newline-aligned chunks (reading) and over
partition-local sorts (CSR build) — numpy's C kernels release the GIL,
so on a multicore host this scales like the paper's OpenMP loops.  This
container exposes a single core: the harness still sweeps the worker
grid and reports the (necessarily flat) curve; the derived field carries
cores_available so the result is interpretable.
"""
import os

import numpy as np

from .common import dataset, emit, timeit


def run():
    from repro.core import load_edgelist
    from repro.core.build import csr_staged_np

    path, v, e = dataset("web_rmat")
    cores = os.cpu_count()
    el = load_edgelist(path, engine="threads", num_vertices=v, num_workers=1)
    n = int(el.num_edges)
    src = np.asarray(el.src[:n])
    dst = np.asarray(el.dst[:n])

    base_el = base_csr = None
    for w in [1, 2, 4, 8, 16]:
        t_el = timeit(lambda ww=w: load_edgelist(
            path, engine="threads", num_vertices=v, num_workers=ww), repeat=2)
        t_csr = timeit(lambda ww=w: csr_staged_np(
            src, dst, None, v, rho=max(4, ww), num_workers=ww), repeat=2)
        base_el = base_el or t_el
        base_csr = base_csr or t_csr
        emit(f"fig9.edgelist_w{w}", t_el,
             f"speedup={base_el / t_el:.2f}x;cores_available={cores}")
        emit(f"fig9.csr_w{w}", t_csr,
             f"speedup={base_csr / t_csr:.2f}x;cores_available={cores}")


if __name__ == "__main__":
    run()
