"""Paper Fig. 5/6: CSR reading (Edgelist read + CSR convert) vs frameworks.

  hornet/gunrock analogue -> naive stream read + python CSR insert
  pigo                    -> two-pass read + single-stage global CSR
  gvel                    -> single-pass read + staged rho=4 CSR

Across the three Table-1 graph classes (web / social / road stand-ins).
"""
from .common import DATASETS, dataset, emit, timeit


def run():
    from repro.core import baselines, convert_to_csr, read_edgelist_numpy

    for ds in DATASETS:
        path, v, e = dataset(ds)

        def naive():
            el = baselines.read_edgelist_naive(path, num_vertices=v)
            baselines.csr_pigo(el)

        def pigo():
            el = baselines.read_edgelist_pigo(path, num_vertices=v)
            baselines.csr_pigo(el)

        def gvel():
            el = read_edgelist_numpy(path, num_vertices=v)
            convert_to_csr(el, method="staged", rho=4, engine="numpy")

        t_n = timeit(naive, repeat=1, warmup=0)
        t_p = timeit(pigo)
        t_g = timeit(gvel)
        emit(f"fig5.{ds}.naive_framework", t_n, f"edges_per_s={e / t_n:.3e}")
        emit(f"fig5.{ds}.pigo", t_p,
             f"edges_per_s={e / t_p:.3e};vs_naive={t_n / t_p:.1f}x")
        emit(f"fig5.{ds}.gvel", t_g,
             f"edges_per_s={e / t_g:.3e};vs_naive={t_n / t_g:.1f}x;"
             f"vs_pigo={t_p / t_g:.2f}x")


if __name__ == "__main__":
    run()
