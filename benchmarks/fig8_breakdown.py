"""Paper Fig. 8: time split between Edgelist reading and CSR conversion."""
from .common import DATASETS, dataset, emit, timeit


def run():
    from repro.core import convert_to_csr, load_edgelist

    for ds in DATASETS:
        path, v, e = dataset(ds)
        el = load_edgelist(path, engine="numpy", num_vertices=v)
        t_el = timeit(lambda: load_edgelist(path, engine="numpy",
                                            num_vertices=v))
        t_c = timeit(lambda: convert_to_csr(el, method="staged", rho=4,
                                            engine="numpy"))
        emit(f"fig8.{ds}.edgelist", t_el,
             f"share={t_el / (t_el + t_c) * 100:.0f}%")
        emit(f"fig8.{ds}.to_csr", t_c,
             f"share={t_c / (t_el + t_c) * 100:.0f}%")


if __name__ == "__main__":
    run()
