"""Paper Fig. 4: partition-count (rho) sweep for the staged CSR build."""
import jax.numpy as jnp

from .common import dataset, emit, timeit


def run():
    from repro.core import build, read_edgelist_numpy
    path, v, e = dataset("web_rmat")
    el = read_edgelist_numpy(path, num_vertices=v)
    n = int(el.num_edges)
    src = jnp.asarray(el.src[:n])
    dst = jnp.asarray(el.dst[:n])
    base = None
    for rho in [1, 2, 4, 8, 16, 32]:
        def fn(r=rho):
            o, t, _ = build.csr_staged(src, dst, None, v, rho=r)
            t.block_until_ready()
        t = timeit(fn)
        base = base or t
        emit(f"fig4.rho_{rho}", t, f"rel_to_rho1={t / base:.2f}x")


if __name__ == "__main__":
    run()
