"""Serve a small model with batched requests (continuous batching engine).

  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--arch", "phi4-mini-3.8b", "--reduced",
                   "--requests", "12", "--max-new", "24",
                   "--batch", "4", "--max-seq", "96"]))
