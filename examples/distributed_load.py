"""Distributed graph loading across a device mesh (GVEL staged at scale).

Run with simulated devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_load.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402

from repro.core import host_shard_and_load, make_graph_file  # noqa: E402


def main():
    n = len(jax.devices())
    print(f"devices: {n}")
    mesh = make_mesh((n,), ("data",))

    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "g.el")
    v, e = make_graph_file(path, "rmat", scale=12, edge_factor=8)
    print(f"graph: |V|={v:,} |E|={e:,}")

    # stage 0: each shard parses its byte range (per-device edgelists)
    # stage 1: partial degrees -> psum      (partitioned degree counting)
    # stage 2: all_to_all by vertex owner   (the merge, as a collective)
    # stage 3: shard-local staged CSR build (contention-free)
    csr = host_shard_and_load(mesh, "data", path, num_vertices=v)
    off = np.asarray(csr.offsets)
    total = int(off[:, -1].sum())
    print(f"vertex-partitioned CSR: {off.shape[0]} shards x "
          f"{off.shape[1]-1} rows; total edges={total:,}")
    assert total == e
    rows_per = off.shape[1] - 1
    for k in range(min(n, 4)):
        print(f"  shard {k}: owns vertices [{k*rows_per}, "
              f"{(k+1)*rows_per}) with {int(off[k, -1]):,} edges")
    print("OK")


if __name__ == "__main__":
    main()
