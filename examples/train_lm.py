"""End-to-end driver: train a ~100M-param LM on a GVEL-loaded graph corpus.

The full pipeline the framework exists for: text edgelist --GVEL--> CSR
--random walks--> token batches --> train_step (AdamW, remat, ckpt).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  (defaults are sized for CPU; --full-width uses the ~100M config)
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--full-width", action="store_true",
                   help="~100M params (slower on CPU)")
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args()

    import dataclasses

    import jax

    from repro.configs import get_config, reduced_config
    from repro.core import read_csr, make_graph_file
    from repro.data.walks import walk_batch
    from repro.ft.coordinator import Coordinator, FTConfig
    from repro.models import init_params
    from repro.train import loop as train_loop
    from repro.train.optimizer import OptimizerConfig
    from repro.train.state import init_state
    from repro.train.step import make_train_step

    if args.full_width:
        # ~100M decoder: 12 x 768 with a 32k vocab
        cfg = dataclasses.replace(
            get_config("phi4-mini-3.8b"), num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
            vocab_size=32768)
    else:
        cfg = reduced_config("phi4-mini-3.8b")

    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "corpus.el")
    v, e = make_graph_file(path, "rmat", scale=13, edge_factor=16)
    t0 = time.perf_counter()
    csr = read_csr(path, num_vertices=v, method="staged", engine="numpy")
    print(f"GVEL: loaded |V|={v:,} |E|={e:,} to CSR in "
          f"{time.perf_counter()-t0:.2f}s")

    params = init_params(jax.random.key(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    oc = OptimizerConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps)
    step = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))
    state = init_state(params)
    src = lambda i: walk_batch(csr, cfg, args.batch, args.seq, i)
    state, hist = train_loop.run(
        state, step, src, num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        coordinator=Coordinator(FTConfig(ckpt_every=100)), log_every=20)
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
