"""Quickstart: load a graph into EdgeList and CSR with GVEL.

  PYTHONPATH=src python examples/quickstart.py

Everything goes through the GraphSource front door — ``open_graph``
returns a lazy handle that resolves format/codec/engine once, probes
metadata for free (``info()``), and memoizes its products.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import available_engines, make_graph_file, open_graph


def main():
    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "web.el")
    print("generating an RMAT web-like graph ...")
    v, e = make_graph_file(path, "rmat", scale=14, edge_factor=16)
    size = os.path.getsize(path)
    print(f"  |V|={v:,} |E|={e:,}  ({size/1e6:.1f} MB text)")
    print(f"loader engines: {available_engines()}")

    # open_graph is cheap: it sniffs format + codec, nothing more.
    # (try it from a shell: PYTHONPATH=src python -m repro.core.source FILE)
    src = open_graph(path, num_vertices=v)
    print(f"opened {src!r}")
    print(f"  info: {src.info().to_dict()}")

    t0 = time.perf_counter()
    el = src.edgelist()                      # host parse (numpy engine)
    t_el = time.perf_counter() - t0
    print(f"edgelist(): {int(el.num_edges):,} edges in "
          f"{t_el*1e3:.0f} ms ({int(el.num_edges)/t_el/1e6:.2f} M edges/s)")

    t0 = time.perf_counter()
    csr = src.csr(method="staged", rho=4)    # fused streaming device build
    t_c = time.perf_counter() - t0
    assert int(csr.offsets[-1]) == e
    print(f"csr() end-to-end (streaming device engine): {t_c*1e3:.0f} ms; "
          f"offsets[-1]={int(csr.offsets[-1]):,}")
    assert src.csr() is src.csr()            # products are memoized

    deg = csr.degrees()
    print(f"degree stats: max={int(deg.max())}, mean={float(deg.mean()):.1f} "
          f"(power law => staged build wins, per the paper)")

    # write once, load many: snapshot the parsed edgelist + prebuilt CSR,
    # then reload with zero parsing and zero building (pure mmap)
    gvel = os.path.join(tmp, "web.gvel")
    snap_src = src.save(gvel)                # returns a handle on the output
    print(f"saved {snap_src!r}")
    t0 = time.perf_counter()
    csr3 = open_graph(gvel).csr()
    t_s = time.perf_counter() - t0
    assert int(csr3.offsets[-1]) == e
    print(f"csr() from .gvel snapshot (embedded CSR, no parse/build): "
          f"{t_s*1e3:.1f} ms ({t_c/max(t_s, 1e-9):.0f}x vs streaming parse)")

    # compressed snapshot: .csr() lazily decodes ONLY the CSR sections
    zgvel = os.path.join(tmp, "web.z.gvel")
    src.save(zgvel, compress="zlib")
    zsrc = open_graph(zgvel)
    print(f"compressed snapshot: {zsrc.info().size_bytes/1e6:.2f} MB "
          f"(codec={zsrc.info().codec})")
    t0 = time.perf_counter()
    csr4 = zsrc.csr()                        # edgelist frames never decoded
    t_z = time.perf_counter() - t0
    assert int(csr4.offsets[-1]) == e
    print(f"csr() from compressed snapshot (lazy, CSR sections only): "
          f"{t_z*1e3:.1f} ms")


if __name__ == "__main__":
    main()
