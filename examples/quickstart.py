"""Quickstart: load a graph edgelist into Edgelist and CSR with GVEL.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (convert_to_csr, make_graph_file, read_csr,
                        read_edgelist_numpy)


def main():
    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "web.el")
    print("generating an RMAT web-like graph ...")
    v, e = make_graph_file(path, "rmat", scale=14, edge_factor=16)
    size = os.path.getsize(path)
    print(f"  |V|={v:,} |E|={e:,}  ({size/1e6:.1f} MB text)")

    t0 = time.perf_counter()
    el = read_edgelist_numpy(path, num_vertices=v)
    t_el = time.perf_counter() - t0
    print(f"read Edgelist: {int(el.num_edges):,} edges in {t_el*1e3:.0f} ms "
          f"({int(el.num_edges)/t_el/1e6:.2f} M edges/s)")

    t0 = time.perf_counter()
    csr = convert_to_csr(el, method="staged", rho=4)
    t_c = time.perf_counter() - t0
    print(f"staged CSR (rho=4): {t_c*1e3:.0f} ms; "
          f"offsets[-1]={int(csr.offsets[-1]):,}")

    deg = csr.degrees()
    print(f"degree stats: max={int(deg.max())}, mean={float(deg.mean()):.1f} "
          f"(power law => staged build wins, per the paper)")

    # one call end-to-end
    csr2 = read_csr(path, num_vertices=v, method="staged")
    assert int(csr2.offsets[-1]) == e
    print("read_csr end-to-end OK")


if __name__ == "__main__":
    main()
