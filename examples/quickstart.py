"""Quickstart: load a graph edgelist into Edgelist and CSR with GVEL.

  PYTHONPATH=src python examples/quickstart.py

Everything goes through the unified loader front door —
``load_edgelist``/``load_csr`` with an engine picked from the registry.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (available_engines, convert_to_csr, load_csr,
                        load_edgelist, make_graph_file, save_snapshot)


def main():
    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "web.el")
    print("generating an RMAT web-like graph ...")
    v, e = make_graph_file(path, "rmat", scale=14, edge_factor=16)
    size = os.path.getsize(path)
    print(f"  |V|={v:,} |E|={e:,}  ({size/1e6:.1f} MB text)")
    print(f"loader engines: {available_engines()}")

    t0 = time.perf_counter()
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    t_el = time.perf_counter() - t0
    print(f"read Edgelist (numpy engine): {int(el.num_edges):,} edges in "
          f"{t_el*1e3:.0f} ms ({int(el.num_edges)/t_el/1e6:.2f} M edges/s)")

    t0 = time.perf_counter()
    csr = convert_to_csr(el, method="staged", rho=4)
    t_c = time.perf_counter() - t0
    print(f"staged CSR (rho=4): {t_c*1e3:.0f} ms; "
          f"offsets[-1]={int(csr.offsets[-1]):,}")

    deg = csr.degrees()
    print(f"degree stats: max={int(deg.max())}, mean={float(deg.mean()):.1f} "
          f"(power law => staged build wins, per the paper)")

    # one call end-to-end: streaming device engine, parse fused into the
    # CSR build — no host EdgeList in between
    t0 = time.perf_counter()
    csr2 = load_csr(path, engine="device", num_vertices=v, method="staged")
    t_f = time.perf_counter() - t0
    assert int(csr2.offsets[-1]) == e
    print(f"load_csr end-to-end (streaming device engine): {t_f*1e3:.0f} ms OK")

    # write once, load many: snapshot the parsed edgelist + prebuilt CSR,
    # then reload with zero parsing and zero building (pure mmap)
    gvel = os.path.join(tmp, "web.gvel")
    save_snapshot(gvel, edgelist=el, csr=csr)
    t0 = time.perf_counter()
    csr3 = load_csr(gvel, engine="snapshot")
    t_s = time.perf_counter() - t0
    assert int(csr3.offsets[-1]) == e
    print(f"load_csr from .gvel snapshot (embedded CSR, no parse/build): "
          f"{t_s*1e3:.1f} ms ({t_f/max(t_s, 1e-9):.0f}x vs streaming parse)")


if __name__ == "__main__":
    main()
