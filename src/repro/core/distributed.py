"""Distributed graph loading: GVEL's staging generalized to a device mesh.

The paper's multi-stage CSR build exists to keep stage-local work
contention-free; across a mesh the same structure becomes:

  stage 0  every data shard parses its own byte range of the file
           (per-device edgelists == per-thread edgelists; pleasingly
           parallel, zero communication),
  stage 1  shard-local partial degree histograms -> ``psum`` over the data
           axis (the collective analogue of combining rho partition
           degree arrays),
  stage 2  edges are bucketed by *owner* shard (vertex range partition)
           and exchanged with a single ``all_to_all`` — the only
           communication step, playing the role of the paper's merge,
  stage 3  every shard builds the CSR rows of its own vertex range
           locally (staged rank-scatter, no shared state).

The result is a vertex-partitioned global CSR: shard k holds rows
[k*V/D, (k+1)*V/D).  This is the layout downstream samplers consume.

All functions are shard_map'd over one named mesh axis and are tested
under ``--xla_force_host_platform_device_count`` in CI.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import build, compat
from .types import CSR

I32 = jnp.int32


def _cap_round(n: int) -> int:
    """Smallest value in ``{2**k, 3 * 2**(k-1)}`` that is >= max(n, 1).

    A half-step power-of-two ladder: measured capacities (send buckets,
    valid-edge bounds) are rounded up to one of two sizes per octave, so
    buffers stay within 1.5x of the real need — a pure pow2 round-up
    wastes up to 2x, and on the exchange path that waste is sorted and
    scanned — while the number of distinct compiled programs stays
    bounded."""
    n = max(int(n), 1)
    p = 1 << (n - 1).bit_length()
    h = (3 * p) // 4
    return h if h >= n else p


def _owner(vid: jax.Array, rows_per_shard: int) -> jax.Array:
    return jnp.clip(vid // rows_per_shard, 0, None)


def exchange_by_owner(
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    num_shards: int,
    rows_per_shard: int,
    axis: str,
    send_cap: int,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array, jax.Array]:
    """Shard-local body: bucket edges by owner shard and all_to_all them.

    Inputs are this shard's fixed-capacity edge buffers (src == -1 pads).
    ``send_cap`` is the per-(shard,shard) bucket capacity — GVEL-style
    over-allocation so the exchange is a single dense collective.
    Returns ``(rcv_src, rcv_dst, rcv_w, count, overflow)``: receive
    buffers of shape (num_shards * send_cap,), the count of valid
    received edges, and the number of *this shard's* edges that did not
    fit their bucket.  A nonzero overflow means the exchange lost edges
    — callers must surface it (``load_csr_sharded`` raises), never
    return the truncated CSR.

    The bucketing is stable: edge i's within-bucket rank is the number
    of earlier edges with the same owner (a cumulative count, no sort),
    so within a bucket edges keep their order in ``src``.  Combined
    with ``all_to_all``'s sender-major receive layout, a shard that
    owns byte ranges in shard order receives its edges in global file
    order — which is what lets the sharded CSR match the host oracle
    bitwise, not just as sets.  (An earlier version bucketed via a
    stable argsort-by-owner; the cumulative count computes the same
    slots in O(e * num_shards) streaming passes instead of an
    O(e log e) sort, and skips the three gathers.)
    """
    owner = jnp.where(src >= 0, _owner(src, rows_per_shard), num_shards)
    oh = (owner[:, None] ==
          jnp.arange(num_shards, dtype=I32)[None, :]).astype(I32)
    rank = jnp.take_along_axis(
        jnp.cumsum(oh, axis=0),
        jnp.clip(owner, 0, num_shards - 1)[:, None].astype(I32),
        axis=1)[:, 0] - 1
    # scatter into (num_shards, send_cap) send buffers; bucket overflow
    # cannot be stored (the collective is dense), so it is *counted* and
    # returned for the caller to raise on
    keep = (owner < num_shards) & (rank < send_cap)
    overflow = jnp.sum((owner < num_shards) & (rank >= send_cap), dtype=I32)
    slot = jnp.where(keep, owner * send_cap + rank, num_shards * send_cap)
    buf = num_shards * send_cap

    def fill(vals, pad, dtype):
        return jnp.full((buf,), pad, dtype).at[slot].set(
            vals.astype(dtype), mode="drop")

    snd_src = fill(src, -1, I32).reshape(num_shards, send_cap)
    snd_dst = fill(dst, -1, I32).reshape(num_shards, send_cap)
    rcv_src = jax.lax.all_to_all(snd_src, axis, 0, 0, tiled=False).reshape(-1)
    rcv_dst = jax.lax.all_to_all(snd_dst, axis, 0, 0, tiled=False).reshape(-1)
    rcv_w = None
    if w is not None:
        snd_w = fill(w, 0.0, jnp.float32).reshape(num_shards, send_cap)
        rcv_w = jax.lax.all_to_all(snd_w, axis, 0, 0, tiled=False).reshape(-1)
    count = jnp.sum(rcv_src >= 0, dtype=I32)
    return rcv_src, rcv_dst, rcv_w, count, overflow


def build_local_csr(
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    rows_per_shard: int,
    axis: str,
    rho: int = 4,
    method: str = "staged",
    bin_bits: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Shard-local body: rank-based CSR (``staged`` or ``binned``) over
    this shard's owned vertex range."""
    my = jax.lax.axis_index(axis)
    local = jnp.where(src >= 0, src - my * rows_per_shard, -1)
    if method == "binned":
        offsets, targets, ww = build.csr_binned(
            local, dst, w, rows_per_shard, bin_bits=bin_bits,
            weighted=w is not None)
    else:
        offsets, targets, ww = build.csr_staged(
            local, dst, w, rows_per_shard, rho=rho, weighted=w is not None)
    return offsets, targets, ww


def load_csr_sharded(
    mesh: Mesh,
    axis: str,
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    num_vertices: int,
    rho: int = 4,
    method: str = "staged",
    bin_bits: Optional[int] = None,
    send_cap: Optional[int] = None,
    edge_limit: Optional[int] = None,
) -> CSR:
    """Edge buffers (sharded on `axis`) -> vertex-partitioned global CSR.

    ``src``/``dst`` are fixed-capacity buffers whose leading dim is sharded
    across the data axis (each shard parsed its own file range).  Output
    offsets/targets are sharded on `axis`: shard k owns rows
    [k*rows, (k+1)*rows).

    ``send_cap`` defaults to the worst case (every local edge owned by
    one shard); :func:`load_csr_sharded_stream` sizes it from measured
    per-bucket counts instead.  If any shard's bucket overflows
    ``send_cap`` the exchange cannot carry every edge — this raises
    ``ValueError`` rather than returning a CSR with silently dropped
    edges.

    ``edge_limit`` is a static per-shard bound on valid edges: the fused
    accumulators pack valid edges at the buffer prefix, so slicing each
    shard's buffers to a bound >= every shard's valid-edge count is
    lossless and keeps the bucketing scan off the padding tail.  Callers
    who pass it are responsible for the bound (edges past it are never
    examined); ``load_csr_sharded_stream`` derives it from the measured
    per-shard counts.
    """
    d = mesh.shape[axis]
    rows = max(-(-num_vertices // d), 1)
    e_per = src.shape[0] // d
    if send_cap is None:
        send_cap = e_per  # worst case: every local edge goes to one owner
    lim = e_per if edge_limit is None else max(min(int(edge_limit), e_per), 1)

    weighted = w is not None
    fn = _exchange_build_fn(mesh, axis, d, rows, int(send_cap), rho,
                            weighted, lim, method, bin_bits)
    win = w if weighted else jnp.zeros((), jnp.float32)
    off, tgt, tw, ovf = fn(src, dst, win)
    ovf_h = np.asarray(ovf)
    if ovf_h.sum():
        raise ValueError(
            f"exchange_by_owner overflow: {int(ovf_h.sum())} edge(s) "
            f"(worst shard: {int(ovf_h.max())}) did not fit their "
            f"per-owner bucket at send_cap={send_cap}; the exchange "
            f"would drop them.  Raise send_cap (worst case: the per-shard "
            f"buffer capacity {e_per}) or let load_csr_sharded_stream "
            f"measure it from the real bucket counts.")
    return CSR(off, tgt, tw if weighted else None, num_vertices, row_start=0)


@functools.lru_cache(maxsize=64)
def _exchange_build_fn(mesh: Mesh, axis: str, d: int, rows: int,
                       send_cap: int, rho: int, weighted: bool,
                       edge_limit: Optional[int] = None,
                       method: str = "staged",
                       bin_bits: Optional[int] = None):
    """The jitted exchange+build program for one (mesh, geometry) combo.

    shard_map over a fresh closure defeats jax's jit cache (new function
    identity every call -> retrace + recompile per load); memoizing the
    wrapped callable on the static configuration restores one-compile-
    per-geometry behavior, same as the module-level jitted parse
    programs on the single-device path."""

    lim = slice(None) if edge_limit is None else slice(None, edge_limit)

    def body(s, dd, ww):
        s, dd = s.reshape(-1)[lim], dd.reshape(-1)[lim]
        ww = ww.reshape(-1)[lim] if weighted else None
        rs, rd, rw, _, ovf = exchange_by_owner(
            s, dd, ww, num_shards=d, rows_per_shard=rows,
            axis=axis, send_cap=send_cap)
        off, tgt, tw = build_local_csr(rs, rd, rw, rows_per_shard=rows,
                                       axis=axis, rho=rho, method=method,
                                       bin_bits=bin_bits)
        if tw is None:
            tw = jnp.zeros_like(tgt, jnp.float32)
        return off[None], tgt[None], tw[None], ovf[None]

    specs = P(axis)
    in_specs = (specs, specs, specs if weighted else P())
    out_specs = (P(axis), P(axis), P(axis), P(axis))
    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs))


def _shard_devices(mesh: Mesh, axis: str, e_per: int):
    """Per-shard device placement for a length-``d*e_per`` array sharded
    on ``axis``: ``(sharding, groups)`` where ``groups[k]`` is the list
    of devices holding shard k's slice (one primary first; extras only
    when the mesh has other axes, which replicate the slice)."""
    d = mesh.shape[axis]
    sharding = NamedSharding(mesh, P(axis))
    devmap = sharding.addressable_devices_indices_map((d * e_per,))
    by_start: dict = {}
    for dev, idx in devmap.items():
        by_start.setdefault(idx[0].start or 0, []).append(dev)
    groups = [sorted(by_start[s], key=lambda dv: dv.id)
              for s in sorted(by_start)]
    if len(groups) != d:
        raise ValueError(
            f"axis {axis!r} of mesh {mesh} yields {len(groups)} distinct "
            f"shard slices, expected {d}")
    return sharding, groups


def stream_shards(
    mesh: Mesh,
    axis: str,
    path: str,
    *,
    weighted: bool = False,
    base: int = 1,
    offset: int = 0,
    beta: Optional[int] = None,
    overlap: Optional[int] = None,
    batch_blocks: Optional[int] = None,
    parse: str = "xla",
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], list, int]:
    """Stage 0, streamed: every shard parses its own byte range of the
    file through the fused donated pipeline, on its own device.

    The file's ``BlockPlan`` is split into ``d`` block-aligned byte
    spans (:func:`repro.core.blocks.shard_plan` — line ownership makes
    block-aligned splits safe, and framed codecs force ``beta`` to the
    frame size so the split is frame-aligned too).  Each shard gets its
    own block source over only its span (raw: shared mmap; framed:
    frame-index seek; gzip: prefix skip) and runs the same staged →
    fused ``parse_accumulate`` loop as the single-host streaming engine,
    with its accumulators *committed to its mesh device* — one worker
    thread per shard stages host bytes while its device parses, and the
    d device pipelines run concurrently.

    Returns ``(src, dst, w, counts, max_vertex_id)``: global arrays of
    ``d * e_per`` slots sharded on ``axis`` (assembled from the
    per-device accumulators without any host round-trip), the per-shard
    valid-edge counts, and the maximum vertex id seen (-1 when empty).
    """
    from concurrent.futures import ThreadPoolExecutor
    from concurrent.futures import TimeoutError as _FutTimeout

    from . import codecs, faults as faults_mod, loader, parse as parse_mod
    from .blocks import plan_blocks, shard_plan

    d = mesh.shape[axis]
    beta = loader.DEFAULT_BETA if beta is None else beta
    overlap = loader.DEFAULT_OVERLAP if overlap is None else overlap
    batch_blocks = (loader.DEFAULT_BATCH_BLOCKS if batch_blocks is None
                    else batch_blocks)
    length, forced_beta = codecs.stream_geometry(path, offset)
    if forced_beta is not None and forced_beta > overlap:
        beta = forced_beta
    plan = plan_blocks(length, beta=beta, overlap=overlap)
    spans = [shard_plan(plan, k, d) for k in range(d)]
    # uniform per-shard capacity (the exchange needs equal-sized shards);
    # spans are balanced to within one block, so the padding this costs
    # over exact per-span caps is at most one block's edge_cap per shard
    e_per = max(max(s.num_blocks for s in spans), 1) * plan.edge_cap
    loader._guard_int32_cap(path, e_per)
    sharding, groups = _shard_devices(mesh, axis, e_per)

    def load_one(k: int):
        span, dev = spans[k], groups[k][0]
        if span.num_blocks == 0:
            # mesh wider than the plan: an empty, still device-resident
            # accumulator (all padding) — the exchange handles it
            return parse_mod.make_accumulators(
                e_per, weighted=weighted, device=dev)
        source = codecs.open_shard_block_source(path, plan, span, offset)
        out = loader._parse_span(
            source, plan, span.block_lo, span.block_hi, weighted=weighted,
            base=base, batch_blocks=batch_blocks, parse=parse, cap=e_per,
            device=dev, prefetch=False)
        source.finish()
        return out

    def load_with_recovery(k: int):
        """``load_one`` with shard-level re-execution: block plans are
        pure functions of the file and each attempt opens a fresh source
        and fresh accumulators, so a re-executed span is bitwise
        identical to a first-try parse.  Transient faults (and stage
        timeouts — a stuck reader may unstick on reopen) re-execute up
        to ``faults.SHARD_RETRIES`` extra times; then the load fails
        with the shard's fault log."""
        span = spans[k]
        attempts = faults_mod.SHARD_RETRIES + 1
        fault_log = []
        for attempt in range(attempts):
            try:
                return load_one(k)
            except (OSError, faults_mod.StageTimeout) as exc:
                transient = (faults_mod.is_transient(exc)
                             or isinstance(exc, faults_mod.StageTimeout))
                fault_log.append(
                    f"attempt {attempt + 1}: {type(exc).__name__}: {exc}")
                if not transient or attempt + 1 >= attempts:
                    raise faults_mod.ShardLoadError(
                        f"{path}: shard {k}/{d} failed loading byte span "
                        f"[{span.byte_lo}, {span.byte_hi}) after "
                        f"{attempt + 1} attempt(s):\n  "
                        + "\n  ".join(fault_log),
                        shard=k, fault_log=fault_log) from exc
                faults_mod._count("shard_retries")

    if d == 1:
        parts = [load_with_recovery(0)]
    else:
        # not a with-block: on a watchdog timeout the stuck shard thread
        # is abandoned (shutdown(wait=False)), never joined
        pool = ThreadPoolExecutor(d, thread_name_prefix="shard-load")
        try:
            futs = [pool.submit(load_with_recovery, k) for k in range(d)]
            parts = []
            for k, fut in enumerate(futs):
                try:
                    parts.append(fut.result(timeout=faults_mod.WATCHDOG_S))
                except _FutTimeout:
                    faults_mod._count("stage_timeouts")
                    span = spans[k]
                    raise faults_mod.StageTimeout(
                        f"{path}: shard {k}/{d} produced nothing within "
                        f"the {faults_mod.WATCHDOG_S:.1f}s watchdog budget "
                        f"(REPRO_WATCHDOG_S) for byte span "
                        f"[{span.byte_lo}, {span.byte_hi}); the shard "
                        f"thread is stuck") from None
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    counts = [int(t) for (_, _, _, t) in parts]
    max_id = -1
    for s, dd, _, _ in parts:
        max_id = max(max_id, int(jnp.maximum(jnp.max(s, initial=-1),
                                             jnp.max(dd, initial=-1))))

    def assemble(per_shard):
        arrays = []
        for k, devs in enumerate(groups):
            arrays.append(per_shard[k])
            # replicated slices (other mesh axes): device-to-device copies
            arrays.extend(jax.device_put(per_shard[k], dev)
                          for dev in devs[1:])
        return jax.make_array_from_single_device_arrays(
            (d * e_per,), sharding, arrays)

    src = assemble([p[0] for p in parts])
    dst = assemble([p[1] for p in parts])
    w = assemble([p[2] for p in parts]) if weighted else None
    return src, dst, w, counts, max_id


def bucket_histogram(
    mesh: Mesh,
    axis: str,
    src: jax.Array,
    *,
    num_shards: int,
    rows_per_shard: int,
    edge_limit: Optional[int] = None,
) -> np.ndarray:
    """(sender, owner) edge counts over the sharded ``src`` buffers —
    the real bucket sizes the exchange will see.  One shard-local
    scatter-add per shard (runs on each shard's device); the (d, d)
    result is tiny and lands on the host, where
    :func:`load_csr_sharded_stream` sizes ``send_cap`` from its peak.
    ``edge_limit`` bounds the scan as in :func:`load_csr_sharded`."""
    fn = _bucket_histogram_fn(mesh, axis, num_shards, rows_per_shard,
                              edge_limit)
    return np.asarray(fn(src))


@functools.lru_cache(maxsize=64)
def _bucket_histogram_fn(mesh: Mesh, axis: str, num_shards: int,
                         rows_per_shard: int,
                         edge_limit: Optional[int] = None):
    """Jitted histogram body, memoized for the same reason as
    :func:`_exchange_build_fn`."""
    lim = slice(None) if edge_limit is None else slice(None, edge_limit)

    def body(s):
        s = s.reshape(-1)[lim]
        owner = jnp.minimum(
            jnp.where(s >= 0, _owner(s, rows_per_shard), num_shards),
            num_shards)
        cnt = jnp.zeros((num_shards + 1,), I32).at[owner].add(1)
        return cnt[None, :num_shards]

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(axis),
                                    out_specs=P(axis)))


def load_csr_sharded_stream(
    mesh: Mesh,
    axis: str,
    path: str,
    *,
    num_vertices: Optional[int] = None,
    weighted: bool = False,
    base: int = 1,
    rho: int = 4,
    method: str = "staged",
    bin_bits: Optional[int] = None,
    offset: int = 0,
    send_cap: Optional[int] = None,
    parse: str = "xla",
    beta: Optional[int] = None,
    overlap: Optional[int] = None,
    batch_blocks: Optional[int] = None,
) -> CSR:
    """File -> vertex-partitioned global CSR, every stage sharded.

    The end-to-end four-stage pipeline: :func:`stream_shards` (stage 0,
    per-device fused parse of per-shard byte ranges), then the
    psum / all_to_all / local-build stages of :func:`load_csr_sharded`.
    No host detour: parsed edges stay on their devices from accumulator
    to CSR.

    ``send_cap=None`` sizes the exchange from *measured* per-bucket
    counts (:func:`bucket_histogram`, rounded up on the half-step
    ladder of :func:`_cap_round` to bound recompiles) instead of the
    worst-case ``e_per`` — receive buffers and the local sort shrink
    from O(E) to O(E/d) per shard on well-spread graphs.  The same
    ladder bounds the valid-edge prefix each shard scans
    (``edge_limit`` from the measured per-shard counts), so neither the
    bucketing nor the histogram ever touches the capacity padding.
    Overflow is still detected and raised, so a hand-passed
    ``send_cap`` can never silently drop edges.
    """
    src, dst, w, counts, max_id = stream_shards(
        mesh, axis, path, weighted=weighted, base=base, offset=offset,
        beta=beta, overlap=overlap, batch_blocks=batch_blocks, parse=parse)
    if num_vertices is None:
        num_vertices = max_id + 1
    d = mesh.shape[axis]
    rows = max(-(-num_vertices // d), 1)
    e_per = src.shape[0] // d
    edge_limit = min(e_per, _cap_round(max(counts, default=0)))
    if send_cap is None:
        peak = int(bucket_histogram(mesh, axis, src, num_shards=d,
                                    rows_per_shard=rows,
                                    edge_limit=edge_limit).max())
        send_cap = _cap_round(peak)
    return load_csr_sharded(mesh, axis, src, dst, w,
                            num_vertices=num_vertices, rho=rho,
                            method=method, bin_bits=bin_bits,
                            send_cap=send_cap, edge_limit=edge_limit)


def host_shard_and_load(
    mesh: Mesh,
    axis: str,
    path: str,
    *,
    num_vertices: int,
    weighted: bool = False,
    base: int = 1,
    rho: int = 4,
) -> CSR:
    """Compatibility wrapper: the historical end-to-end entry point.

    This used to parse every chunk sequentially on the host with the
    numpy parser and ``device_put`` capacity-sized buffers per shard;
    it is now a thin alias for :func:`load_csr_sharded_stream`, which
    streams each shard's byte range through the fused device parse.
    Prefer ``GraphSource.csr_sharded(mesh)`` or
    :func:`load_csr_sharded_stream` directly.
    """
    return load_csr_sharded_stream(
        mesh, axis, path, num_vertices=num_vertices, weighted=weighted,
        base=base, rho=rho)
