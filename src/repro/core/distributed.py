"""Distributed graph loading: GVEL's staging generalized to a device mesh.

The paper's multi-stage CSR build exists to keep stage-local work
contention-free; across a mesh the same structure becomes:

  stage 0  every data shard parses its own byte range of the file
           (per-device edgelists == per-thread edgelists; pleasingly
           parallel, zero communication),
  stage 1  shard-local partial degree histograms -> ``psum`` over the data
           axis (the collective analogue of combining rho partition
           degree arrays),
  stage 2  edges are bucketed by *owner* shard (vertex range partition)
           and exchanged with a single ``all_to_all`` — the only
           communication step, playing the role of the paper's merge,
  stage 3  every shard builds the CSR rows of its own vertex range
           locally (staged rank-scatter, no shared state).

The result is a vertex-partitioned global CSR: shard k holds rows
[k*V/D, (k+1)*V/D).  This is the layout downstream samplers consume.

All functions are shard_map'd over one named mesh axis and are tested
under ``--xla_force_host_platform_device_count`` in CI.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import build, compat
from .types import CSR

I32 = jnp.int32


def _owner(vid: jax.Array, rows_per_shard: int) -> jax.Array:
    return jnp.clip(vid // rows_per_shard, 0, None)


def exchange_by_owner(
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    num_shards: int,
    rows_per_shard: int,
    axis: str,
    send_cap: int,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array]:
    """Shard-local body: bucket edges by owner shard and all_to_all them.

    Inputs are this shard's fixed-capacity edge buffers (src == -1 pads).
    ``send_cap`` is the per-(shard,shard) bucket capacity — GVEL-style
    over-allocation so the exchange is a single dense collective.
    Returns receive buffers of shape (num_shards * send_cap,).
    """
    e = src.shape[0]
    owner = jnp.where(src >= 0, _owner(src, rows_per_shard), num_shards)
    # stable bucket: sort by owner, then compute within-bucket rank
    order = jnp.argsort(owner, stable=True)
    so, ss, sd = owner[order], src[order], dst[order]
    sw = w[order] if w is not None else None
    first = jnp.searchsorted(so, jnp.arange(num_shards + 1, dtype=I32), side="left")
    rank = jnp.arange(e, dtype=I32) - first[jnp.clip(so, 0, num_shards)]
    # scatter into (num_shards, send_cap) send buffers; overflow dropped —
    # callers size send_cap from a bytes bound so this cannot trigger.
    slot = jnp.where((so < num_shards) & (rank < send_cap),
                     so * send_cap + rank, num_shards * send_cap)
    buf = num_shards * send_cap

    def fill(vals, pad, dtype):
        return jnp.full((buf,), pad, dtype).at[slot].set(
            vals.astype(dtype), mode="drop")

    snd_src = fill(ss, -1, I32).reshape(num_shards, send_cap)
    snd_dst = fill(sd, -1, I32).reshape(num_shards, send_cap)
    rcv_src = jax.lax.all_to_all(snd_src, axis, 0, 0, tiled=False).reshape(-1)
    rcv_dst = jax.lax.all_to_all(snd_dst, axis, 0, 0, tiled=False).reshape(-1)
    rcv_w = None
    if w is not None:
        snd_w = fill(sw, 0.0, jnp.float32).reshape(num_shards, send_cap)
        rcv_w = jax.lax.all_to_all(snd_w, axis, 0, 0, tiled=False).reshape(-1)
    count = jnp.sum(rcv_src >= 0, dtype=I32)
    return rcv_src, rcv_dst, rcv_w, count


def build_local_csr(
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    rows_per_shard: int,
    axis: str,
    rho: int = 4,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Shard-local body: staged CSR over this shard's owned vertex range."""
    my = jax.lax.axis_index(axis)
    local = jnp.where(src >= 0, src - my * rows_per_shard, -1)
    offsets, targets, ww = build.csr_staged(
        local, dst, w, rows_per_shard, rho=rho, weighted=w is not None)
    return offsets, targets, ww


def load_csr_sharded(
    mesh: Mesh,
    axis: str,
    src: jax.Array,
    dst: jax.Array,
    w: Optional[jax.Array],
    *,
    num_vertices: int,
    rho: int = 4,
    send_cap: Optional[int] = None,
) -> CSR:
    """Edge buffers (sharded on `axis`) -> vertex-partitioned global CSR.

    ``src``/``dst`` are fixed-capacity buffers whose leading dim is sharded
    across the data axis (each shard parsed its own file range).  Output
    offsets/targets are sharded on `axis`: shard k owns rows
    [k*rows, (k+1)*rows).
    """
    d = mesh.shape[axis]
    rows = -(-num_vertices // d)
    e_per = src.shape[0] // d
    if send_cap is None:
        send_cap = e_per  # worst case: every local edge goes to one owner

    weighted = w is not None

    def body(s, dd, ww):
        s, dd = s.reshape(-1), dd.reshape(-1)
        ww = ww.reshape(-1) if weighted else None
        rs, rd, rw, _ = exchange_by_owner(
            s, dd, ww, num_shards=d, rows_per_shard=rows,
            axis=axis, send_cap=send_cap)
        off, tgt, tw = build_local_csr(rs, rd, rw, rows_per_shard=rows,
                                       axis=axis, rho=rho)
        if tw is None:
            tw = jnp.zeros_like(tgt, jnp.float32)
        return off[None], tgt[None], tw[None]

    specs = P(axis)
    in_specs = (specs, specs, specs if weighted else P())
    out_specs = (P(axis), P(axis), P(axis))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    win = w if weighted else jnp.zeros((), jnp.float32)
    off, tgt, tw = fn(src, dst, win)
    return CSR(off, tgt, tw if weighted else None, num_vertices, row_start=0)


def host_shard_and_load(
    mesh: Mesh,
    axis: str,
    path: str,
    *,
    num_vertices: int,
    weighted: bool = False,
    base: int = 1,
    rho: int = 4,
) -> CSR:
    """Convenience end-to-end: parse the file in D host chunks (stage 0),
    place each chunk on its shard, then run the distributed build."""
    from . import parse_np
    d = mesh.shape[axis]
    data = np.memmap(path, dtype=np.uint8, mode="r")
    bounds = parse_np.chunk_bounds(data, d)
    while len(bounds) < d:
        bounds.append((len(data), len(data)))
    parts = [parse_np.parse_chunk_np(np.asarray(data[lo:hi]),
                                     weighted=weighted, base=base)
             for lo, hi in bounds]
    cap = max(max(p[3] for p in parts), 1)
    srcb = np.full((d, cap), -1, np.int32)
    dstb = np.full((d, cap), -1, np.int32)
    wb = np.zeros((d, cap), np.float32)
    for k, (s, dd, ww, c) in enumerate(parts):
        srcb[k, :c] = s
        dstb[k, :c] = dd
        if weighted:
            wb[k, :c] = ww
    sharding = NamedSharding(mesh, P(axis))
    srcj = jax.device_put(srcb.reshape(d * cap), sharding)
    dstj = jax.device_put(dstb.reshape(d * cap), sharding)
    wj = jax.device_put(wb.reshape(d * cap), sharding) if weighted else None
    return load_csr_sharded(mesh, axis, srcj, dstj, wj,
                            num_vertices=num_vertices, rho=rho)
