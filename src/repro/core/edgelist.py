"""Edgelist readers: memory-mapped file -> in-memory EdgeList.

All engines are single-pass with over-allocated outputs (GVEL Alg. 1)
and live behind the :mod:`repro.core.loader` registry — prefer
``loader.load_edgelist(path, engine=...)``.  This module keeps the host
parser implementations plus back-compat wrappers:

* ``read_edgelist``        — thin wrapper over the loader's streaming
                             ``device`` engine (host prefetch thread
                             double-buffers staged blocks ahead of the
                             jitted block parser; batches accumulate in
                             a packed device buffer).
* ``read_edgelist_numpy``  — host engine: the numpy single-pass vectorized
                             parser over newline-aligned chunks.  Fastest
                             pure-CPU path; benchmark subject.
* ``read_edgelist_threads``— multithreaded host engine (GVEL's OpenMP loop).
* baselines live in :mod:`repro.core.baselines`.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import parse_np
from .types import EdgeList


def _file_bytes(path: str, offset: int) -> np.ndarray:
    """Uncompressed file bytes: a zero-copy mmap for raw files, an
    in-memory decompression for gzip/framed inputs (core.codecs)."""
    from .codecs import file_bytes
    return file_bytes(path, offset)


def symmetrize(el: EdgeList) -> EdgeList:
    """Append reverse edges (paper: symmetric graphs store each edge once)."""
    n = int(el.num_edges)
    src = np.concatenate([el.src[:n], el.dst[:n]])
    dst = np.concatenate([el.dst[:n], el.src[:n]])
    w = None if el.weights is None else np.concatenate([el.weights[:n]] * 2)
    return EdgeList(src, dst, w, np.int64(2 * n), el.num_vertices)


def read_edgelist(
    path: str,
    *,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    beta: int = 256 * 1024,
    overlap: int = 64,
    batch_blocks: int = 8,
) -> EdgeList:
    """Device engine (back-compat wrapper; see loader.load_edgelist)."""
    from .loader import load_edgelist
    return load_edgelist(path, engine="device", weighted=weighted,
                         symmetric=symmetric, base=base,
                         num_vertices=num_vertices, beta=beta,
                         overlap=overlap, batch_blocks=batch_blocks)


def read_edgelist_threads(
    path: str,
    *,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    offset: int = 0,
    num_workers: int = 8,
    chunks_per_worker: int = 4,
) -> EdgeList:
    """Multithreaded host engine (GVEL's OpenMP loop, faithfully).

    Chunks are newline-aligned and *smaller than the worker count*
    (chunks_per_worker x workers) so the pool load-balances like OpenMP
    dynamic scheduling — the fix for PIGO's equal-split straggler issue
    the paper calls out.  numpy releases the GIL inside its C kernels, so
    threads scale on real cores.
    """
    from concurrent.futures import ThreadPoolExecutor

    data = _file_bytes(path, offset)
    n_chunks = max(num_workers * chunks_per_worker,
                   len(data) // (256 * 1024))     # beta-sized: stay in L2
    bounds = parse_np.chunk_bounds(data, max(1, n_chunks))

    def work(b):
        lo, hi = b
        return parse_np.parse_chunk_np(np.asarray(data[lo:hi]),
                                       weighted=weighted, base=base)

    if num_workers == 1:
        parts = [work(b) for b in bounds]
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            parts = list(pool.map(work, bounds))
    src = (np.concatenate([p[0] for p in parts]) if parts
           else np.zeros(0, np.int64)).astype(np.int32)
    dst = (np.concatenate([p[1] for p in parts]) if parts
           else np.zeros(0, np.int64)).astype(np.int32)
    w = ((np.concatenate([p[2] for p in parts]) if parts
          else np.zeros(0)).astype(np.float32) if weighted else None)
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    el = EdgeList(src, dst, w, np.int64(len(src)), num_vertices)
    return symmetrize(el) if symmetric else el


def read_edgelist_numpy(
    path: str,
    *,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    offset: int = 0,
    chunk_bytes: int = 256 * 1024,
    num_chunks: Optional[int] = None,
) -> EdgeList:
    """Host engine: single-pass vectorized numpy parse over aligned chunks.

    chunk_bytes defaults to GVEL's beta = 256 KiB: on CPU the same block
    size that balanced the paper's OpenMP threads keeps the ~15
    vectorized passes resident in L2 — measured 2.7x over whole-file
    parsing on this host (see EXPERIMENTS.md fig2).
    """
    data = _file_bytes(path, offset)
    n = len(data)
    if num_chunks is None:
        num_chunks = max(1, -(-n // chunk_bytes))
    bounds = parse_np.chunk_bounds(data, num_chunks)
    srcs, dsts, ws = [], [], []
    total = 0
    for lo, hi in bounds:
        s, d, w, c = parse_np.parse_chunk_np(
            np.asarray(data[lo:hi]), weighted=weighted, base=base)
        srcs.append(s.astype(np.int32))
        dsts.append(d.astype(np.int32))
        if weighted:
            ws.append(w.astype(np.float32))
        total += c
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int32)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int32)
    w = (np.concatenate(ws) if ws else np.zeros(0, np.float32)) if weighted else None
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    el = EdgeList(src, dst, w, np.int64(total), num_vertices)
    return symmetrize(el) if symmetric else el
