"""Host-side block planning and staging (GVEL getBlock, TPU-adapted).

The file is cut into uniform beta-byte blocks.  Each block's device buffer
is `overlap + beta` bytes: `overlap` bytes of left context plus the owned
range.  Buffers are newline-padded at both file edges so the very first
byte of the file starts a line and the final line is always terminated —
the branch-free replacement for GVEL's newline repositioning.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

NEWLINE = 10


def mmap_bytes(path: str, offset: int = 0) -> np.ndarray:
    """Memory-map a file as uint8, optionally skipping a header prefix.

    GVEL maps the file and advises WILLNEED; np.memmap is the same
    mmap(2) under the hood, and the staging loops touch pages
    sequentially, which triggers kernel readahead (the madvise effect).
    Shared by the text staging pipeline, the host parsers, and the
    binary snapshot reader.
    """
    size = os.path.getsize(path)
    if size <= offset:
        return np.zeros(0, np.uint8)
    data = np.memmap(path, dtype=np.uint8, mode="r")
    return data[offset:] if offset else data


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    file_len: int
    beta: int          # owned bytes per block (GVEL: 256 KiB)
    overlap: int       # left context >= max line length
    num_blocks: int
    buf_len: int       # overlap + beta

    @property
    def edge_cap(self) -> int:
        # min parsable line is 4 bytes ("1 2\n"); +2 slack
        return self.buf_len // 4 + 2


def plan_blocks(file_len: int, beta: int = 256 * 1024, overlap: int = 64) -> BlockPlan:
    if beta <= overlap:
        raise ValueError(f"beta ({beta}) must exceed overlap ({overlap})")
    num_blocks = max(1, -(-file_len // beta))
    return BlockPlan(file_len, beta, overlap, num_blocks, overlap + beta)


def stage_blocks(data: np.ndarray, plan: BlockPlan, block_ids: np.ndarray) -> np.ndarray:
    """Gather block buffers (with left overlap) into an (nb, buf_len) array.

    ``data`` is the memory-mapped file bytes (uint8).  Out-of-file regions
    (before byte 0, after EOF) are filled with newlines.

    Consecutive block ids (the streaming loader's batches) take a fast
    path: one contiguous memcpy of the spanned byte range into a
    newline-padded flat buffer, then a zero-copy strided window per
    block — the per-block Python loop this replaces copied the overlap
    bytes twice and paid a numpy slice round-trip per block.
    """
    ids = np.asarray(block_ids, np.int64)
    nb = len(ids)
    n = plan.file_len
    if nb == 0:
        return np.zeros((0, plan.buf_len), np.uint8)
    if nb == 1 or np.all(np.diff(ids) == 1):
        lo = int(ids[0]) * plan.beta - plan.overlap        # may be < 0
        flat_len = (nb - 1) * plan.beta + plan.buf_len
        flat = np.full(flat_len, NEWLINE, np.uint8)
        s, e = max(lo, 0), min(lo + flat_len, n)
        if e > s:
            flat[s - lo : e - lo] = data[s:e]
        # rows alias (row r's overlap tail IS row r+1's head), so the view
        # is read-only; consumers copy into device buffers anyway
        return np.lib.stride_tricks.as_strided(
            flat, shape=(nb, plan.buf_len), strides=(plan.beta, 1),
            writeable=False)
    # general (non-contiguous) case: per-block slice copies
    out = np.full((nb, plan.buf_len), NEWLINE, np.uint8)
    for row, b in enumerate(ids):
        lo = int(b) * plan.beta - plan.overlap
        hi = int(b) * plan.beta + plan.beta
        s, e = max(lo, 0), min(hi, n)
        if e > s:
            out[row, s - lo : e - lo] = data[s:e]
    return out


def owned_range(plan: BlockPlan) -> tuple[int, int]:
    """Buffer-local [start, end) of the owned byte range (uniform per block)."""
    return plan.overlap, plan.overlap + plan.beta
