"""Host-side block planning and staging (GVEL getBlock, TPU-adapted).

The file is cut into uniform beta-byte blocks.  Each block's device buffer
is `overlap + beta` bytes: `overlap` bytes of left context plus the owned
range.  Buffers are newline-padded at both file edges so the very first
byte of the file starts a line and the final line is always terminated —
the branch-free replacement for GVEL's newline repositioning.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

NEWLINE = 10


def mmap_bytes(path: str, offset: int = 0) -> np.ndarray:
    """Memory-map a file as uint8, optionally skipping a header prefix.

    GVEL maps the file and advises WILLNEED; np.memmap is the same
    mmap(2) under the hood, and the staging loops touch pages
    sequentially, which triggers kernel readahead (the madvise effect).
    Shared by the text staging pipeline, the host parsers, and the
    binary snapshot reader.
    """
    size = os.path.getsize(path)
    if size <= offset:
        return np.zeros(0, np.uint8)
    data = np.memmap(path, dtype=np.uint8, mode="r")
    return data[offset:] if offset else data


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    file_len: int
    beta: int          # owned bytes per block (GVEL: 256 KiB)
    overlap: int       # left context >= max line length
    num_blocks: int
    buf_len: int       # overlap + beta

    @property
    def edge_cap(self) -> int:
        # min parsable line is 4 bytes ("1 2\n"); +2 slack
        return self.buf_len // 4 + 2


def plan_blocks(file_len: int, beta: int = 256 * 1024, overlap: int = 64) -> BlockPlan:
    if beta <= overlap:
        raise ValueError(f"beta ({beta}) must exceed overlap ({overlap})")
    num_blocks = max(1, -(-file_len // beta))
    return BlockPlan(file_len, beta, overlap, num_blocks, overlap + beta)


def _newline_flat(nb: int, plan: BlockPlan) -> np.ndarray:
    """Newline-filled flat buffer spanning ``nb`` consecutive blocks
    (one block's owned bytes per stride step, plus the final overlap)."""
    return np.full((nb - 1) * plan.beta + plan.buf_len, NEWLINE, np.uint8)


def _strided_block_view(flat: np.ndarray, nb: int, plan: BlockPlan) -> np.ndarray:
    """Zero-copy per-block windows over a flat span.  Rows alias (row
    r's overlap tail IS row r+1's head), so the view is read-only;
    consumers copy into device buffers anyway."""
    return np.lib.stride_tricks.as_strided(
        flat, shape=(nb, plan.buf_len), strides=(plan.beta, 1),
        writeable=False)


def stage_blocks(data: np.ndarray, plan: BlockPlan, block_ids: np.ndarray) -> np.ndarray:
    """Gather block buffers (with left overlap) into an (nb, buf_len) array.

    ``data`` is the memory-mapped file bytes (uint8).  Out-of-file regions
    (before byte 0, after EOF) are filled with newlines.

    Consecutive block ids (the streaming loader's batches) take a fast
    path: one contiguous memcpy of the spanned byte range into a
    newline-padded flat buffer, then a zero-copy strided window per
    block — the per-block Python loop this replaces copied the overlap
    bytes twice and paid a numpy slice round-trip per block.
    """
    ids = np.asarray(block_ids, np.int64)
    nb = len(ids)
    n = plan.file_len
    if nb == 0:
        return np.zeros((0, plan.buf_len), np.uint8)
    if nb == 1 or np.all(np.diff(ids) == 1):
        lo = int(ids[0]) * plan.beta - plan.overlap        # may be < 0
        flat = _newline_flat(nb, plan)
        s, e = max(lo, 0), min(lo + len(flat), n)
        if e > s:
            flat[s - lo : e - lo] = data[s:e]
        return _strided_block_view(flat, nb, plan)
    # general (non-contiguous) case: per-block slice copies
    out = np.full((nb, plan.buf_len), NEWLINE, np.uint8)
    for row, b in enumerate(ids):
        lo = int(b) * plan.beta - plan.overlap
        hi = int(b) * plan.beta + plan.beta
        s, e = max(lo, 0), min(hi, n)
        if e > s:
            out[row, s - lo : e - lo] = data[s:e]
    return out


def owned_range(plan: BlockPlan) -> tuple[int, int]:
    """Buffer-local [start, end) of the owned byte range (uniform per block)."""
    return plan.overlap, plan.overlap + plan.beta


# ---------------------------------------------------------------------------
# block sources: where staged block bytes come from
# ---------------------------------------------------------------------------
#
# The streaming loader used to stage straight off an mmap; compressed
# inputs (core.codecs) need the same staging over bytes that only exist
# after decompression.  A block source answers "give me the staged
# buffers for these block ids" — random-access over memory, or
# sequentially over a stream of decompressed chunks.  The loader's
# prefetch thread drives `stage`, so for stream sources decompression
# runs in that thread and overlaps the device parse.

class MemoryBlockSource:
    """Random-access staging over in-memory (usually mmap'd) bytes."""

    def __init__(self, data: np.ndarray):
        self.data = data
        self.length = len(data)

    def stage(self, plan: BlockPlan, block_ids: np.ndarray) -> np.ndarray:
        return stage_blocks(self.data, plan, block_ids)

    def finish(self) -> None:
        pass


class SequentialBlockSource:
    """Staging over a forward-only stream of byte chunks.

    ``chunks`` yields successive spans of the uncompressed byte stream
    (any sizes, including empty); ``length`` is the total expected after
    dropping the first ``skip`` bytes (an embedded-header offset, in
    uncompressed coordinates).  Batches must be consumed in order with
    contiguous ascending block ids — exactly how the streaming loader
    iterates — and only ``overlap`` bytes of tail context are retained
    between batches, so memory stays O(batch) regardless of file size.

    ``finish`` drains the stream and verifies the total produced length
    against ``length``: a stream that is shorter or longer than declared
    (truncated file, lying gzip trailer) raises ``ValueError`` instead
    of returning a silently partial graph.
    """

    def __init__(self, chunks, length: int, *, skip: int = 0,
                 describe: str = "byte stream", mismatch_hint: str = ""):
        self._chunks = iter(chunks)
        self.length = max(int(length), 0)
        self._to_skip = skip
        self._describe = describe
        self._hint = mismatch_hint
        self._buf = bytearray()
        self._buf_start = 0            # stream offset of _buf[0] (post-skip)
        self._produced = 0             # post-skip bytes pulled so far
        self._next_block = 0

    def _pull(self) -> bool:
        chunk = next(self._chunks, None)
        if chunk is None:
            return False
        if self._to_skip:
            drop = min(self._to_skip, len(chunk))
            self._to_skip -= drop
            chunk = chunk[drop:]
        self._buf += chunk
        self._produced += len(chunk)
        return True

    def stage(self, plan: BlockPlan, block_ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(block_ids, np.int64)
        nb = len(ids)
        if nb == 0:
            return np.zeros((0, plan.buf_len), np.uint8)
        if (nb > 1 and not np.all(np.diff(ids) == 1)) or \
                int(ids[0]) != self._next_block:
            raise ValueError(
                f"{self._describe}: sequential source staged out of order "
                f"(got blocks {ids[0]}..{ids[-1]}, expected "
                f"{self._next_block}..)")
        self._next_block = int(ids[-1]) + 1
        lo = int(ids[0]) * plan.beta - plan.overlap          # may be < 0
        hi = min((int(ids[-1]) + 1) * plan.beta, self.length)
        while self._buf_start + len(self._buf) < hi:
            if not self._pull():
                break                 # short stream: pad now, finish() raises
        flat = _newline_flat(nb, plan)
        s = max(lo, 0)
        e = min(hi, self._buf_start + len(self._buf))
        if e > s:
            off = s - self._buf_start
            flat[s - lo : e - lo] = np.frombuffer(
                self._buf, np.uint8, count=e - s, offset=off)
        keep_from = max((int(ids[-1]) + 1) * plan.beta - plan.overlap, 0)
        if keep_from > self._buf_start:
            del self._buf[:keep_from - self._buf_start]
            self._buf_start = keep_from
        return _strided_block_view(flat, nb, plan)

    def finish(self) -> None:
        while self._pull():
            pass
        if self._produced != self.length:
            raise ValueError(
                f"{self._describe}: stream decompressed to "
                f"{self._produced} bytes after the header offset, expected "
                f"{self.length}{self._hint}")
