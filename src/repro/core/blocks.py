"""Host-side block planning and staging (GVEL getBlock, TPU-adapted).

The file is cut into uniform beta-byte blocks.  Each block's device buffer
is `overlap + beta` bytes: `overlap` bytes of left context plus the owned
range.  Buffers are newline-padded at both file edges so the very first
byte of the file starts a line and the final line is always terminated —
the branch-free replacement for GVEL's newline repositioning.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

NEWLINE = 10


def mmap_bytes(path: str, offset: int = 0) -> np.ndarray:
    """Memory-map a file as uint8, optionally skipping a header prefix.

    GVEL maps the file and advises WILLNEED; np.memmap is the same
    mmap(2) under the hood, and the staging loops touch pages
    sequentially, which triggers kernel readahead (the madvise effect).
    Shared by the text staging pipeline, the host parsers, and the
    binary snapshot reader.
    """
    from . import faults
    if faults._ACTIVE is not None:          # chaos hook; no-op otherwise
        faults.inject("mmap", 0, where=path)
    size = os.path.getsize(path)
    if size <= offset:
        return np.zeros(0, np.uint8)
    data = np.memmap(path, dtype=np.uint8, mode="r")
    return data[offset:] if offset else data


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    file_len: int
    beta: int          # owned bytes per block (GVEL: 256 KiB)
    overlap: int       # left context >= max line length
    num_blocks: int
    buf_len: int       # overlap + beta

    @property
    def edge_cap(self) -> int:
        # min parsable line is 4 bytes ("1 2\n"); +2 slack
        return self.buf_len // 4 + 2


def plan_blocks(file_len: int, beta: int = 256 * 1024, overlap: int = 64) -> BlockPlan:
    if beta <= overlap:
        raise ValueError(f"beta ({beta}) must exceed overlap ({overlap})")
    num_blocks = max(1, -(-file_len // beta))
    return BlockPlan(file_len, beta, overlap, num_blocks, overlap + beta)


def flat_len(nb: int, plan: BlockPlan) -> int:
    """Bytes of flat staging needed for ``nb`` consecutive blocks (one
    block's owned bytes per stride step, plus the final overlap)."""
    return (nb - 1) * plan.beta + plan.buf_len


class StagingArena:
    """A ring of reusable flat staging buffers for the streaming loader.

    Without an arena every staged batch allocates a fresh flat buffer
    (and the allocator pays a page-fault walk over it).  The loader
    instead creates one arena per stream and passes it to every
    ``stage`` call; the per-batch host cost drops to a single memcpy of
    the new bytes.

    Ring discipline (why ``slots=2`` is safe): the loader double-buffers
    — batch *i* is converted to a device array in the consuming thread
    while batch *i+1* stages in the prefetch thread, so two buffers are
    live at once.  A slot is only reused at batch *i+2*, which the
    prefetch thread starts *after* the consumer finished with batch *i*
    (``jnp.asarray`` of the strided view makes its contiguous copy
    before the consumer submits more staging work).  Consumers that
    hold staged views longer must pass more ``slots`` or copy.

    Buffers are handed out dirty; the staging code newline-fills only
    the head/tail slack it does not overwrite with file bytes.
    """

    def __init__(self, nbytes: int, slots: int = 2):
        self._slots = [np.full(max(int(nbytes), 1), NEWLINE, np.uint8)
                       for _ in range(max(int(slots), 2))]
        self._turn = 0

    def take(self, nbytes: int) -> np.ndarray:
        """Next ring buffer, grown if needed; contents are stale."""
        i = self._turn
        self._turn = (self._turn + 1) % len(self._slots)
        if self._slots[i].size < nbytes:
            self._slots[i] = np.full(nbytes, NEWLINE, np.uint8)
        return self._slots[i][:nbytes]


def _take_flat(nb: int, plan: BlockPlan, arena: StagingArena | None,
               filled_lo: int, filled_hi: int) -> np.ndarray:
    """Flat staging buffer for ``nb`` blocks; everything outside
    ``[filled_lo, filled_hi)`` (which the caller will overwrite with
    file bytes) is newline-filled."""
    need = flat_len(nb, plan)
    if arena is None:
        return np.full(need, NEWLINE, np.uint8)
    flat = arena.take(need)
    lo = max(min(filled_lo, need), 0)
    hi = max(min(filled_hi, need), lo)
    if lo:
        flat[:lo] = NEWLINE
    if hi < need:
        flat[hi:] = NEWLINE
    return flat


def _strided_block_view(flat: np.ndarray, nb: int, plan: BlockPlan) -> np.ndarray:
    """Zero-copy per-block windows over a flat span.  Rows alias (row
    r's overlap tail IS row r+1's head), so the view is read-only;
    consumers copy into device buffers anyway."""
    return np.lib.stride_tricks.as_strided(
        flat, shape=(nb, plan.buf_len), strides=(plan.beta, 1),
        writeable=False)


def check_line_overlap(view: np.ndarray, plan: BlockPlan,
                       ids: np.ndarray, data_len: int,
                       describe: str = "staged blocks") -> None:
    """Detect lines longer than ``plan.overlap`` crossing a block's owned
    start — the one staging geometry the parser cannot recover from.

    The parse contract says no line may exceed ``overlap`` bytes; when a
    longer line spans a block boundary its head lies before the owning
    block's buffer and the parser would silently mis-parse the truncated
    tail (a too-long comment whose tail looks like digits becomes a
    phantom edge).  For in-contract inputs every ``overlap``-wide window
    of file bytes contains a newline, so this check never fires on them:
    a block whose left-context window ``[b*beta - overlap, b*beta)`` has
    *no* newline proves a violating line and raises, naming the byte
    offset.  Block 0 is exempt (its left context is synthetic padding),
    as are windows past EOF (newline-padded).
    """
    ids = np.asarray(ids, np.int64)
    if len(ids) == 0:
        return
    need = (ids > 0) & (ids * plan.beta < data_len)
    if not need.any():
        return
    ok = (view[:, :plan.overlap] == NEWLINE).any(axis=1)
    bad = need & ~ok
    if bad.any():
        b = int(ids[int(np.argmax(bad))])
        off = b * plan.beta
        raise ValueError(
            f"{describe}: no newline within overlap={plan.overlap} bytes "
            f"before byte offset {off} (block {b}'s owned start) — a line "
            f"longer than {plan.overlap} bytes crosses the block boundary "
            f"there and would be mis-parsed.  Re-run with a larger "
            f"overlap= (it must exceed the longest line, including "
            f"comments), or strip overlong lines; offsets are relative to "
            f"any header offset skipped at open.")


def stage_blocks(data: np.ndarray, plan: BlockPlan, block_ids: np.ndarray,
                 arena: StagingArena | None = None,
                 check_lines: bool = False) -> np.ndarray:
    """Gather block buffers (with left overlap) into an (nb, buf_len) array.

    ``data`` is the memory-mapped file bytes (uint8).  Out-of-file regions
    (before byte 0, after EOF) are filled with newlines.

    Consecutive block ids (the streaming loader's batches) take a fast
    path: one contiguous memcpy of the spanned byte range into a
    newline-padded flat buffer, then a zero-copy strided window per
    block — the per-block Python loop this replaces copied the overlap
    bytes twice and paid a numpy slice round-trip per block.  Passing an
    ``arena`` reuses its ring buffers instead of allocating per batch
    (see :class:`StagingArena` for the reuse discipline).

    ``check_lines=True`` (the text-parse pipelines set it; raw byte
    staging does not) raises ``ValueError`` when a line longer than
    ``plan.overlap`` bytes crosses a block's owned start
    (:func:`check_line_overlap`).
    """
    ids = np.asarray(block_ids, np.int64)
    nb = len(ids)
    n = plan.file_len
    if nb == 0:
        return np.zeros((0, plan.buf_len), np.uint8)
    if nb == 1 or np.all(np.diff(ids) == 1):
        lo = int(ids[0]) * plan.beta - plan.overlap        # may be < 0
        s = max(lo, 0)
        e = min(lo + flat_len(nb, plan), n)
        flat = _take_flat(nb, plan, arena, s - lo, e - lo)
        if e > s:
            flat[s - lo : e - lo] = data[s:e]
        view = _strided_block_view(flat, nb, plan)
    else:
        # general (non-contiguous) case: per-block slice copies
        view = np.full((nb, plan.buf_len), NEWLINE, np.uint8)
        for row, b in enumerate(ids):
            lo = int(b) * plan.beta - plan.overlap
            hi = int(b) * plan.beta + plan.beta
            s, e = max(lo, 0), min(hi, n)
            if e > s:
                view[row, s - lo : e - lo] = data[s:e]
    if check_lines:
        check_line_overlap(view, plan, ids, n)
    return view


def owned_range(plan: BlockPlan) -> tuple[int, int]:
    """Buffer-local [start, end) of the owned byte range (uniform per block)."""
    return plan.overlap, plan.overlap + plan.beta


@dataclasses.dataclass(frozen=True)
class ShardSpan:
    """Shard ``shard``-of-``num_shards``'s contiguous slice of a BlockPlan.

    The split is **block-aligned**, which is what makes it safe: a line
    is owned by the block containing its terminating newline, and a
    block's left context comes from its own staged ``overlap`` bytes —
    so any contiguous block range parses exactly the lines it owns, with
    no coordination with neighbouring shards.  For framed codecs the
    plan's beta is already forced to ``frame_beta``, so a block-aligned
    split is frame-aligned for free.
    """

    plan: BlockPlan
    shard: int
    num_shards: int
    block_lo: int      # first owned block (inclusive)
    block_hi: int      # past-the-end block; == block_lo for an empty span

    @property
    def num_blocks(self) -> int:
        return self.block_hi - self.block_lo

    @property
    def byte_lo(self) -> int:
        """First owned file byte (post-header coordinates)."""
        return min(self.block_lo * self.plan.beta, self.plan.file_len)

    @property
    def byte_hi(self) -> int:
        """Past-the-end owned file byte."""
        return min(self.block_hi * self.plan.beta, self.plan.file_len)

    @property
    def edge_cap(self) -> int:
        """Accumulator slots this span needs (over-allocation bound)."""
        return self.num_blocks * self.plan.edge_cap


def shard_plan(plan: BlockPlan, k: int, d: int) -> ShardSpan:
    """Partition ``plan``'s blocks into ``d`` contiguous byte-range spans
    and return shard ``k``'s.

    Spans are balanced to within one block, ordered (shard k's bytes all
    precede shard k+1's — the exchange stage relies on this to keep
    received edges in global file order), disjoint, and jointly cover
    every block.  When the mesh is wider than the plan (``d`` >
    ``num_blocks``) the excess shards get empty spans, which the sharded
    loader must — and does — handle: their accumulators simply stay
    empty.
    """
    if d < 1:
        raise ValueError(f"num_shards must be >= 1, got {d}")
    if not 0 <= k < d:
        raise ValueError(f"shard index {k} outside [0, {d})")
    nb = plan.num_blocks
    return ShardSpan(plan, k, d, (k * nb) // d, ((k + 1) * nb) // d)


# ---------------------------------------------------------------------------
# block sources: where staged block bytes come from
# ---------------------------------------------------------------------------
#
# The streaming loader used to stage straight off an mmap; compressed
# inputs (core.codecs) need the same staging over bytes that only exist
# after decompression.  A block source answers "give me the staged
# buffers for these block ids" — random-access over memory, or
# sequentially over a stream of decompressed chunks.  The loader's
# prefetch thread drives `stage`, so for stream sources decompression
# runs in that thread and overlaps the device parse.

class MemoryBlockSource:
    """Random-access staging over in-memory (usually mmap'd) bytes."""

    def __init__(self, data: np.ndarray):
        self.data = data
        self.length = len(data)

    def stage(self, plan: BlockPlan, block_ids: np.ndarray,
              arena: StagingArena | None = None,
              check_lines: bool = False) -> np.ndarray:
        return stage_blocks(self.data, plan, block_ids, arena, check_lines)

    def finish(self) -> None:
        pass


class SequentialBlockSource:
    """Staging over a forward-only stream of byte chunks.

    ``chunks`` yields successive spans of the uncompressed byte stream
    (any sizes, including empty); ``length`` is the total expected after
    dropping the first ``skip`` bytes (an embedded-header offset, in
    uncompressed coordinates).  Batches must be consumed in order with
    contiguous ascending block ids — exactly how the streaming loader
    iterates.

    Pending bytes are held as a queue of zero-copy chunk views with a
    running stream offset: staging copies each overlapping chunk span
    straight into the flat batch buffer (one memcpy per chunk) and
    retains only the unconsumed tail views for the next batch's overlap
    — memory stays O(batch), and there is no per-batch compaction of a
    growing buffer (the old ``bytearray`` design paid an O(buffered)
    memmove per batch to delete its consumed prefix).

    A source may cover only a *span* of the logical stream — the sharded
    loader gives each mesh shard its own source over its byte range:
    ``start`` is the post-skip stream position of the first chunk byte
    (the chunks iterator must begin there — e.g. a frame-sliced framed
    reader), ``end`` is the past-the-end position this source must cover,
    and ``first_block`` is the first block id ``stage`` will be asked
    for.  ``start`` must not exceed ``first_block * beta - overlap`` (the
    leftmost byte the first staged batch needs); block-aligned spans with
    a one-block (or one-frame) left margin satisfy this because
    ``beta > overlap``.

    ``finish`` verifies coverage: a source whose span reaches the stream
    end (``end == length``) drains the remainder and demands the exact
    declared total (truncated file, lying gzip trailer); a mid-stream
    span only demands that the stream reached ``end`` — either way a
    short stream raises ``ValueError`` instead of returning a silently
    partial graph.
    """

    def __init__(self, chunks, length: int, *, skip: int = 0,
                 start: int = 0, end: int | None = None,
                 first_block: int = 0,
                 describe: str = "byte stream", mismatch_hint: str = ""):
        self._chunks = iter(chunks)
        self.length = max(int(length), 0)
        self._to_skip = skip
        self._start = min(max(int(start), 0), self.length)
        self._end = self.length if end is None else \
            min(max(int(end), self._start), self.length)
        self._describe = describe
        self._hint = mismatch_hint
        self._q: list[np.ndarray] = []     # pending chunk views, in order
        self._q_start = self._start    # stream offset of _q[0][0] (post-skip)
        self._q_len = 0                # total bytes queued
        self._produced = 0             # post-skip bytes pulled so far
        self._next_block = int(first_block)

    def _pull(self) -> bool:
        chunk = next(self._chunks, None)
        if chunk is None:
            return False
        if self._to_skip:
            drop = min(self._to_skip, len(chunk))
            self._to_skip -= drop
            chunk = chunk[drop:]
        self._produced += len(chunk)
        if len(chunk):
            view = np.frombuffer(chunk, np.uint8)
            self._q.append(view)
            self._q_len += len(view)
        return True

    def stage(self, plan: BlockPlan, block_ids: np.ndarray,
              arena: StagingArena | None = None,
              check_lines: bool = False) -> np.ndarray:
        ids = np.asarray(block_ids, np.int64)
        nb = len(ids)
        if nb == 0:
            return np.zeros((0, plan.buf_len), np.uint8)
        if (nb > 1 and not np.all(np.diff(ids) == 1)) or \
                int(ids[0]) != self._next_block:
            raise ValueError(
                f"{self._describe}: sequential source staged out of order "
                f"(got blocks {ids[0]}..{ids[-1]}, expected "
                f"{self._next_block}..)")
        self._next_block = int(ids[-1]) + 1
        lo = int(ids[0]) * plan.beta - plan.overlap          # may be < 0
        hi = min((int(ids[-1]) + 1) * plan.beta, self.length)
        while self._q_start + self._q_len < hi:
            if not self._pull():
                break                 # short stream: pad now, finish() raises
        s = max(lo, 0)
        e = min(hi, self._q_start + self._q_len)
        flat = _take_flat(nb, plan, arena, s - lo, e - lo)
        pos = self._q_start           # walk the queue once, copying spans
        for view in self._q:
            if pos >= e:
                break
            c0, c1 = max(s - pos, 0), min(e - pos, len(view))
            if c1 > c0:
                flat[pos + c0 - lo : pos + c1 - lo] = view[c0:c1]
            pos += len(view)
        # retain only the tail the next batch's overlap needs (views,
        # not copies); whole chunks before it are dropped
        keep_from = max((int(ids[-1]) + 1) * plan.beta - plan.overlap,
                        self._q_start)
        while self._q and self._q_start + len(self._q[0]) <= keep_from:
            dropped = self._q.pop(0)
            self._q_start += len(dropped)
            self._q_len -= len(dropped)
        if self._q and keep_from > self._q_start:
            cut = keep_from - self._q_start
            self._q[0] = self._q[0][cut:]
            self._q_start = keep_from
            self._q_len -= cut
        out = _strided_block_view(flat, nb, plan)
        if check_lines:
            check_line_overlap(out, plan, ids, self.length, self._describe)
        return out

    def finish(self) -> None:
        need = self._end - self._start
        if self._end >= self.length:
            # span reaches the stream end: drain and demand the exact total
            while self._pull():
                self._q.clear()       # drained bytes are only counted
                self._q_len = 0
            if self._produced != need:
                raise ValueError(
                    f"{self._describe}: stream decompressed to "
                    f"{self._start + self._produced} bytes after the header "
                    f"offset, expected {self.length}{self._hint}")
        else:
            # mid-stream span: only demand that the stream covered it
            while self._produced < need and self._pull():
                self._q.clear()
                self._q_len = 0
            if self._produced < need:
                raise ValueError(
                    f"{self._describe}: stream ended at byte "
                    f"{self._start + self._produced} (after the header "
                    f"offset), before this shard span's end at "
                    f"{self._end}{self._hint}")
