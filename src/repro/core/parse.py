"""Vectorized edgelist parsing (the TPU adaptation of GVEL Algorithm 1).

GVEL's CPU hot loop walks bytes with a pointer and custom digit parsers.
On a vector machine the same work is mask/scan algebra over a whole block:

  1. classify every byte at once (digit / dot / minus / newline / space),
  2. form *token* segments (maximal runs of number chars) and *line*
     segments (split at newlines) from cumulative sums,
  3. combine digits into values with segment reductions
     (value = sum digit_i * 10^(#digits after i in the token)),
  4. assemble (src, dst, weight) per line and compact valid, *owned*
     lines into a fixed-capacity edge buffer (GVEL's over-allocation:
     capacity is a bytes-derived upper bound, untouched tail stays padding).

Block-boundary handling replaces GVEL's getBlock() pointer repositioning
with uniform tiles + a left overlap + an ownership mask: every block buffer
carries `overlap` bytes of left context, and a line belongs to the block
whose *owned byte range* contains the line's terminating newline.  This is
branch-free and identical for every block, so one jitted program serves all.

Limits (documented): vertex ids must have <= 9 decimal digits (int32 math;
covers every graph in the paper, max |V| = 214M), weights are plain
decimals (no exponent notation), and no line may exceed `overlap` bytes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

I32 = jnp.int32

# byte classes
_NL, _CR, _SP, _TAB, _DOT, _MINUS = 10, 13, 32, 9, 46, 45


def _scatter_set(cap: int, select, index, values, fill, dtype):
    """out[index[i]] = values[i] where select[i]; OOB indices dropped."""
    out = jnp.full((cap,), fill, dtype)
    idx = jnp.where(select, index, cap)
    return out.at[idx].set(values.astype(dtype), mode="drop")


def _scatter_add(cap: int, select, index, values, dtype):
    out = jnp.zeros((cap,), dtype)
    idx = jnp.where(select, index, cap)
    return out.at[idx].add(values.astype(dtype), mode="drop")


@functools.partial(
    jax.jit,
    static_argnames=("weighted", "base", "edge_cap", "max_digits"),
)
def parse_block(
    buf: jax.Array,
    owned_start: jax.Array,
    owned_end: jax.Array,
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array]:
    """Parse one byte block into fixed-capacity (src, dst, w, count).

    buf:  (n,) uint8, newline-padded.  A line is *owned* iff the index of
    its terminating newline lies in [owned_start, owned_end).
    Returns int32 src/dst (padded with -1), float32 w or None, int32 count.
    """
    n = buf.shape[0]
    tok_cap = n // 2 + 2
    line_cap = n + 1

    d = buf.astype(I32)
    idx = jnp.arange(n, dtype=I32)

    is_digit = (d >= 48) & (d <= 57)
    is_dot = d == _DOT
    is_minus = d == _MINUS
    is_tok = is_digit | is_dot | is_minus
    is_nl = d == _NL
    is_ws = (d == _SP) | (d == _TAB) | (d == _CR)
    is_bad = ~(is_tok | is_nl | is_ws)

    # ---- token segmentation -------------------------------------------------
    prev_tok = jnp.concatenate([jnp.zeros((1,), bool), is_tok[:-1]])
    tok_start = is_tok & ~prev_tok
    tok_ord = jnp.cumsum(tok_start.astype(I32)) - 1      # token id at/under i
    num_toks = jnp.maximum(tok_ord[-1] + 1, 0)

    # line index of every byte = #newlines strictly before it
    line_of = jnp.cumsum(is_nl.astype(I32)) - is_nl.astype(I32)

    # per-token quantities (scatter at token starts / ends)
    next_tok = jnp.concatenate([is_tok[1:], jnp.zeros((1,), bool)])
    tok_end = is_tok & ~next_tok
    tok_line = _scatter_set(tok_cap, tok_start, tok_ord,
                            line_of, line_cap, I32)      # line of each token
    cum_dig = jnp.cumsum(is_digit.astype(I32))           # inclusive global
    dig_before_tok = _scatter_set(tok_cap, tok_start, tok_ord,
                                  cum_dig - is_digit.astype(I32), 0, I32)

    # digits strictly after i within the same token
    tok_total_dig = _scatter_add(tok_cap, is_tok, tok_ord, is_digit, I32)
    dig_incl = cum_dig - dig_before_tok[jnp.clip(tok_ord, 0, tok_cap - 1)]
    digits_after = jnp.clip(tok_total_dig[jnp.clip(tok_ord, 0, tok_cap - 1)]
                            - dig_incl, 0, max_digits)

    # fractional digits: dot position per token
    tok_dot_idx = _scatter_set(tok_cap, is_tok & is_dot, tok_ord, idx, -1, I32)
    tok_has_dot = tok_dot_idx >= 0
    dot_of = tok_dot_idx[jnp.clip(tok_ord, 0, tok_cap - 1)]
    is_frac_digit = is_digit & (dot_of >= 0) & (idx > dot_of)
    tok_frac_len = _scatter_add(tok_cap, is_tok, tok_ord, is_frac_digit, I32)
    tok_neg = _scatter_add(tok_cap, is_tok, tok_ord, is_minus, I32) > 0

    # integer value over *all* digits of the token ("3.25" -> 325), but the
    # place of a digit counts only digit chars after it, so the dot is inert.
    digit_val = jnp.where(is_digit, d - 48, 0)
    pow10_i = (10 ** jnp.arange(max_digits + 1, dtype=I32))
    contrib_i = digit_val * pow10_i[digits_after]
    tok_int = _scatter_add(tok_cap, is_digit & is_tok, tok_ord, contrib_i, I32)

    if weighted:
        pow10_f = jnp.float32(10.0) ** jnp.arange(max_digits + 1)
        contrib_f = digit_val.astype(jnp.float32) * pow10_f[digits_after]
        tok_allf = _scatter_add(tok_cap, is_digit & is_tok, tok_ord, contrib_f,
                                jnp.float32)
        tok_float = tok_allf / pow10_f[jnp.clip(tok_frac_len, 0, max_digits)]
        tok_float = jnp.where(tok_neg, -tok_float, tok_float)
        del tok_has_dot

    # ---- line assembly ------------------------------------------------------
    t_arange = jnp.arange(tok_cap, dtype=I32)
    tok_valid = t_arange < num_toks
    tl = jnp.where(tok_valid, tok_line, line_cap)
    first_tok_of_line = jnp.full((line_cap + 1,), tok_cap, I32) \
        .at[jnp.where(tok_valid, tl, line_cap)].min(t_arange, mode="drop")[:-1]
    ord_in_line = t_arange - first_tok_of_line[jnp.clip(tl, 0, line_cap - 1)]

    ntok_line = _scatter_add(line_cap, tok_valid, tl, jnp.ones_like(t_arange), I32)
    bad_line = _scatter_add(line_cap, is_bad, line_of,
                            jnp.ones_like(idx), I32) > 0
    term_idx = _scatter_set(line_cap, is_nl, line_of, idx, -1, I32)

    def line_val(role, values, fill, dtype):
        sel = tok_valid & (ord_in_line == role)
        return _scatter_set(line_cap, sel, tl, values, fill, dtype)

    src_l = line_val(0, tok_int, -1, I32)
    dst_l = line_val(1, tok_int, -1, I32)
    if weighted:
        w_l = line_val(2, tok_float, 1.0, jnp.float32)   # missing weight -> 1
        has_w = line_val(2, jnp.ones_like(t_arange), 0, I32) > 0
        w_l = jnp.where(has_w, w_l, 1.0)

    owned = (term_idx >= owned_start) & (term_idx < owned_end)
    valid = owned & ~bad_line & (ntok_line >= 2)

    # ---- compaction (GVEL over-allocation: fixed capacity + count) ----------
    pos = jnp.cumsum(valid.astype(I32)) - 1
    count = jnp.maximum(pos[-1] + 1, 0)
    src = _scatter_set(edge_cap, valid, pos, src_l - base, -1, I32)
    dst = _scatter_set(edge_cap, valid, pos, dst_l - base, -1, I32)
    w = _scatter_set(edge_cap, valid, pos, w_l, 0.0, jnp.float32) if weighted else None
    return src, dst, w, count


@functools.partial(
    jax.jit, static_argnames=("weighted", "base", "edge_cap", "max_digits")
)
def parse_blocks(
    bufs: jax.Array,
    owned_start: jax.Array,
    owned_end: jax.Array,
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
):
    """vmap of parse_block over a batch of equally-sized blocks."""
    fn = functools.partial(parse_block, weighted=weighted, base=base,
                           edge_cap=edge_cap, max_digits=max_digits)
    return jax.vmap(fn)(bufs, owned_start, owned_end)


def compact_edges(src_b, dst_b, w_b, counts, total_cap: int):
    """Concatenate per-block fixed-capacity outputs into one packed buffer.

    The device-side analogue of gluing per-thread edgelists: an exclusive
    scan over per-block counts gives every block a disjoint write range.
    """
    nb, cap = src_b.shape
    starts = jnp.cumsum(counts) - counts
    within = jnp.arange(cap, dtype=I32)[None, :]
    valid = within < counts[:, None]
    dest = jnp.where(valid, starts[:, None] + within, total_cap)
    dest = dest.reshape(-1)
    out_src = jnp.full((total_cap,), -1, I32).at[dest].set(src_b.reshape(-1), mode="drop")
    out_dst = jnp.full((total_cap,), -1, I32).at[dest].set(dst_b.reshape(-1), mode="drop")
    out_w = None
    if w_b is not None:
        out_w = jnp.zeros((total_cap,), jnp.float32).at[dest].set(w_b.reshape(-1), mode="drop")
    return out_src, out_dst, out_w, jnp.sum(counts)
