"""Vectorized edgelist parsing (the TPU adaptation of GVEL Algorithm 1).

GVEL's CPU hot loop walks bytes with a pointer and custom digit parsers.
On a vector machine the same work is mask/scan algebra over a whole block:

  1. classify every byte at once (digit / dot / minus / newline / space),
  2. form *token* segments (maximal runs of number chars) and *line*
     segments (split at newlines) from cumulative sums,
  3. combine digits into values with segment reductions
     (value = sum digit_i * 10^(#digits after i in the token)),
  4. assemble (src, dst, weight) per line and compact valid, *owned*
     lines into a fixed-capacity edge buffer (GVEL's over-allocation:
     capacity is a bytes-derived upper bound, untouched tail stays padding).

Block-boundary handling replaces GVEL's getBlock() pointer repositioning
with uniform tiles + a left overlap + an ownership mask: every block buffer
carries `overlap` bytes of left context, and a line belongs to the block
whose *owned byte range* contains the line's terminating newline.  This is
branch-free and identical for every block, so one jitted program serves all.

One per-byte core, :func:`_parse_block_bytes`, carries that algebra in
*sorted-segment* form: token/line ids increase with byte position, so
every per-token and per-line quantity is a cumulative max/sum plus a
gather instead of a scatter — on CPU XLA a scatter runs ~5M elem/s
while cumsum/gather run 20-100M elem/s.  Two entry points wrap it:

* :func:`parse_block` / :func:`parse_blocks` — block in, fixed-capacity
  per-block ``(src, dst, w, count)`` out (one compaction scatter per
  block).  The standalone parser: unit tests, the Pallas kernel's XLA
  reference, and the historical batch pipeline all consume it.
* :func:`parse_accumulate` — the streaming loader's fused hot path: a
  whole batch of blocks in, edges packed **directly into the packed
  device accumulators** (donated, so the update is in-place where the
  backend supports buffer donation — see :func:`donation_supported`).
  The per-block ``(nb, edge_cap)`` intermediates of the two-step
  parse-then-accumulate pipeline never materialize; the batch-wide
  compaction (:func:`_compact_accumulate`) costs exactly one scatter
  per batch, which is where the streaming engine's speedup over the
  batch round-trip lives.  The Pallas engine shares the same
  compaction through ``kernels.parse_edges.parse_edges_accumulate``.

Limits (documented): vertex ids must have <= 9 decimal digits (int32 math;
covers every graph in the paper, max |V| = 214M), weights are plain
decimals (no exponent notation), and no line may exceed `overlap` bytes
(violations that cross a block boundary are detected during staging and
raise — see ``blocks.stage_blocks``; ``docs/performance.md`` has the
remedy).  ``parse_accumulate`` computes weight mantissas exactly in
integer arithmetic and rounds to float32 once, so weights match
``parse_block`` bit-for-bit up to 7 significant digits; 8+ digit
mantissas may differ in the last ulp (both paths round, in different
orders).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32

# byte classes
_NL, _CR, _SP, _TAB, _DOT, _MINUS = 10, 13, 32, 9, 46, 45


def _scatter_set(cap: int, select, index, values, fill, dtype):
    """out[index[i]] = values[i] where select[i]; OOB indices dropped."""
    out = jnp.full((cap,), fill, dtype)
    idx = jnp.where(select, index, cap)
    return out.at[idx].set(values.astype(dtype), mode="drop")


@functools.partial(
    jax.jit,
    static_argnames=("weighted", "base", "edge_cap", "max_digits"),
)
def parse_block(
    buf: jax.Array,
    owned_start: jax.Array,
    owned_end: jax.Array,
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array]:
    """Parse one byte block into fixed-capacity (src, dst, w, count).

    buf:  (n,) uint8, newline-padded.  A line is *owned* iff the index of
    its terminating newline lies in [owned_start, owned_end).
    Returns int32 src/dst (padded with -1), float32 w or None, int32 count.

    A thin wrapper over the per-byte sorted-segment core
    (:func:`_parse_block_bytes`) plus one compaction scatter — lines
    compact in terminating-newline order, which is line order.
    """
    n = buf.shape[0]
    valid, src_b, dst_b, w_b = _parse_block_bytes(
        buf, owned_start, owned_end, weighted=weighted, base=base,
        max_digits=max_digits)
    pos = jnp.cumsum(valid.astype(I32)) - 1
    count = jnp.maximum(pos[-1] + 1, 0)
    # the block's only scatter: pack the valid newline byte positions;
    # values then come from gathers at those positions
    packed = _scatter_set(edge_cap, valid, pos,
                          jnp.arange(n, dtype=I32), n, I32)
    pv = packed < n
    pc = jnp.minimum(packed, n - 1)
    src = jnp.where(pv, src_b[pc], -1)
    dst = jnp.where(pv, dst_b[pc], -1)
    w = jnp.where(pv, w_b[pc], 0.0) if weighted else None
    return src, dst, w, count


@functools.partial(
    jax.jit, static_argnames=("weighted", "base", "edge_cap", "max_digits")
)
def parse_blocks(
    bufs: jax.Array,
    owned_start: jax.Array,
    owned_end: jax.Array,
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
):
    """vmap of parse_block over a batch of equally-sized blocks."""
    fn = functools.partial(parse_block, weighted=weighted, base=base,
                           edge_cap=edge_cap, max_digits=max_digits)
    return jax.vmap(fn)(bufs, owned_start, owned_end)


# ---------------------------------------------------------------------------
# fused parse -> accumulate (the streaming loader's hot path)
# ---------------------------------------------------------------------------

def _parse_block_bytes(buf, owned_start, owned_end, *, weighted: bool,
                       base: int, max_digits: int = 9):
    """Per-byte fused parse of one block: ``(valid, src, dst, w)`` in the
    byte domain.

    ``valid[i]`` is True iff byte ``i`` is an *owned* newline terminating
    a well-formed edge line; ``src``/``dst``/``w`` carry that line's
    parsed values at those bytes (garbage elsewhere — consumers gather
    at valid positions only).  Token/line ids increase with byte
    position, so every per-token and per-line quantity is a cumulative
    max/sum plus a gather — no scatters at all.  Integer token values
    come from a wrapped int32 cumulative sum — per-token differences
    are exact for <= ``max_digits`` digit tokens.  The Pallas kernel
    (``kernels.parse_edges``) realizes this same algebra in VMEM; both
    wrappers (:func:`parse_block`, :func:`parse_accumulate`) and the
    kernel therefore agree bit-for-bit.
    """
    n = buf.shape[0]
    d = buf.astype(I32)
    idx = jnp.arange(n, dtype=I32)

    is_digit = (d >= 48) & (d <= 57)
    is_dot = d == _DOT
    is_minus = d == _MINUS
    is_tok = is_digit | is_dot | is_minus
    is_nl = d == _NL
    is_ws = (d == _SP) | (d == _TAB) | (d == _CR)
    is_bad = ~(is_tok | is_nl | is_ws)

    prev_tok = jnp.concatenate([jnp.zeros((1,), bool), is_tok[:-1]])
    tok_start = is_tok & ~prev_tok
    next_tok = jnp.concatenate([is_tok[1:], jnp.zeros((1,), bool)])
    tok_end = is_tok & ~next_tok

    cum_ts = jnp.cumsum(tok_start.astype(I32))     # token starts <= i
    cum_dig = jnp.cumsum(is_digit.astype(I32))     # digits <= i

    # my token's end/start byte position, per byte (valid at token bytes:
    # tokens never span newlines, so runs are well-nested)
    end_pos = jax.lax.cummin(jnp.where(tok_end, idx, n - 1), reverse=True)
    start_pos = jax.lax.cummax(jnp.where(tok_start, idx, 0))

    # digits strictly after byte i within its token
    digits_after = jnp.clip(cum_dig[end_pos] - cum_dig, 0, max_digits)
    pow10_i = 10 ** jnp.arange(max_digits + 1, dtype=I32)
    contrib = jnp.where(is_digit, (d - 48) * pow10_i[digits_after], 0)
    csum_c = jnp.cumsum(contrib)       # int32 wraps; per-token diff is exact
    excl_c = csum_c - contrib
    # integer value of the token ending at byte i (valid at token ends)
    tok_val = csum_c - excl_c[start_pos]

    # latest newline strictly before byte i (-1: none)
    pex = jnp.concatenate([
        jnp.full((1,), -1, I32),
        jax.lax.cummax(jnp.where(is_nl, idx, -1))[:-1]])
    # token starts up to my line's opening newline
    cts_at = jnp.where(pex < 0, 0, cum_ts[jnp.maximum(pex, 0)])
    # my token's 0-based ordinal within its line (valid at token ends)
    ord_in_line = cum_ts - 1 - cts_at

    def role_pos(k):
        """Latest byte <= i ending a token with line-ordinal k."""
        return jax.lax.cummax(jnp.where(tok_end & (ord_in_line == k), idx, -1))

    p0, p1 = role_pos(0), role_pos(1)
    bad_pos = jax.lax.cummax(jnp.where(is_bad, idx, -1))

    owned = (idx >= owned_start) & (idx < owned_end)
    # ">= 2 tokens in the line" <=> a role-1 token ends inside it
    valid = is_nl & owned & (p1 > pex) & ~(bad_pos > pex)

    src = tok_val[jnp.maximum(p0, 0)] - base
    dst = tok_val[jnp.maximum(p1, 0)] - base

    w = None
    if weighted:
        p2 = role_pos(2)
        dot_pos = jax.lax.cummax(jnp.where(is_dot, idx, -1))
        minus_pos = jax.lax.cummax(jnp.where(is_minus, idx, -1))
        p2c = jnp.maximum(p2, 0)
        w_start = start_pos[p2c]
        dot_of = dot_pos[p2c]
        frac_len = jnp.where(dot_of >= w_start,
                             cum_dig[p2c] - cum_dig[jnp.maximum(dot_of, 0)], 0)
        pow10_f = jnp.float32(10.0) ** jnp.arange(max_digits + 1)
        wf = tok_val[p2c].astype(jnp.float32) \
            / pow10_f[jnp.clip(frac_len, 0, max_digits)]
        wf = jnp.where(minus_pos[p2c] >= w_start, -wf, wf)
        w = jnp.where(p2 > pex, wf, 1.0)       # missing weight -> 1
    return valid, src, dst, w


def _compact_accumulate(acc_src, acc_dst, acc_w, total, valid, src, dst, w,
                        *, edge_bound: int):
    """Pack a batch of per-byte parses into the accumulators at ``total``.

    ``valid``/``src``/``dst``/``w`` are ``(nb, blen)`` byte-domain
    outputs of :func:`_parse_block_bytes` (or the Pallas kernel's
    byte-domain realization of it — ``kernels.parse_edges`` fuses the
    same compaction after its kernel).  Blocks pack consecutively and
    edges within a block stay in line order — the same edge order the
    two-step parse_blocks + accumulate pipeline produced.
    """
    valid_f = valid.reshape(-1)
    flat_n = valid_f.shape[0]
    # batch-wide exclusive compaction
    dest = jnp.cumsum(valid_f.astype(I32)) - 1
    count = jnp.maximum(dest[-1] + 1, 0)
    # one scatter packs byte positions; values then come from gathers
    # (scatter is the slow primitive on CPU XLA — use exactly one)
    pos = jnp.full((edge_bound,), flat_n, I32).at[
        jnp.where(valid_f, dest, edge_bound)].set(
            jnp.arange(flat_n, dtype=I32), mode="drop")
    pv = pos < flat_n
    posc = jnp.minimum(pos, flat_n - 1)
    src_w = jnp.where(pv, src.reshape(-1)[posc], -1)
    dst_w = jnp.where(pv, dst.reshape(-1)[posc], -1)
    # a fixed-size window written at the running offset: with donation
    # this lowers to an in-place memcpy of edge_bound elements; invalid
    # window slots carry the accumulator's padding values, and the next
    # batch's window starts where this batch's edges end, so padding
    # never buries an edge
    acc_src = jax.lax.dynamic_update_slice(acc_src, src_w, (total,))
    acc_dst = jax.lax.dynamic_update_slice(acc_dst, dst_w, (total,))
    if acc_w is not None and w is not None:
        w_w = jnp.where(pv, w.reshape(-1)[posc], 0.0)
        acc_w = jax.lax.dynamic_update_slice(acc_w, w_w, (total,))
    return acc_src, acc_dst, acc_w, total + count


def _parse_accumulate_impl(acc_src, acc_dst, acc_w, total, bufs,
                           owned_start, owned_end, *, weighted: bool,
                           base: int, edge_bound: int, max_digits: int = 9):
    fn = functools.partial(_parse_block_bytes, weighted=weighted, base=base,
                           max_digits=max_digits)
    valid, src, dst, w = jax.vmap(fn)(bufs, owned_start, owned_end)
    return _compact_accumulate(acc_src, acc_dst, acc_w, total, valid, src,
                               dst, w, edge_bound=edge_bound)


@functools.lru_cache(maxsize=None)
def _parse_accumulate_jit(donate: bool):
    return jax.jit(
        _parse_accumulate_impl,
        static_argnames=("weighted", "base", "edge_bound", "max_digits"),
        donate_argnums=(0, 1, 2) if donate else ())


@functools.lru_cache(maxsize=None)
def donation_supported() -> bool:
    """Probe whether this backend honors ``donate_argnums`` (in-place
    buffer reuse).  CPU and TPU do on current jaxlib; a backend that
    refuses donation leaves the input buffer alive — callers fall back
    to the same program without donation (one extra buffer copy per
    batch, same results).  Cached per process."""
    probe = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.zeros((8,), I32)
    probe(x).block_until_ready()
    return x.is_deleted()


def parse_accumulate(acc_src, acc_dst, acc_w, total, bufs, owned_start,
                     owned_end, *, weighted: bool, base: int,
                     edge_bound: int, max_digits: int = 9,
                     donate: Optional[bool] = None):
    """Fused batch parse + packed accumulation (one jitted program).

    Parses ``bufs`` (nb, buf_len) and writes the batch's edges into the
    packed accumulators at offset ``total``, returning the updated
    ``(acc_src, acc_dst, acc_w, total)``.  ``edge_bound`` is the static
    per-batch edge capacity (``nb * plan.edge_cap``); the caller must
    guarantee ``total + edge_bound <= len(acc_src)`` (the loader sizes
    the accumulators so trimmed batches always fit exactly).

    ``donate=None`` probes the backend once and donates the accumulator
    buffers when supported — the update then happens in place, instead
    of copying the full capacity-sized buffers every batch.  **Donated
    inputs are consumed**: callers must rebind (never reuse) the passed
    accumulators, exactly like the loader's streaming loop does.
    ``donate=False`` is the documented fallback for backends that
    refuse donation (and for callers that want to keep their inputs).
    """
    if donate is None:
        donate = donation_supported()
    return _parse_accumulate_jit(bool(donate))(
        acc_src, acc_dst, acc_w, total, bufs, owned_start, owned_end,
        weighted=weighted, base=base, edge_bound=edge_bound,
        max_digits=max_digits)


def make_accumulators(cap: int, *, weighted: bool, device=None):
    """Fresh packed edge accumulators: ``(src=-1, dst=-1, w=0, total=0)``.

    The one place the accumulator layout (padding values, dtypes) is
    written down — the streaming loader, the tuner's measurement pass,
    and the sharded loader all start from here.  ``device`` commits the
    buffers to a specific device: jit follows committed inputs, so the
    whole donated parse+accumulate chain then runs on that device (the
    sharded loader places shard k's accumulators on mesh device k and
    the per-shard parses execute concurrently with no cross-device
    traffic).
    """
    cap = max(int(cap), 1)
    acc_src = np.full((cap,), -1, np.int32)
    acc_dst = np.full((cap,), -1, np.int32)
    acc_w = np.zeros((cap,), np.float32) if weighted else None
    total = np.zeros((), np.int32)
    if device is None:
        return (jnp.asarray(acc_src), jnp.asarray(acc_dst),
                jnp.asarray(acc_w) if weighted else None, jnp.asarray(total))
    put = functools.partial(jax.device_put, device=device)
    return (put(acc_src), put(acc_dst), put(acc_w) if weighted else None,
            put(total))
