"""Transparent compression for every loading path: codecs + framed blocks.

GVEL makes loading IO-bound; once parsing is off the critical path
(snapshots, fused streaming) the remaining cost is bytes on disk.  This
module lets every loader input arrive compressed:

* a **codec registry** — stdlib ``zlib`` always, ``zstd`` auto-registered
  when the ``zstandard`` package is importable.  Codecs are named for
  CLIs (``--compress zlib:6``) and numbered for on-disk headers.
* a **framed block format** — compressed payloads are a sequence of
  independent frames, each one ``BlockPlan``-sized block of the original
  bytes with its compressed length, uncompressed length, and CRC32.
  Frames map 1:1 onto the staging blocks of :mod:`repro.core.blocks`,
  so the streaming engines decompress frame *i+1* in the prefetch
  thread while the device parses frame *i* (the ParaGrapher overlap:
  compressed inputs can load faster than raw when the disk is slow).
  The same frame stream is the payload of compressed ``.gvel`` v2
  sections (:mod:`repro.core.snapshot`).
* a **framed file container** (``.elz`` by convention, detected by
  magic, never extension) for standalone compressed text edgelists, and
  transparent ``.el.gz`` / gzip support via the stdlib.

Every decompression path validates frame checksums and declared lengths
and raises ``ValueError`` on any mismatch — a corrupted input must never
come back as silently-wrong edges.
"""
from __future__ import annotations

import dataclasses
import gzip
import io
import os
import struct
import zlib
from typing import Dict, Iterator, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from . import faults as _faults
from .blocks import MemoryBlockSource, SequentialBlockSource, mmap_bytes

# codec id 0 is reserved for "stored" (no compression) in on-disk headers
CODEC_RAW = 0

FRAME_HDR_FMT = "<III"            # comp_len, raw_len, crc32(raw payload)
FRAME_HDR_LEN = struct.calcsize(FRAME_HDR_FMT)          # 12

FRAMED_MAGIC = b"GVELFRMD"
FRAMED_VERSION = 1
# magic, version, codec_id, frame_beta, orig_len, frame_count, reserved
FRAMED_HDR_FMT = "<8sIIQQII"
FRAMED_HDR_LEN = struct.calcsize(FRAMED_HDR_FMT)        # 40

GZIP_MAGIC = b"\x1f\x8b"

DEFAULT_FRAME_BETA = 256 * 1024   # GVEL's beta: one frame per staging block

# decompression chunk pulled per prefetch-thread step for gzip streams
_GZ_CHUNK = 256 * 1024


# ---------------------------------------------------------------------------
# codec registry
# ---------------------------------------------------------------------------

@runtime_checkable
class Codec(Protocol):
    """One compression algorithm.  ``codec_id`` is the stable on-disk
    number (framed file headers, ``.gvel`` v2 section entries); ``name``
    is the CLI/API handle."""

    name: str
    codec_id: int

    def compress(self, data: bytes, level: Optional[int]) -> bytes: ...

    def decompress(self, data: bytes, raw_len: int) -> bytes: ...


class ZlibCodec:
    """Stdlib zlib (DEFLATE) — always available, the tier-1 path."""

    name = "zlib"
    codec_id = 1

    def compress(self, data: bytes, level: Optional[int] = None) -> bytes:
        return zlib.compress(data, -1 if level is None else level)

    def decompress(self, data: bytes, raw_len: int) -> bytes:
        try:
            return zlib.decompress(data, bufsize=max(raw_len, 64))
        except zlib.error as exc:
            raise ValueError(f"zlib frame decompression failed: {exc}") from None


class ZstdCodec:
    """``zstandard`` package; registered only when importable."""

    name = "zstd"
    codec_id = 2

    def __init__(self):
        import zstandard
        self._mod = zstandard

    def compress(self, data: bytes, level: Optional[int] = None) -> bytes:
        cctx = self._mod.ZstdCompressor(level=3 if level is None else level)
        return cctx.compress(data)

    def decompress(self, data: bytes, raw_len: int) -> bytes:
        try:
            return self._mod.ZstdDecompressor().decompress(
                data, max_output_size=max(raw_len, 64))
        except self._mod.ZstdError as exc:
            raise ValueError(f"zstd frame decompression failed: {exc}") from None


_CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Register under ``codec.name`` (last wins).  ``codec_id`` must be
    unique and nonzero (0 is the reserved "stored" id)."""
    if codec.codec_id == CODEC_RAW:
        raise ValueError("codec_id 0 is reserved for uncompressed data")
    for other in _CODECS.values():
        if other.codec_id == codec.codec_id and other.name != codec.name:
            raise ValueError(
                f"codec_id {codec.codec_id} already taken by {other.name!r}")
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; available: {available_codecs()}"
        ) from None


def codec_for_id(codec_id: int) -> Codec:
    for codec in _CODECS.values():
        if codec.codec_id == codec_id:
            return codec
    hint = " (is the zstandard package installed?)" if codec_id == 2 else ""
    raise ValueError(f"unknown codec id {codec_id}{hint}; "
                     f"available: {available_codecs()}")


def available_codecs() -> list[str]:
    return sorted(_CODECS)


def parse_codec_spec(spec: str) -> Tuple[Codec, Optional[int]]:
    """``"zlib"`` / ``"zstd:9"`` -> (codec, level-or-None)."""
    name, _, level = spec.partition(":")
    codec = get_codec(name)
    if not level:
        return codec, None
    try:
        return codec, int(level)
    except ValueError:
        raise ValueError(f"bad codec level {level!r} in spec {spec!r}") from None


register_codec(ZlibCodec())
try:                               # capability check: zstd is optional
    register_codec(ZstdCodec())
except ImportError:
    pass


# ---------------------------------------------------------------------------
# frame layer (shared by framed files and .gvel v2 sections)
# ---------------------------------------------------------------------------

def frame_count_for(raw_len: int, frame_beta: int) -> int:
    """Frames in a stream over ``raw_len`` bytes (>= 1: empty input is
    one empty frame, so every stream has a checksummed frame)."""
    return max(1, -(-raw_len // frame_beta))


def compress_frames(data, codec: Codec, *, level: Optional[int] = None,
                    frame_beta: int = DEFAULT_FRAME_BETA) -> bytes:
    """Bytes -> concatenated ``[header | payload]`` frames, one frame per
    ``frame_beta``-sized block of the input (last may be short)."""
    if frame_beta <= 0:
        raise ValueError(f"frame_beta must be positive, got {frame_beta}")
    if isinstance(data, (bytes, bytearray, memoryview)):
        buf = bytes(data)
    else:
        buf = np.ascontiguousarray(np.asarray(data, np.uint8)).tobytes()
    out = []
    for lo in range(0, len(buf), frame_beta) or [0]:
        raw = buf[lo:lo + frame_beta]
        comp = codec.compress(raw, level)
        out.append(struct.pack(FRAME_HDR_FMT, len(comp), len(raw),
                               zlib.crc32(raw)))
        out.append(comp)
    return b"".join(out)


def iter_decompressed_frames(payload, codec: Codec, *,
                             context: str = "frame stream",
                             start_frame: int = 0,
                             stop_frame: Optional[int] = None,
                             ) -> Iterator[bytes]:
    """Yield validated uncompressed frame payloads in order.

    ``start_frame``/``stop_frame`` select a frame range: frames before
    ``start_frame`` are *walked* (their headers validated, their payloads
    never decompressed — the frame headers form an implicit seek index),
    and iteration stops before ``stop_frame``.  The sharded loader uses
    this to give each mesh shard a decompression stream over only its
    byte span.

    Raises ``ValueError`` on a truncated frame header or payload, a
    declared-length mismatch after decompression, or a CRC32 mismatch —
    corruption surfaces as an error, never as wrong bytes.
    """
    view = memoryview(payload)
    pos = 0
    idx = 0
    while pos < len(view):
        if stop_frame is not None and idx >= stop_frame:
            return
        if pos + FRAME_HDR_LEN > len(view):
            raise ValueError(
                f"{context}: truncated frame header for frame {idx} at "
                f"byte {pos} ({len(view) - pos} of {FRAME_HDR_LEN} bytes)")
        comp_len, raw_len, crc = struct.unpack_from(FRAME_HDR_FMT, view, pos)
        payload_pos = pos + FRAME_HDR_LEN
        pos = payload_pos
        if pos + comp_len > len(view):
            raise ValueError(
                f"{context}: truncated frame payload for frame {idx} at "
                f"byte {pos} ({len(view) - pos} of {comp_len} declared "
                f"bytes)")
        if idx < start_frame:         # seek: skip the compressed payload
            pos += comp_len
            idx += 1
            continue
        comp = bytes(view[pos:pos + comp_len])
        if _faults._ACTIVE is not None:
            for f in _faults.inject("frame", idx, where=context):
                comp = _faults.corrupt_bytes(comp, f, salt=idx)
        try:
            raw = codec.decompress(comp, raw_len)
        except ValueError as exc:
            raise ValueError(
                f"{context}: frame {idx} at byte {payload_pos}: "
                f"{exc}") from None
        pos += comp_len
        idx += 1
        if len(raw) != raw_len:
            raise ValueError(
                f"{context}: frame {idx - 1} at byte {payload_pos} declared "
                f"{raw_len} uncompressed bytes but decompressed to "
                f"{len(raw)}")
        if zlib.crc32(raw) != crc:
            raise ValueError(
                f"{context}: frame {idx - 1} checksum mismatch at byte "
                f"{payload_pos} (corrupt payload)")
        yield raw


@dataclasses.dataclass(frozen=True)
class FrameEntry:
    """One frame's coordinates inside a frame stream: where its
    compressed payload sits (``payload_off``/``comp_len``) and which
    uncompressed byte range it covers (``raw_off``/``raw_len``)."""

    index: int
    payload_off: int              # byte offset of compressed payload
    comp_len: int
    raw_off: int                  # cumulative uncompressed offset
    raw_len: int
    crc: int

    @property
    def raw_end(self) -> int:
        return self.raw_off + self.raw_len


def frame_table(payload, *, context: str = "frame stream") -> list:
    """Walk a frame stream's headers into a seek index — a list of
    :class:`FrameEntry` — without decompressing anything.

    The per-frame headers (comp_len, raw_len, crc) form an implicit
    index: 12 bytes read per frame, compressed payloads skipped.  This
    is the planner behind partial section decode (``.gvel`` v2 row
    ranges touch only the frames their byte span overlaps) and the
    per-section frame counts in ``GraphSource.info()``.  Raises
    ``ValueError`` on a truncated header or a payload running past the
    end of the stream.
    """
    view = memoryview(payload)
    entries = []
    pos = 0
    raw_off = 0
    idx = 0
    while pos < len(view):
        if pos + FRAME_HDR_LEN > len(view):
            raise ValueError(
                f"{context}: truncated frame header for frame {idx} at "
                f"byte {pos} ({len(view) - pos} of {FRAME_HDR_LEN} bytes)")
        comp_len, raw_len, crc = struct.unpack_from(FRAME_HDR_FMT, view, pos)
        pos += FRAME_HDR_LEN
        if pos + comp_len > len(view):
            raise ValueError(
                f"{context}: truncated frame payload for frame {idx} at "
                f"byte {pos} ({len(view) - pos} of {comp_len} declared "
                f"bytes)")
        entries.append(FrameEntry(idx, pos, comp_len, raw_off, raw_len, crc))
        pos += comp_len
        raw_off += raw_len
        idx += 1
    return entries


def count_frames(payload, *, context: str = "frame stream") -> int:
    """Frame count of a stream by header walk (no decompression)."""
    return len(frame_table(payload, context=context))


def frames_overlapping(entries: list, byte_lo: int, byte_hi: int) -> list:
    """The sub-list of ``entries`` whose uncompressed byte ranges
    overlap ``[byte_lo, byte_hi)`` — the frames a partial read must
    decode, and no others.  Empty ranges touch no frames."""
    if byte_hi <= byte_lo:
        return []
    return [e for e in entries
            if e.raw_off < byte_hi and e.raw_end > byte_lo and e.raw_len]


def decode_frame(payload, entry: FrameEntry, codec: Codec, *,
                 context: str = "frame stream") -> bytes:
    """Decompress and checksum exactly one frame of a stream.

    The seek-and-decode primitive: callers resolve ``entry`` from
    :func:`frame_table` (header walk only) and pay decompression for
    just the frames they need — the same per-frame selectivity
    :func:`open_shard_block_source` gives the sharded streaming loader,
    exposed for random access.  Raises ``ValueError`` on a
    declared-length or CRC32 mismatch.
    """
    view = memoryview(payload)
    comp = bytes(view[entry.payload_off:entry.payload_off + entry.comp_len])
    if _faults._ACTIVE is not None:
        for f in _faults.inject("frame", entry.index, where=context):
            comp = _faults.corrupt_bytes(comp, f, salt=entry.index)
    try:
        raw = codec.decompress(comp, entry.raw_len)
    except ValueError as exc:
        raise ValueError(
            f"{context}: frame {entry.index} at byte {entry.payload_off}: "
            f"{exc}") from None
    if len(raw) != entry.raw_len:
        raise ValueError(
            f"{context}: frame {entry.index} at byte {entry.payload_off} "
            f"declared {entry.raw_len} uncompressed bytes but decompressed "
            f"to {len(raw)}")
    if zlib.crc32(raw) != entry.crc:
        raise ValueError(
            f"{context}: frame {entry.index} checksum mismatch at byte "
            f"{entry.payload_off} (corrupt payload)")
    return raw


def decompress_frames(payload, raw_len: int, codec: Codec, *,
                      context: str = "frame stream") -> np.ndarray:
    """Whole frame stream -> uint8 array of exactly ``raw_len`` bytes."""
    out = np.empty(raw_len, np.uint8)
    pos = 0
    for idx, raw in enumerate(
            iter_decompressed_frames(payload, codec, context=context)):
        if pos + len(raw) > raw_len:
            raise ValueError(
                f"{context}: frame {idx} decompresses past the declared "
                f"total ({pos + len(raw)} > {raw_len} bytes)")
        out[pos:pos + len(raw)] = np.frombuffer(raw, np.uint8)
        pos += len(raw)
    if pos != raw_len:
        raise ValueError(f"{context}: frames decompress to {pos} bytes, "
                         f"expected {raw_len}")
    return out


# ---------------------------------------------------------------------------
# framed file container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FramedInfo:
    """Validated header of a framed compressed file."""

    path: str
    codec: Codec
    frame_beta: int
    orig_len: int
    frame_count: int
    payload_offset: int


def write_framed(out_path: str, data, *, codec: str = "zlib",
                 level: Optional[int] = None,
                 frame_beta: int = DEFAULT_FRAME_BETA) -> None:
    """Compress ``data`` (bytes / uint8 array) into a framed container."""
    c = get_codec(codec)
    buf = data if isinstance(data, (bytes, bytearray)) else \
        np.asarray(data, np.uint8).tobytes()
    payload = compress_frames(buf, c, level=level, frame_beta=frame_beta)
    with open(out_path, "wb") as f:
        f.write(struct.pack(FRAMED_HDR_FMT, FRAMED_MAGIC, FRAMED_VERSION,
                            c.codec_id, frame_beta, len(buf),
                            frame_count_for(len(buf), frame_beta), 0))
        f.write(payload)


def compress_file_framed(in_path: str, out_path: str, *, codec: str = "zlib",
                         level: Optional[int] = None,
                         frame_beta: int = DEFAULT_FRAME_BETA) -> None:
    write_framed(out_path, mmap_bytes(in_path), codec=codec, level=level,
                 frame_beta=frame_beta)


def is_framed(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(len(FRAMED_MAGIC)) == FRAMED_MAGIC
    except OSError:
        return False


def is_gzip(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(len(GZIP_MAGIC)) == GZIP_MAGIC
    except OSError:
        return False


def compression_of(path: str) -> Optional[str]:
    """``"framed"`` / ``"gzip"`` / None, by magic sniff (never extension)."""
    if is_framed(path):
        return "framed"
    if is_gzip(path):
        return "gzip"
    return None


def read_framed_header(path: str) -> FramedInfo:
    size = os.path.getsize(path)
    if size < FRAMED_HDR_LEN:
        raise ValueError(f"{path}: truncated framed header ({size} bytes)")
    with open(path, "rb") as f:
        hdr = f.read(FRAMED_HDR_LEN)
    magic, version, codec_id, frame_beta, orig_len, count, reserved = \
        struct.unpack(FRAMED_HDR_FMT, hdr)
    if magic != FRAMED_MAGIC:
        raise ValueError(f"{path}: bad magic {magic!r}, not a framed file")
    if version != FRAMED_VERSION:
        raise ValueError(f"{path}: unsupported framed version {version} "
                         f"(this reader supports {FRAMED_VERSION})")
    if reserved != 0:
        raise ValueError(f"{path}: nonzero reserved framed header field")
    if frame_beta <= 0:
        raise ValueError(f"{path}: framed header has frame_beta {frame_beta}")
    try:
        codec = codec_for_id(codec_id)
    except ValueError as exc:
        raise ValueError(f"{path}: {exc}") from None
    if count != frame_count_for(orig_len, frame_beta):
        raise ValueError(
            f"{path}: header declares {count} frames, but {orig_len} bytes "
            f"at frame_beta {frame_beta} is "
            f"{frame_count_for(orig_len, frame_beta)}")
    return FramedInfo(path, codec, frame_beta, orig_len, count,
                      FRAMED_HDR_LEN)


def _framed_chunks(info: FramedInfo, start_frame: int = 0,
                   stop_frame: Optional[int] = None) -> Iterator[bytes]:
    """Sequential frame payloads of a framed file (prefetch-thread fuel).

    The whole compressed payload is mmap'd (compressed bytes only —
    small); each ``next()`` decompresses exactly one frame, so the
    consumer controls how far ahead of the parser decompression runs.
    ``start_frame``/``stop_frame`` restrict the stream to a frame range
    (frames before the start are header-walked, not decompressed).
    """
    data = mmap_bytes(info.path, info.payload_offset)
    yield from iter_decompressed_frames(data, info.codec, context=info.path,
                                        start_frame=start_frame,
                                        stop_frame=stop_frame)


def _gzip_chunks(path: str) -> Iterator[bytes]:
    """Sequential ``_GZ_CHUNK``-sized chunks of a gzip file."""
    try:
        with gzip.open(path, "rb") as f:
            while True:
                chunk = f.read(_GZ_CHUNK)
                if not chunk:
                    return
                yield chunk
    except (EOFError, zlib.error, gzip.BadGzipFile) as exc:
        raise ValueError(f"{path}: corrupt gzip stream: {exc}") from None


def gzip_length_hint(path: str) -> int:
    """Uncompressed length from the gzip trailer (ISIZE).

    Exact for single-member files under 4 GiB; for multi-member or
    huge files it understates, which the streaming reader detects and
    rejects (use the framed container for those).
    """
    size = os.path.getsize(path)
    if size < 18:                  # header (10) + trailer (8)
        raise ValueError(f"{path}: truncated gzip file ({size} bytes)")
    with open(path, "rb") as f:
        f.seek(-4, os.SEEK_END)
        return struct.unpack("<I", f.read(4))[0]


# ---------------------------------------------------------------------------
# loader integration: whole-file bytes, streams, block sources
# ---------------------------------------------------------------------------

def file_bytes(path: str, offset: int = 0) -> np.ndarray:
    """Uncompressed file bytes as uint8, ``offset`` applied *after*
    decompression (so MTX ``body_offset`` means the same thing for raw
    and compressed inputs).  Raw files stay a zero-copy mmap; compressed
    files are materialized in memory (host-parser path — the streaming
    engines use :func:`open_block_source` instead and never hold the
    whole decompressed file)."""
    kind = compression_of(path)
    if kind is None:
        return mmap_bytes(path, offset)
    if kind == "gzip":
        data = np.frombuffer(b"".join(_gzip_chunks(path)), np.uint8)
    else:
        info = read_framed_header(path)
        data = decompress_frames(mmap_bytes(path, info.payload_offset),
                                 info.orig_len, info.codec, context=path)
    return data[offset:] if offset else data


class _FramedRawIO(io.RawIOBase):
    """Minimal read-only raw IO over a framed file's uncompressed bytes
    (forward-only; wrap in ``io.BufferedReader`` for readline/peek).

    ``tell``/``seekable`` are implemented so ``BufferedReader.tell()``
    reports *uncompressed* positions — header scanners (MTX) rely on
    that to compute body offsets; actual seeking is unsupported.
    """

    def __init__(self, info: FramedInfo):
        self._chunks = _framed_chunks(info)
        self._pending = b""
        self._pos = 0

    def readable(self) -> bool:
        return True

    def seekable(self) -> bool:
        return True                   # for BufferedReader.tell() only

    def tell(self) -> int:
        return self._pos

    def seek(self, pos, whence=os.SEEK_SET):
        if (whence == os.SEEK_SET and pos == self._pos) or \
                (whence == os.SEEK_CUR and pos == 0):
            return self._pos          # no-op seeks keep tell() working
        raise io.UnsupportedOperation(
            "framed streams are forward-only; seek is not supported")

    def readinto(self, b) -> int:
        while not self._pending:
            chunk = next(self._chunks, None)
            if chunk is None:
                return 0
            self._pending = chunk
        n = min(len(b), len(self._pending))
        b[:n] = self._pending[:n]
        self._pending = self._pending[n:]
        self._pos += n
        return n


def open_stream(path: str):
    """Binary file-like over the *uncompressed* bytes of ``path`` —
    ``tell()`` reports uncompressed positions, so header scanners (MTX)
    compute body offsets that mean the same thing for every input."""
    kind = compression_of(path)
    if kind is None:
        return open(path, "rb")
    if kind == "gzip":
        return gzip.open(path, "rb")
    return io.BufferedReader(_FramedRawIO(read_framed_header(path)))


def peek_bytes(path: str, n: int) -> bytes:
    """First ``n`` uncompressed bytes (b"" on unreadable/corrupt files —
    this is a sniffing helper, not a validator)."""
    try:
        with open_stream(path) as f:
            return f.read(n)
    except (OSError, ValueError, EOFError, zlib.error):
        return b""


def open_block_source(path: str, offset: int = 0):
    """The streaming engines' input factory:
    ``(block source, forced_beta-or-None)``.

    Raw files get a random-access :class:`MemoryBlockSource` over the
    mmap.  Compressed files get a :class:`SequentialBlockSource` whose
    chunks are decompressed lazily — the loader's prefetch thread pulls
    them, so decompression overlaps the device parse.  Framed files
    force the plan's block size to ``frame_beta`` so frames map 1:1
    onto staging blocks (one frame decompressed per block staged).
    """
    kind = compression_of(path)
    if kind is None:
        source = MemoryBlockSource(mmap_bytes(path, offset))
        return _faults.wrap_block_source(source, path), None
    if kind == "gzip":
        length = gzip_length_hint(path)
        source = SequentialBlockSource(
            _gzip_chunks(path), length - offset, skip=offset,
            describe=f"{path} (gzip)",
            mismatch_hint=" (multi-member or >4 GiB gzip? the trailer "
                          "length is unreliable there — recompress with "
                          "repro.core.codecs.compress_file_framed, or use "
                          "a host engine: numpy/threads)")
        return _faults.wrap_block_source(source, f"{path} (gzip)"), None
    info = read_framed_header(path)
    source = SequentialBlockSource(
        _framed_chunks(info), info.orig_len - offset, skip=offset,
        describe=f"{path} (framed {info.codec.name})")
    return (_faults.wrap_block_source(source,
                                      f"{path} (framed {info.codec.name})"),
            info.frame_beta)


def stream_geometry(path: str, offset: int = 0) -> Tuple[int, Optional[int]]:
    """``(uncompressed post-offset length, forced_beta-or-None)`` without
    opening a block source.

    The sharded loader plans the whole file once (this call), splits the
    plan into per-shard spans, and only then opens one shard-local block
    source per span via :func:`open_shard_block_source` — mirroring the
    geometry :func:`open_block_source` would have produced.
    """
    kind = compression_of(path)
    if kind is None:
        return max(os.path.getsize(path) - offset, 0), None
    if kind == "gzip":
        return max(gzip_length_hint(path) - offset, 0), None
    info = read_framed_header(path)
    return max(info.orig_len - offset, 0), info.frame_beta


def open_shard_block_source(path: str, plan, span, offset: int = 0):
    """A block source able to stage exactly ``span``'s blocks of ``plan``.

    ``plan`` must be the plan built from :func:`stream_geometry`'s length
    (and forced beta, for framed inputs); ``span`` is a
    ``blocks.ShardSpan`` with at least one block.  Per codec:

    * **raw** — a shared-mmap :class:`MemoryBlockSource`; random access
      makes any block range free.
    * **framed** — the frame headers form a seek index: the source's
      chunk stream starts at the frame containing the span's leftmost
      needed byte (first owned byte minus ``overlap`` of left context)
      and stops after the span's last frame.  Frames before the start
      are header-walked, never decompressed — shard k pays only for its
      own span's decompression.
    * **gzip** — DEFLATE streams have no seek index, so each shard
      decompresses (and discards) the prefix before its span; correct,
      but prefix-decompression cost grows with the shard index.  Use the
      framed container when sharded loading speed matters.
    """
    if span.num_blocks <= 0:
        raise ValueError(
            f"shard {span.shard}/{span.num_shards} owns no blocks; "
            f"callers skip opening sources for empty spans")
    kind = compression_of(path)
    shard_tag = f"shard {span.shard}/{span.num_shards}"
    if kind is None:
        source = MemoryBlockSource(mmap_bytes(path, offset))
        return _faults.wrap_block_source(source, f"{path} ({shard_tag})")
    if kind == "gzip":
        start = max(span.block_lo * plan.beta - plan.overlap, 0)
        end = plan.file_len if span.block_hi >= plan.num_blocks \
            else min(span.block_hi * plan.beta, plan.file_len)
        source = SequentialBlockSource(
            _gzip_chunks(path), plan.file_len, skip=offset + start,
            start=start, end=end, first_block=span.block_lo,
            describe=f"{path} (gzip, {shard_tag})",
            mismatch_hint=" (multi-member or >4 GiB gzip? the trailer "
                          "length is unreliable there — recompress with "
                          "repro.core.codecs.compress_file_framed, or use "
                          "a host engine: numpy/threads)")
        return _faults.wrap_block_source(source, f"{path} (gzip, {shard_tag})")
    info = read_framed_header(path)
    fb = info.frame_beta
    # pre-offset byte range the span needs: its blocks plus left context
    start_pre = max(span.block_lo * plan.beta - plan.overlap, 0) + offset
    end_pre = min(span.block_hi * plan.beta + offset, info.orig_len)
    frame_lo = min(start_pre // fb, max(info.frame_count - 1, 0))
    frame_hi = max(min(-(-end_pre // fb), info.frame_count), frame_lo)
    start = max(frame_lo * fb - offset, 0)
    source = SequentialBlockSource(
        _framed_chunks(info, frame_lo, frame_hi), plan.file_len,
        skip=max(offset - frame_lo * fb, 0),
        start=start, end=max(end_pre - offset, start),
        first_block=span.block_lo,
        describe=f"{path} (framed {info.codec.name}, {shard_tag})")
    return _faults.wrap_block_source(
        source, f"{path} (framed {info.codec.name}, {shard_tag})")
