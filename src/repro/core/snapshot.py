"""Binary graph snapshots: the ``.gvel`` container (write once, load many).

GVEL's CSR speedups come from paying the text-parse cost exactly once;
every load after that should be a zero-parse mmap.  This module defines
a versioned little-endian container holding the packed edgelist buffers
(``src``/``dst``/optional ``w``) and, optionally, a prebuilt CSR
(``offsets``/``indices``/optional ``weights``) so ``load_csr`` can skip
even the rank-based build — the true "write once, load many" fast path.

Layout (all integers little-endian; byte-level spec in
``docs/snapshot-format.md``)::

    [ header  | section table | pad | section 0 | pad | section 1 | ... ]

    header (40 bytes):
        magic     8s   b"GVELSNAP"
        version   u32  1 (raw sections) or 2 (sections may be compressed)
        flags     u32  bit 0 WEIGHTED, bit 1 HAS_EDGELIST, bit 2 HAS_CSR
        num_vertices  u64
        num_edges     u64
        section_count u32
        reserved      u32  (must be 0)
    section table entry (v1, 24 bytes each):
        section_id u32, dtype_code u32, offset u64, nbytes u64
    section table entry (v2, 40 bytes each):
        v1 fields + codec_id u32 (0 = stored), reserved u32,
        raw_nbytes u64; compressed payloads are ``core.codecs`` frame
        streams (per-frame lengths + CRC32)

Every section starts on a 4096-byte (page) boundary so an mmap'd reader
hands out aligned, typed, read-only views with no copying and no
parsing.  Compressed v2 section payloads decode **lazily, per
section**: a both-sections snapshot opened for its prebuilt CSR never
decompresses its edgelist frames (``read_snapshot(path, eager=False)``;
the default ``eager=True`` keeps the historical decompress-at-open
contract).  Vertex ids in a snapshot are canonical **0-based**
regardless of the base of the text file it was converted from.

Readers must reject unknown versions and truncated files, and must
*ignore* unknown section ids (that is how the format grows without a
version bump — see the spec for the bump rules).

The :class:`SnapshotEngine` registered under ``"snapshot"`` plugs this
into the loader registry: ``read_edgelist`` returns mmap-backed views,
``stream`` feeds the fused ``load_csr`` device path, and
``read_csr_prebuilt`` serves an embedded CSR with no build at all.
"""
from __future__ import annotations

import os
import struct
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from .blocks import mmap_bytes
from .types import CSR, EdgeList

MAGIC = b"GVELSNAP"
VERSION = 1                        # written when no v2 feature is used
VERSION_COMPRESSED = 2             # v2: section table entries carry a codec
SUPPORTED_VERSIONS = (VERSION, VERSION_COMPRESSED)
HEADER_FMT = "<8sIIQQII"           # magic, version, flags, V, E, n_sections, reserved
HEADER_LEN = struct.calcsize(HEADER_FMT)       # 40
SECTION_FMT = "<IIQQ"              # id, dtype code, byte offset, byte length
SECTION_LEN = struct.calcsize(SECTION_FMT)     # 24
# v2 entry: v1 fields + codec id, reserved (0), uncompressed byte length
SECTION_FMT_V2 = "<IIQQIIQ"
SECTION_LEN_V2 = struct.calcsize(SECTION_FMT_V2)   # 40
ALIGN = 4096                       # sections are page-aligned

# Per-section budget for decoded-frame memos on the selective-read path
# (get_slice).  A long-lived handle serving point reads against a large
# compressed section would otherwise accumulate every frame it ever
# touched — the decoded payload re-assembled piecemeal, pinned by the
# serving cache.  Least-recently-used frames are dropped past the cap
# (re-decode on next touch); evictions are counted and surfaced through
# Snapshot.frame_cache_stats() / SourceCache.stats().  Tests (and
# memory-constrained servers) may lower this module global.
FRAME_CACHE_BYTES = 32 * 1024 * 1024

FLAG_WEIGHTED = 1 << 0
FLAG_EDGELIST = 1 << 1
FLAG_CSR = 1 << 2

SEC_SRC = 1
SEC_DST = 2
SEC_EDGE_WEIGHTS = 3
SEC_CSR_OFFSETS = 4
SEC_CSR_INDICES = 5
SEC_CSR_WEIGHTS = 6

SECTION_NAMES = {
    SEC_SRC: "src",
    SEC_DST: "dst",
    SEC_EDGE_WEIGHTS: "edge_weights",
    SEC_CSR_OFFSETS: "csr_offsets",
    SEC_CSR_INDICES: "csr_indices",
    SEC_CSR_WEIGHTS: "csr_weights",
}

# dtype codes are explicit little-endian; a snapshot means the same bytes
# on every host (big-endian writers must byteswap before writing).
_CODE_TO_DTYPE = {
    1: np.dtype("<i4"),
    2: np.dtype("<i8"),
    3: np.dtype("<f4"),
    4: np.dtype("<f8"),
    5: np.dtype("u1"),
}
_KIND_TO_CODE = {("i", 4): 1, ("i", 8): 2, ("f", 4): 3, ("f", 8): 4,
                 ("u", 1): 5}


class SnapshotError(ValueError):
    """Malformed, truncated, or unsupported ``.gvel`` file.

    ``section`` names the damaged section (``"csr_indices"``, ...) when
    the failure is a payload decode — the quarantine key the serving
    cache uses to keep other sections of the same file live — and is
    ``None`` for structural damage (bad magic, truncated table)."""

    def __init__(self, message: str, *, section: Optional[str] = None):
        super().__init__(message)
        self.section = section


def _dtype_code(dtype: np.dtype) -> int:
    try:
        return _KIND_TO_CODE[(dtype.kind, dtype.itemsize)]
    except KeyError:
        raise SnapshotError(f"unsupported section dtype {dtype}") from None


def _align(off: int) -> int:
    return -(-off // ALIGN) * ALIGN


def is_snapshot(path: str) -> bool:
    """Cheap magic sniff; False for missing/short/non-snapshot files."""
    try:
        with open(path, "rb") as f:
            return f.read(len(MAGIC)) == MAGIC
    except OSError:
        return False


def peek_header(path: str) -> Tuple[int, int, int, int, int]:
    """Validate and return (version, flags, V, E, section_count) without
    touching any section bytes — used for cheap num_vertices hints."""
    size = os.path.getsize(path)
    if size < HEADER_LEN:
        raise SnapshotError(f"{path}: truncated header ({size} bytes)")
    with open(path, "rb") as f:
        hdr = f.read(HEADER_LEN)
    magic, version, flags, v, e, count, reserved = struct.unpack(HEADER_FMT, hdr)
    if magic != MAGIC:
        raise SnapshotError(f"{path}: bad magic {magic!r}, not a .gvel snapshot")
    if version not in SUPPORTED_VERSIONS:
        raise SnapshotError(
            f"{path}: unsupported snapshot version {version} "
            f"(this reader supports {SUPPORTED_VERSIONS})")
    if reserved != 0:
        raise SnapshotError(f"{path}: nonzero reserved header field")
    return version, flags, v, e, count


def peek_table(path: str):
    """Header + section-table metadata without touching payload bytes:
    ``(version, flags, V, E, entries)`` where each entry is
    ``(sid, dtype_code, offset, nbytes, codec_id, raw_nbytes)``.

    The cheap introspection primitive behind ``GraphSource.info()`` —
    reads ``HEADER_LEN + count * entry_len`` bytes, nothing else."""
    version, flags, v, e, count = peek_header(path)
    v2 = version == VERSION_COMPRESSED
    entry_fmt = SECTION_FMT_V2 if v2 else SECTION_FMT
    entry_len = SECTION_LEN_V2 if v2 else SECTION_LEN
    table_len = count * entry_len
    with open(path, "rb") as f:
        f.seek(HEADER_LEN)
        raw = f.read(table_len)
    if len(raw) < table_len:
        raise SnapshotError(
            f"{path}: truncated section table "
            f"({HEADER_LEN + len(raw)} < {HEADER_LEN + table_len} bytes)")
    entries = []
    for i in range(count):
        if v2:
            sid, code, off, nbytes, codec_id, _rsvd, raw_nbytes = \
                struct.unpack_from(entry_fmt, raw, i * entry_len)
        else:
            sid, code, off, nbytes = struct.unpack_from(entry_fmt, raw,
                                                        i * entry_len)
            codec_id, raw_nbytes = 0, nbytes
        entries.append((sid, code, off, nbytes, codec_id, raw_nbytes))
    return version, flags, v, e, entries


def section_frame_counts(path: str) -> Dict[str, int]:
    """Per-section frame counts for a snapshot's *compressed* sections:
    ``{section_name: frame_count}`` (empty for v1 / all-raw files).

    Reads the header, the section table, and each compressed section's
    12-byte frame headers (``codecs.frame_table`` walks them, skipping
    every compressed payload) — never decompresses anything.  This is
    the partial-decode planner's view of the file, surfaced through
    ``GraphSource.info()``.
    """
    from . import codecs
    _version, _flags, _v, _e, entries = peek_table(path)
    out: Dict[str, int] = {}
    data = None
    for sid, _code, off, nbytes, codec_id, _raw in entries:
        if codec_id == 0 or sid not in SECTION_NAMES:
            continue
        if data is None:
            data = mmap_bytes(path)
        out[SECTION_NAMES[sid]] = codecs.count_frames(
            data[off:off + nbytes],
            context=f"{path} section {sid}")
    return out


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def save_snapshot(
    path: str,
    *,
    edgelist: Optional[EdgeList] = None,
    csr: Optional[CSR] = None,
    compress: Optional[str] = None,
    compress_level: Optional[int] = None,
    frame_beta: Optional[int] = None,
) -> None:
    """Write a ``.gvel`` snapshot from loader outputs.

    At least one of ``edgelist`` / ``csr`` is required; pass both to get
    a file that serves *every* ``load_*`` entry point (``load_csr``
    prefers the embedded CSR and skips the build entirely).  Vertex ids
    are stored as-is — loader outputs are already 0-based.  A CSR must
    be global (``row_start == 0``); shard-local CSRs have no file-level
    meaning.

    ``compress`` names a registered codec (``"zlib"``, ``"zstd"`` when
    available); section payloads are then stored as checksummed frame
    streams (``core.codecs``) and the file is written as version 2.
    With ``compress=None`` (default) the output is a byte-identical
    version-1 file — readable by any v1 reader.
    """
    if edgelist is None and csr is None:
        raise ValueError("save_snapshot needs an edgelist, a csr, or both")

    sections: List[Tuple[int, np.ndarray]] = []
    flags = 0
    num_vertices = None
    num_edges = None

    if edgelist is not None:
        n = int(edgelist.num_edges)
        src = np.ascontiguousarray(np.asarray(edgelist.src[:n], dtype="<i4"))
        dst = np.ascontiguousarray(np.asarray(edgelist.dst[:n], dtype="<i4"))
        sections += [(SEC_SRC, src), (SEC_DST, dst)]
        if edgelist.weights is not None:
            w = np.ascontiguousarray(np.asarray(edgelist.weights[:n],
                                                dtype="<f4"))
            sections.append((SEC_EDGE_WEIGHTS, w))
            flags |= FLAG_WEIGHTED
        flags |= FLAG_EDGELIST
        num_vertices = int(edgelist.num_vertices)
        num_edges = n

    if csr is not None:
        if csr.row_start != 0:
            raise ValueError("save_snapshot: shard-local CSR (row_start != 0) "
                             "cannot be snapshotted")
        offsets = np.ascontiguousarray(np.asarray(csr.offsets, dtype="<i8"))
        indices = np.ascontiguousarray(np.asarray(csr.targets, dtype="<i4"))
        if offsets.shape[0] != csr.num_vertices + 1:
            raise ValueError(
                f"save_snapshot: offsets length {offsets.shape[0]} != "
                f"num_vertices + 1 ({csr.num_vertices + 1})")
        if num_vertices is not None and num_vertices != csr.num_vertices:
            raise ValueError(
                f"save_snapshot: edgelist has {num_vertices} vertices, "
                f"csr has {csr.num_vertices}")
        if num_edges is not None and num_edges != indices.shape[0]:
            raise ValueError(
                f"save_snapshot: edgelist has {num_edges} edges, "
                f"csr has {indices.shape[0]} — snapshot one graph")
        csr_weighted = csr.weights is not None
        if edgelist is not None and csr_weighted != (edgelist.weights is not None):
            raise ValueError("save_snapshot: edgelist/csr weight presence "
                             "mismatch")
        sections += [(SEC_CSR_OFFSETS, offsets), (SEC_CSR_INDICES, indices)]
        if csr_weighted:
            cw = np.ascontiguousarray(np.asarray(csr.weights, dtype="<f4"))
            sections.append((SEC_CSR_WEIGHTS, cw))
            flags |= FLAG_WEIGHTED
        flags |= FLAG_CSR
        num_vertices = csr.num_vertices
        if num_edges is None:
            num_edges = int(indices.shape[0])

    if compress is not None:
        from . import codecs
        codec = codecs.get_codec(compress)
        beta = codecs.DEFAULT_FRAME_BETA if frame_beta is None else frame_beta
        version = VERSION_COMPRESSED
        payloads = [(sid, arr,
                     codecs.compress_frames(arr.tobytes(), codec,
                                            level=compress_level,
                                            frame_beta=beta))
                    for sid, arr in sections]
    else:
        codec = None
        version = VERSION
        payloads = [(sid, arr, None) for sid, arr in sections]

    # layout: header, table, then page-aligned sections in table order
    entry_len = SECTION_LEN if version == VERSION else SECTION_LEN_V2
    table = []
    off = HEADER_LEN + len(sections) * entry_len
    for sid, arr, comp in payloads:
        off = _align(off)
        stored = arr.nbytes if comp is None else len(comp)
        if version == VERSION:
            table.append((sid, _dtype_code(arr.dtype), off, stored))
        else:
            table.append((sid, _dtype_code(arr.dtype), off, stored,
                          codec.codec_id, 0, arr.nbytes))
        off += stored
    end = off

    with open(path, "wb") as f:
        f.write(struct.pack(HEADER_FMT, MAGIC, version, flags,
                            num_vertices, num_edges, len(sections), 0))
        fmt = SECTION_FMT if version == VERSION else SECTION_FMT_V2
        for entry in table:
            f.write(struct.pack(fmt, *entry))
        for (sid, arr, comp), entry in zip(payloads, table):
            f.seek(entry[2])
            f.write(arr.tobytes() if comp is None else comp)
        # zero-length tail sections may point past the last written byte;
        # extend so every (offset, offset + nbytes) range is in-file
        f.truncate(end)


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------

class _Section:
    """One section's payload cell.

    Uncompressed sections are materialized at table-parse time as
    zero-copy mmap views (the mmap itself is lazy — the kernel pages
    bytes in on first touch).  Compressed sections hold only their frame
    stream's byte range; :meth:`get` decodes (and CRC-checks) the
    payload on first access and memoizes the result, so a section the
    caller never touches is never decompressed — and corruption in it
    is never noticed (the deferred-error trade documented in
    ``docs/api.md``).

    :meth:`get_slice` is the selective-read path below :meth:`get`: an
    element range of an uncompressed section is a zero-copy sub-view,
    and an element range of a *compressed* section decodes only the
    frames its byte span overlaps (the frame headers form a seek index
    — ``codecs.frame_table``), caching decoded frames per frame so a
    stream of point reads never re-pays a frame's decompression.
    Decode paths are lock-guarded: concurrent readers of one section
    (the query-service cache shares handles across threads) each see
    fully-decoded, immutable arrays.
    """

    __slots__ = ("path", "sid", "dtype", "offset", "nbytes", "codec",
                 "raw_nbytes", "_data", "_arr", "_lock", "_ftable",
                 "_frames", "_frames_bytes", "_frame_hits",
                 "_frame_evictions")

    def __init__(self, path, sid, dtype, offset, nbytes, codec,
                 raw_nbytes, data):
        self.path = path
        self.sid = sid
        self.dtype = dtype
        self.offset = offset
        self.nbytes = nbytes
        self.codec = codec               # None = stored (codec_id 0)
        self.raw_nbytes = raw_nbytes
        self._data = data
        self._arr = (data[offset:offset + nbytes].view(dtype)
                     if codec is None else None)
        self._lock = threading.Lock()
        self._ftable = None              # codecs.FrameEntry seek index
        # frame idx -> raw bytes, LRU order, bounded by FRAME_CACHE_BYTES
        self._frames: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._frames_bytes = 0
        self._frame_hits = 0
        self._frame_evictions = 0

    @property
    def length(self) -> int:
        """Element count, known from the table alone (no payload)."""
        return self.raw_nbytes // self.dtype.itemsize

    @property
    def decoded(self) -> bool:
        return self._arr is not None

    def get(self) -> np.ndarray:
        if self._arr is None:
            with self._lock:
                if self._arr is not None:       # decoded while waiting
                    return self._arr
                # dynamic attribute lookup so tests can instrument the
                # decode path (repro.core.codecs.decompress_frames)
                from . import codecs
                try:
                    arr = codecs.decompress_frames(
                        self._data[self.offset:self.offset + self.nbytes],
                        self.raw_nbytes, self.codec,
                        context=f"{self.path} section {self.sid}")
                except ValueError as exc:
                    raise SnapshotError(
                        str(exc),
                        section=SECTION_NAMES.get(self.sid)) from None
                arr.flags.writeable = False  # parity with the mmap views
                self._frames.clear()         # full decode supersedes frames
                self._frames_bytes = 0
                self._arr = arr.view(self.dtype)
        return self._arr

    def _frame_table(self):
        if self._ftable is None:
            from . import codecs
            try:
                self._ftable = codecs.frame_table(
                    self._data[self.offset:self.offset + self.nbytes],
                    context=f"{self.path} section {self.sid}")
            except ValueError as exc:
                raise SnapshotError(
                    str(exc), section=SECTION_NAMES.get(self.sid)) from None
        return self._ftable

    def get_slice(self, lo: int, hi: int) -> np.ndarray:
        """Elements ``[lo, hi)`` of this section.

        Uncompressed (and already-fully-decoded) sections return a
        zero-copy sub-view.  Compressed sections decode **only the
        frames overlapping the element range's byte span** — resolved
        through the frame-header seek index, each decoded frame cached
        on the cell — and assemble the slice from them.  Corruption in
        frames the range never touches is never noticed (the partial
        analogue of the per-section deferred-error trade).
        """
        if not 0 <= lo <= hi <= self.length:
            raise IndexError(
                f"{self.path} section {self.sid}: element range "
                f"[{lo}, {hi}) outside [0, {self.length})")
        if self._arr is not None:
            return self._arr[lo:hi]
        isz = self.dtype.itemsize
        byte_lo, byte_hi = lo * isz, hi * isz
        if byte_lo == byte_hi:
            return np.empty(0, self.dtype)
        from . import codecs
        with self._lock:
            if self._arr is not None:           # raced with a full get()
                return self._arr[lo:hi]
            entries = self._frame_table()
            touched = codecs.frames_overlapping(entries, byte_lo, byte_hi)
            if not touched or touched[0].raw_off > byte_lo \
                    or touched[-1].raw_end < byte_hi:
                raise SnapshotError(
                    f"{self.path} section {self.sid}: frames cover "
                    f"{self.raw_nbytes} bytes but byte range "
                    f"[{byte_lo}, {byte_hi}) is not fully framed",
                    section=SECTION_NAMES.get(self.sid))
            payload = self._data[self.offset:self.offset + self.nbytes]
            parts = []
            for entry in touched:
                raw = self._frames.get(entry.index)
                if raw is None:
                    try:
                        # dynamic lookup: tests instrument decode_frame
                        # to assert ONLY the touched frames decode
                        raw = np.frombuffer(codecs.decode_frame(
                            payload, entry, self.codec,
                            context=f"{self.path} section {self.sid}"),
                            np.uint8)
                    except ValueError as exc:
                        raise SnapshotError(
                            str(exc),
                            section=SECTION_NAMES.get(self.sid)) from None
                    self._frames[entry.index] = raw
                    self._frames_bytes += raw.nbytes
                    # LRU bound: drop coldest memos past the byte cap.
                    # ``parts`` still references this read's frames, so
                    # eviction only forgets, never corrupts, the slice
                    # being assembled.
                    cap = max(int(FRAME_CACHE_BYTES), 0)
                    while self._frames_bytes > cap and len(self._frames) > 1:
                        _, old = self._frames.popitem(last=False)
                        self._frames_bytes -= old.nbytes
                        self._frame_evictions += 1
                else:
                    self._frame_hits += 1
                    self._frames.move_to_end(entry.index)
                parts.append(raw)
            base = touched[0].raw_off
            buf = parts[0] if len(parts) == 1 else np.concatenate(parts)
            out = buf[byte_lo - base:byte_hi - base].view(self.dtype)
            out.flags.writeable = False
            return out


class Snapshot:
    """A validated, mmap-backed handle on a ``.gvel`` file.

    Structure (header, section table, section presence and lengths) is
    validated at open without touching any payload bytes.  Payload
    access is **lazy per section**: v1 / uncompressed sections are
    zero-copy views straight into the page cache, compressed v2
    sections are decompressed — and checksummed — on first access of
    the corresponding property (``src``/``dst``/``edge_weights``/
    ``csr_offsets``/``csr_indices``/``csr_weights``) and memoized.
    Touching only the CSR properties of a both-sections snapshot never
    decodes the edgelist frame streams (and vice versa).

    The trade: corruption inside a compressed payload surfaces at first
    access of *that section* (as :class:`SnapshotError`), not at open.
    Call :meth:`materialize` — or use ``read_snapshot(path)``, which is
    eager by default — to force every checksum up front.
    """

    def __init__(self, path: str, version: int, flags: int,
                 num_vertices: int, num_edges: int,
                 sections: "dict[int, _Section]"):
        self.path = path
        self.version = version
        self.flags = flags
        self.num_vertices = num_vertices
        self.num_edges = num_edges
        self._sections = sections

    def _get(self, sid: int) -> Optional[np.ndarray]:
        cell = self._sections.get(sid)
        if cell is None:
            return None
        first = not cell.decoded
        arr = cell.get()
        if first and sid == SEC_CSR_OFFSETS:
            try:
                self._check_csr_offsets(arr)
            except SnapshotError:
                # stay fatal on retry: a memoized-but-inconsistent array
                # must never be served by the next access
                cell._arr = None
                raise
        return arr

    def _check_csr_offsets(self, arr: np.ndarray) -> None:
        if arr.shape[0] and int(arr[-1]) != self.num_edges:
            raise SnapshotError(
                f"{self.path}: csr offsets end at {int(arr[-1])}, "
                f"header says {self.num_edges} edges",
                section="csr_offsets")

    # lazy payload properties ------------------------------------------------
    @property
    def src(self) -> Optional[np.ndarray]:
        return self._get(SEC_SRC)

    @property
    def dst(self) -> Optional[np.ndarray]:
        return self._get(SEC_DST)

    @property
    def edge_weights(self) -> Optional[np.ndarray]:
        return self._get(SEC_EDGE_WEIGHTS)

    @property
    def csr_offsets(self) -> Optional[np.ndarray]:
        return self._get(SEC_CSR_OFFSETS)

    @property
    def csr_indices(self) -> Optional[np.ndarray]:
        return self._get(SEC_CSR_INDICES)

    @property
    def csr_weights(self) -> Optional[np.ndarray]:
        return self._get(SEC_CSR_WEIGHTS)

    # ------------------------------------------------------------------------
    @property
    def weighted(self) -> bool:
        return bool(self.flags & FLAG_WEIGHTED)

    @property
    def has_edgelist(self) -> bool:
        return bool(self.flags & FLAG_EDGELIST)

    @property
    def has_csr(self) -> bool:
        return bool(self.flags & FLAG_CSR)

    def decoded_sections(self) -> "list[int]":
        """Section ids whose payloads have been materialized (for
        uncompressed sections that is every present id — views cost
        nothing).  Instrumentation hook for tests and benchmarks."""
        return sorted(sid for sid, c in self._sections.items() if c.decoded)

    def section_codecs(self) -> "list[str]":
        """Distinct codec names used by compressed sections."""
        return sorted({c.codec.name for c in self._sections.values()
                       if c.codec is not None})

    def frame_cache_stats(self) -> Dict[str, int]:
        """Decoded-frame memo counters summed over sections:
        ``frames`` / ``bytes`` currently held (bounded per section by
        ``FRAME_CACHE_BYTES``), ``hits`` (reads served from a memo) and
        ``evictions`` (memos dropped past the cap) since open.  The
        serving cache (:meth:`repro.core.cache.SourceCache.stats`)
        aggregates this across its hot handles."""
        out = {"frames": 0, "bytes": 0, "hits": 0, "evictions": 0}
        for c in self._sections.values():
            out["frames"] += len(c._frames)
            out["bytes"] += c._frames_bytes
            out["hits"] += c._frame_hits
            out["evictions"] += c._frame_evictions
        return out

    def materialize(self) -> "Snapshot":
        """Force-decode (and checksum) every section; returns self.
        After this, corruption anywhere in the file has either raised
        or cannot exist — the eager ``read_snapshot`` contract."""
        for sid in sorted(self._sections):
            self._get(sid)
        return self

    def edgelist(self) -> EdgeList:
        if not self.has_edgelist:
            raise SnapshotError(f"{self.path}: CSR-only snapshot has no "
                                f"edgelist sections")
        return EdgeList(self.src, self.dst, self.edge_weights,
                        np.int64(self.num_edges), self.num_vertices)

    def csr(self) -> CSR:
        if not self.has_csr:
            raise SnapshotError(f"{self.path}: snapshot has no CSR sections")
        return CSR(self.csr_offsets, self.csr_indices, self.csr_weights,
                   self.num_vertices)

    # selective reads --------------------------------------------------------
    def _offsets_slice(self, lo: int, hi: int) -> np.ndarray:
        """``offsets[lo:hi+1]`` via partial decode, with the same
        consistency guarantees the full read enforces, scoped to the
        slice: monotone, within ``[0, num_edges]``."""
        off = self._sections[SEC_CSR_OFFSETS].get_slice(lo, hi + 1)
        # point reads slice 2-3 elements; ufunc dispatch would dominate
        # them, so check tiny slices in plain Python
        bad = False
        if off.size:
            if int(off[0]) < 0 or int(off[-1]) > self.num_edges:
                bad = True
            elif off.size <= 4:
                prev = int(off[0])
                for x in off[1:]:
                    x = int(x)
                    if x < prev:
                        bad = True
                        break
                    prev = x
            else:
                bad = bool(np.any(np.diff(off) < 0))
        if bad:
            raise SnapshotError(
                f"{self.path}: csr offsets [{lo}, {hi}] are inconsistent "
                f"(non-monotone or outside [0, {self.num_edges}])")
        return off

    def csr_rows(self, lo: int, hi: int, *,
                 weighted: Optional[bool] = None) -> CSR:
        """The CSR restricted to vertex rows ``[lo, hi)``, decoding (and
        for raw snapshots, touching) only the bytes those rows span.

        Returns a row-local :class:`CSR` — ``offsets`` rebased to 0,
        ``row_start=lo``, global ``num_vertices`` — exactly the
        shard-local layout the distributed loader emits, so
        ``csr.neighbors(u - lo)`` works unchanged.  For uncompressed
        sections the targets/weights come back as zero-copy mmap
        sub-views; compressed sections decode only the frames the row
        range's byte span overlaps (frames are cached per section, so
        repeated point reads are decode-free).  ``weighted=None`` means
        "what the snapshot says".
        """
        if not self.has_csr:
            raise SnapshotError(f"{self.path}: snapshot has no CSR sections")
        if not 0 <= lo <= hi <= self.num_vertices:
            raise IndexError(
                f"{self.path}: row range [{lo}, {hi}) outside "
                f"[0, {self.num_vertices})")
        if weighted is None:
            weighted = self.weighted
        elif weighted and not self.weighted:
            raise SnapshotError(
                f"{self.path}: weighted rows requested but snapshot is "
                f"unweighted")
        off = self._offsets_slice(lo, hi)
        e_lo = int(off[0]) if off.size else 0
        e_hi = int(off[-1]) if off.size else 0
        targets = self._sections[SEC_CSR_INDICES].get_slice(e_lo, e_hi)
        w = (self._sections[SEC_CSR_WEIGHTS].get_slice(e_lo, e_hi)
             if weighted else None)
        local = off if e_lo == 0 else off - np.int64(e_lo)
        return CSR(local, targets, w, self.num_vertices, row_start=lo)

    def neighbors(self, u: int, *, weighted: bool = False):
        """Point lookup: vertex ``u``'s neighbor ids (and weights when
        asked), decoding only the frames the adjacency span touches."""
        row = self.csr_rows(int(u), int(u) + 1, weighted=weighted)
        return (row.targets, row.weights) if weighted else row.targets

    def degree(self, u: int) -> int:
        """Out-degree of ``u`` — touches exactly two offset elements
        (at most the offset frames they fall in)."""
        if not self.has_csr:
            raise SnapshotError(f"{self.path}: snapshot has no CSR sections")
        if not 0 <= int(u) < self.num_vertices:
            raise IndexError(f"{self.path}: vertex {u} outside "
                             f"[0, {self.num_vertices})")
        off = self._offsets_slice(int(u), int(u) + 1)
        return int(off[1]) - int(off[0])


def read_snapshot(path: str, *, eager: bool = True) -> Snapshot:
    """mmap + validate a ``.gvel`` file.

    Structure — header, table, section presence, and element counts —
    is always validated here, *without* reading payload bytes (counts
    come from the table's ``raw_nbytes``).  With ``eager=True`` (the
    default, and the historical contract) every compressed section is
    also decompressed and checksummed before returning, so corruption
    anywhere surfaces at open.  With ``eager=False`` the returned
    :class:`Snapshot` decodes each compressed section on first access
    instead — a both-sections snapshot opened for its prebuilt CSR
    never pays for its edgelist frames (the ``GraphSource`` lazy path;
    see ``docs/api.md`` for the deferred-corruption-error semantics).
    """
    version, flags, num_vertices, num_edges, count = peek_header(path)
    size = os.path.getsize(path)
    v2 = version == VERSION_COMPRESSED
    entry_fmt = SECTION_FMT_V2 if v2 else SECTION_FMT
    entry_len = SECTION_LEN_V2 if v2 else SECTION_LEN
    table_end = HEADER_LEN + count * entry_len
    if size < table_end:
        raise SnapshotError(
            f"{path}: truncated section table ({size} < {table_end} bytes)")
    data = mmap_bytes(path)
    raw = data[HEADER_LEN:table_end].tobytes()

    cells: dict = {}
    for i in range(count):
        if v2:
            sid, code, off, nbytes, codec_id, rsvd, raw_nbytes = \
                struct.unpack_from(entry_fmt, raw, i * entry_len)
            if rsvd != 0:
                raise SnapshotError(f"{path}: section {sid} has nonzero "
                                    f"reserved table field")
        else:
            sid, code, off, nbytes = struct.unpack_from(entry_fmt, raw,
                                                        i * entry_len)
            codec_id, raw_nbytes = 0, nbytes
        if sid not in (SEC_SRC, SEC_DST, SEC_EDGE_WEIGHTS, SEC_CSR_OFFSETS,
                       SEC_CSR_INDICES, SEC_CSR_WEIGHTS):
            continue                    # forward compat: skip unknown sections
        if code not in _CODE_TO_DTYPE:
            raise SnapshotError(f"{path}: section {sid} has unknown dtype "
                                f"code {code}")
        dtype = _CODE_TO_DTYPE[code]
        if off % ALIGN:
            raise SnapshotError(f"{path}: section {sid} offset {off} is not "
                                f"{ALIGN}-byte aligned")
        if off + nbytes > size:
            raise SnapshotError(
                f"{path}: truncated — section {sid} spans "
                f"[{off}, {off + nbytes}) but file is {size} bytes")
        if raw_nbytes % dtype.itemsize:
            raise SnapshotError(f"{path}: section {sid} length {raw_nbytes} "
                                f"is not a multiple of {dtype.itemsize}")
        if codec_id == 0:
            if raw_nbytes != nbytes:
                raise SnapshotError(
                    f"{path}: uncompressed section {sid} declares "
                    f"{raw_nbytes} raw bytes but stores {nbytes}")
            codec = None
        else:
            # the codec must resolve at open (it is table metadata, not
            # payload) — a file needing an uninstalled codec fails fast
            from . import codecs
            try:
                codec = codecs.codec_for_id(codec_id)
            except ValueError as exc:
                raise SnapshotError(f"{path}: section {sid}: {exc}") from None
        cells[sid] = _Section(path, sid, dtype, off, nbytes, codec,
                              raw_nbytes, data)

    def expect(sid: int, name: str, length: int) -> None:
        cell = cells.get(sid)
        if cell is None:
            raise SnapshotError(f"{path}: flagged {name} section missing")
        if cell.length != length:
            raise SnapshotError(f"{path}: {name} has {cell.length} elements, "
                                f"header implies {length}")

    if flags & FLAG_EDGELIST:
        expect(SEC_SRC, "src", num_edges)
        expect(SEC_DST, "dst", num_edges)
        if flags & FLAG_WEIGHTED:
            expect(SEC_EDGE_WEIGHTS, "edge-weights", num_edges)
    if flags & FLAG_CSR:
        expect(SEC_CSR_OFFSETS, "csr-offsets", num_vertices + 1)
        expect(SEC_CSR_INDICES, "csr-indices", num_edges)
        if flags & FLAG_WEIGHTED:
            expect(SEC_CSR_WEIGHTS, "csr-weights", num_edges)
    snap = Snapshot(path, version, flags, num_vertices, num_edges, cells)
    if flags & FLAG_CSR and cells[SEC_CSR_OFFSETS].decoded:
        # uncompressed offsets are views already — check them at open,
        # exactly as the eager reader always did
        snap._check_csr_offsets(cells[SEC_CSR_OFFSETS].get())
    return snap.materialize() if eager else snap


# ---------------------------------------------------------------------------
# loader engine
# ---------------------------------------------------------------------------

class SnapshotEngine:
    """Zero-parse loader engine over ``.gvel`` snapshots.

    ``base`` is accepted for interface parity and ignored — snapshot ids
    are canonical 0-based.  ``offset`` must be 0 (snapshots are never a
    body embedded in another file).
    """

    name = "snapshot"

    def __init__(self):
        self._memo: Optional[Tuple[tuple, Snapshot]] = None

    def _snap(self, path: str) -> Snapshot:
        """One open + validation per file per ``load_csr`` call: the
        front door probes ``read_csr_prebuilt`` / ``num_vertices_hint``
        / ``stream`` in sequence, so memoize on (path, mtime, size).
        A stale entry only costs a re-read.  Snapshots are opened
        *lazily* (``eager=False``): compressed v2 sections decode on
        first access, so serving a prebuilt CSR from a both-sections
        snapshot never decompresses its edgelist frames.  The memo pins
        one mmap plus whatever sections have been decoded so far —
        call :meth:`clear_memo` to release them early.  The (key,
        value) pair is written as one tuple so concurrent loads of
        different files race only on which entry survives, never on a
        mixed key/value.
        """
        st = os.stat(path)
        key = (path, st.st_mtime_ns, st.st_size)
        memo = self._memo
        if memo is not None and memo[0] == key:
            return memo[1]
        snap = read_snapshot(path, eager=False)
        self._memo = (key, snap)
        return snap

    def clear_memo(self) -> None:
        """Drop the memoized snapshot (frees a compressed v2 snapshot's
        decompressed arrays; the next load re-reads the file)."""
        self._memo = None

    @staticmethod
    def _check(snap: Snapshot, *, weighted: bool, offset: int) -> None:
        if offset:
            raise ValueError("snapshot engine does not support offset reads")
        if weighted and not snap.weighted:
            raise SnapshotError(
                f"{snap.path}: weighted load requested but snapshot is "
                f"unweighted")

    def read_edgelist(self, path: str, *, weighted: bool = False,
                      base: int = 0, num_vertices: Optional[int] = None,
                      offset: int = 0, **kw) -> EdgeList:
        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if not snap.has_edgelist:
            raise SnapshotError(f"{snap.path}: CSR-only snapshot has no "
                                f"edgelist sections")
        # touch only what the caller asked for: an unweighted read of a
        # weighted compressed snapshot never decodes the weights section
        w = snap.edge_weights if weighted else None
        v = snap.num_vertices if num_vertices is None else num_vertices
        return EdgeList(snap.src, snap.dst, w, np.int64(snap.num_edges), v)

    def num_vertices_hint(self, path: str) -> int:
        """Header-only |V| — lets the fused ``load_csr`` keep isolated
        trailing vertices a max-id scan over the edges would drop."""
        return self._snap(path).num_vertices

    def stream(self, path: str, *, weighted: bool = False, base: int = 0,
               offset: int = 0, **kw):
        """mmap -> packed device buffers for the fused ``load_csr`` path.

        The buffers are exact-length (no -1 tail padding), which the
        rank-based builders accept: padding handling is a no-op when
        there is none.
        """
        import jax.numpy as jnp

        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if snap.num_edges > np.iinfo(np.int32).max:
            # Same int32 regime as the text streaming engine's capacity
            # guard: the fused path's running total is a device int32.
            raise ValueError(
                f"{path}: {snap.num_edges} edges exceeds int32 for the fused "
                f"load_csr path; embed a prebuilt CSR in the snapshot "
                f"(scripts/convert.py default) or use load_edgelist")
        if not snap.has_edgelist:
            raise SnapshotError(f"{snap.path}: CSR-only snapshot has no "
                                f"edgelist sections")
        src = jnp.asarray(snap.src)
        dst = jnp.asarray(snap.dst)
        w = jnp.asarray(snap.edge_weights) if weighted else None
        total = jnp.asarray(snap.num_edges, jnp.int32)
        return (src, dst, w, total), snap.num_edges

    def read_csr_prebuilt(self, path: str, *, weighted: bool = False,
                          num_vertices: Optional[int] = None, offset: int = 0,
                          **kw) -> Optional[CSR]:
        """Embedded-CSR fast path: mmap views, no parse, no build.

        Returns None (caller falls back to the stream + build path) when
        the snapshot has no CSR sections or the caller pinned a
        different ``num_vertices`` than the stored CSR was built for.
        """
        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if not snap.has_csr:
            return None
        if num_vertices is not None and num_vertices != snap.num_vertices:
            return None
        # section-selective: only the CSR cells decode (never the
        # edgelist frames of a both-sections snapshot), and the weights
        # section only when the caller asked for weights
        return CSR(snap.csr_offsets, snap.csr_indices,
                   snap.csr_weights if weighted else None,
                   snap.num_vertices)

    def read_csr_rows(self, path: str, lo: int, hi: int, *,
                      weighted: bool = False,
                      num_vertices: Optional[int] = None, offset: int = 0,
                      **kw) -> Optional[CSR]:
        """Selective fast path: rows ``[lo, hi)`` straight off the
        snapshot — mmap sub-views for raw sections, frame-selective
        decode for compressed ones.  Returns None (caller slices the
        full product instead) when the snapshot has no CSR sections or
        the caller pinned a conflicting ``num_vertices``."""
        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if not snap.has_csr:
            return None
        if num_vertices is not None and num_vertices != snap.num_vertices:
            return None
        return snap.csr_rows(lo, hi, weighted=weighted)

    def read_neighbors(self, path: str, u: int, *, weighted: bool = False,
                       num_vertices: Optional[int] = None, offset: int = 0,
                       **kw):
        """Point-lookup fast path: ``(targets, weights-or-None)`` for
        vertex ``u``, or None when no CSR sections are embedded."""
        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if not snap.has_csr:
            return None
        if num_vertices is not None and num_vertices != snap.num_vertices:
            return None
        row = snap.csr_rows(int(u), int(u) + 1, weighted=weighted)
        return row.targets, row.weights

    def read_degree(self, path: str, u: int, *, weighted: bool = False,
                    num_vertices: Optional[int] = None, offset: int = 0,
                    **kw) -> Optional[int]:
        """Degree fast path: two offset elements, no target bytes."""
        snap = self._snap(path)
        self._check(snap, weighted=weighted, offset=offset)
        if not snap.has_csr:
            return None
        if num_vertices is not None and num_vertices != snap.num_vertices:
            return None
        return snap.degree(u)
