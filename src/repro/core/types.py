"""Core data types for the GVEL graph-loading substrate.

EdgeList and CSR are registered pytrees so they flow through jit/shard_map.
Vertex ids are int32 (|V| < 2**31); shard-local edge counts are int32;
*global* offsets that may exceed 2**31 live on host as numpy int64.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EdgeList:
    """COO edges. ``weights`` is None for unweighted graphs.

    ``num_edges`` may be a traced scalar (valid prefix length) when the
    arrays are fixed-capacity buffers, mirroring GVEL's over-allocation.
    """

    src: Any                      # (E_cap,) int32
    dst: Any                      # (E_cap,) int32
    weights: Optional[Any]        # (E_cap,) float32 or None
    num_edges: Any                # () int32 — valid prefix
    num_vertices: int             # static

    def tree_flatten(self):
        leaves = (self.src, self.dst, self.weights, self.num_edges)
        return leaves, (self.num_vertices,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        src, dst, weights, num_edges = leaves
        return cls(src, dst, weights, num_edges, aux[0])

    @property
    def capacity(self) -> int:
        return self.src.shape[0]

    def materialize(self) -> "EdgeList":
        """Trim buffers to the valid prefix (host-side)."""
        n = int(self.num_edges)
        w = None if self.weights is None else np.asarray(self.weights[:n])
        return EdgeList(np.asarray(self.src[:n]), np.asarray(self.dst[:n]), w,
                        np.int64(n), self.num_vertices)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency.

    offsets[u] .. offsets[u+1] index into targets/weights for vertex u.
    For shard-local CSRs, ``row_start`` records the first global vertex id
    owned by this shard (vertex-partitioned layout).
    """

    offsets: Any                  # (V_local + 1,) int32/int64
    targets: Any                  # (E_local,) int32
    weights: Optional[Any]        # (E_local,) float32 or None
    num_vertices: int             # global |V| (static)
    row_start: int = 0            # first owned vertex (static)

    def tree_flatten(self):
        return (self.offsets, self.targets, self.weights), (self.num_vertices, self.row_start)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        offsets, targets, weights = leaves
        return cls(offsets, targets, weights, aux[0], aux[1])

    @property
    def num_rows(self) -> int:
        return self.offsets.shape[0] - 1

    def degree(self, u) -> Any:
        return self.offsets[u + 1] - self.offsets[u]

    def neighbors(self, u):
        lo, hi = int(self.offsets[u]), int(self.offsets[u + 1])
        return self.targets[lo:hi]

    def degrees(self) -> Any:
        return self.offsets[1:] - self.offsets[:-1]


@dataclasses.dataclass(frozen=True)
class GraphMeta:
    """Header information for a graph file."""

    num_vertices: int
    num_edges: int                # as declared (pre symmetric expansion)
    weighted: bool
    symmetric: bool
    base: int = 1                 # vertex-id base in the file (MTX is 1-based)
    pattern: bool = False         # MTX 'pattern' — no weight column


def csr_from_dense(adj: np.ndarray) -> CSR:
    """Reference CSR from a dense adjacency count matrix (tests only)."""
    adj = np.asarray(adj)
    v = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.int64)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    targets = np.repeat(
        np.tile(np.arange(v), v), adj.reshape(-1).astype(np.int64)
    ) if adj.size else np.zeros(0, np.int64)
    # np.repeat over tiled columns: rebuild row-major properly
    cols = []
    for u in range(v):
        row = np.repeat(np.arange(v), adj[u])
        cols.append(row)
    targets = np.concatenate(cols) if cols else np.zeros(0, np.int64)
    return CSR(offsets, targets.astype(np.int32), None, v)
