"""Single-pass vectorized edgelist parsing in numpy (host fast path).

The same mask/scan algebra as :mod:`repro.core.parse`, expressed with
numpy's C kernels and tuned for memory traffic: uint8 wrap tricks instead
of widening casts, int32 cumsums, shifted-slice token boundaries instead
of diff temporaries, power-of-ten lookup tables instead of per-element
pow, and boundary positions derived from prefix sums instead of
searchsorted.  This is the performant CPU realization of GVEL's
single-pass custom parser; the jnp/Pallas versions are its device twins.

Chunks handed to this parser must be split at newline boundaries (the
caller uses ``bytes.rfind(b'\\n')`` — the literal getBlock analogue).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

_POW10 = 10 ** np.arange(19, dtype=np.int64)
_POW10F = 10.0 ** np.arange(19)

# one-gather byte classification (replaces ~13 compare/or passes with 4
# table lookups — the vector analogue of GVEL's custom parser dispatch)
_IS_DIGIT = np.zeros(256, bool)
_IS_DIGIT[48:58] = True
_IS_TOK = _IS_DIGIT.copy()
_IS_TOK[[45, 46]] = True
_IS_NL = np.zeros(256, bool)
_IS_NL[10] = True
_IS_BAD = ~_IS_TOK
_IS_BAD[[10, 32, 9, 13]] = False


def parse_chunk_np(
    data: np.ndarray,
    *,
    weighted: bool,
    base: int = 1,
) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray], int]:
    """Parse a newline-terminated chunk -> (src, dst, w, count).  int64 ids."""
    d = np.asarray(data)
    n = d.shape[0]
    empty = (np.zeros(0, np.int64), np.zeros(0, np.int64),
             np.zeros(0, np.float64) if weighted else None, 0)
    if n == 0:
        return empty

    # ---- byte classes: one table gather per class ----------------------------
    is_digit = _IS_DIGIT[d]
    is_nl = _IS_NL[d]
    is_tok = _IS_TOK[d]

    # ---- token boundaries: single xor pass + small gathers --------------------
    flips = np.flatnonzero(is_tok[1:] != is_tok[:-1]) + 1
    if is_tok[0]:
        flips = np.concatenate(([0], flips))
    if is_tok[-1]:
        flips = np.concatenate((flips, [n]))
    tok_starts = flips[0::2]
    tok_ends = flips[1::2] - 1
    T = tok_starts.size
    if T == 0:
        return empty
    tok_len = tok_ends - tok_starts + 1

    # ---- integer values: digit * 10^(digits after it in the token) ----------
    cum_dig = np.cumsum(is_digit, dtype=np.int32)   # chunk < 2^31 bytes
    tok_bytes = np.flatnonzero(is_tok)
    end_per_elem = np.repeat(tok_ends, tok_len)
    digits_after = (cum_dig[end_per_elem] - cum_dig[tok_bytes]).astype(np.int64)
    dv = d[tok_bytes].astype(np.int64) - 48
    dmask = is_digit[tok_bytes]
    contrib = np.where(dmask, dv, 0) * _POW10[np.minimum(digits_after, 18)]
    tok_offsets = np.zeros(T, np.int64)
    np.cumsum(tok_len[:-1], out=tok_offsets[1:])
    tok_int = np.add.reduceat(contrib, tok_offsets)

    if weighted:
        frac_len = np.zeros(T, np.int64)
        dot_bytes = np.flatnonzero(is_tok & (d == 46))
        if dot_bytes.size:
            tok_of_dot = np.searchsorted(tok_starts, dot_bytes,
                                         side="right") - 1
            frac_len[tok_of_dot] = cum_dig[tok_ends[tok_of_dot]] \
                - cum_dig[dot_bytes]
        neg = np.zeros(T, bool)
        minus_bytes = np.flatnonzero(is_tok & (d == 45))
        if minus_bytes.size:
            neg[np.searchsorted(tok_starts, minus_bytes, side="right") - 1] = True
        tok_float = tok_int / _POW10F[np.minimum(frac_len, 18)]
        tok_float = np.where(neg, -tok_float, tok_float)

    # ---- line assembly (prefix-sum line ids; tokens are line-sorted) --------
    cum_nl = np.cumsum(is_nl, dtype=np.int32)
    num_lines = int(cum_nl[-1]) + (0 if is_nl[-1] else 1)
    tok_line = cum_nl[tok_starts]            # newlines before start
    ntok = np.bincount(tok_line, minlength=num_lines)
    first_tok = np.zeros(num_lines, np.int64)
    np.cumsum(ntok[:-1], out=first_tok[1:])
    ord_in_line = np.arange(T) - first_tok[tok_line]

    valid = ntok >= 2
    # bad-byte rejection (comments, junk): rare — scan only when present
    bad_bytes = np.flatnonzero(_IS_BAD[d])
    if bad_bytes.size:
        valid[cum_nl[bad_bytes]] = False

    src_l = np.full(num_lines, -1, np.int64)
    dst_l = np.full(num_lines, -1, np.int64)
    sel0 = ord_in_line == 0
    sel1 = ord_in_line == 1
    src_l[tok_line[sel0]] = tok_int[sel0]
    dst_l[tok_line[sel1]] = tok_int[sel1]
    if weighted:
        w_l = np.ones(num_lines, np.float64)
        sel2 = ord_in_line == 2
        w_l[tok_line[sel2]] = tok_float[sel2]

    src = src_l[valid] - base
    dst = dst_l[valid] - base
    w = w_l[valid] if weighted else None
    return src, dst, w, int(valid.sum())


def chunk_bounds(data: np.ndarray, num_chunks: int) -> list[tuple[int, int]]:
    """Split a byte buffer into ~equal chunks at newline boundaries
    (host-literal getBlock: back off each cut to the previous newline)."""
    n = len(data)
    raw = np.linspace(0, n, num_chunks + 1).astype(np.int64)
    cuts = [0]
    view = data.tobytes() if not isinstance(data, (bytes, bytearray)) else data
    for c in raw[1:-1]:
        p = view.rfind(b"\n", 0, int(c))
        cuts.append(p + 1 if p >= 0 else 0)
    cuts.append(n)
    cuts = sorted(set(cuts))
    return [(cuts[i], cuts[i + 1]) for i in range(len(cuts) - 1)]
