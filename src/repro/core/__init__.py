"""GVEL core: fast graph loading in Edgelist and CSR formats, in JAX.

Public API:
    open_graph -> GraphSource            — THE front door: a lazy,
                                           introspectable handle; .info() /
                                           .edgelist() / .csr() / .stream() /
                                           .save() (see docs/api.md)
    LoadOptions, SourceInfo              — normalized option / metadata types
    load_edgelist, load_csr              — thin wrappers over a GraphSource;
                                           pick a parse engine by name
                                           (device | pallas | numpy |
                                           threads | snapshot)
    register_engine, available_engines   — the loader extension point
    save_snapshot, read_snapshot         — binary .gvel snapshots (zero-parse
                                           reload; see docs/snapshot-format.md)
    register_codec, available_codecs     — compression codec registry; gzip /
    write_framed, compress_file_framed     framed inputs load transparently
    read_edgelist, read_edgelist_numpy   — back-compat engine wrappers
    read_csr, convert_to_csr             — file/EdgeList -> CSR (staged)
    read_mtx, read_mtx_csr, mtx_to_snapshot — MatrixMarket with honored attrs
    load_csr_sharded_stream, load_csr_sharded, host_shard_and_load
                                         — multi-device vertex-partitioned CSR;
                                           the _stream variant shards the file's
                                           byte ranges so every stage (parse
                                           included) runs on the mesh
                                           (GraphSource.csr_sharded(mesh);
                                           docs/distributed.md)
    tune                                 — measured beta x batch_blocks
                                           autotuning for the streaming
                                           engines (open_graph(tune=True);
                                           docs/performance.md)
    env                                  — platform configuration (x64,
                                           backend, forced host devices,
                                           XLA flags) and the platform
                                           fingerprint keying tune
                                           profiles
    SourceCache, query, default_cache    — process-level hot-graph cache: a
                                           bounded LRU of open GraphSources
                                           serving point/range/full queries
                                           (query(path, "neighbors",
                                           vertex=v); docs/query.md)
    EdgeList, CSR, GraphMeta             — core types
"""
from .types import CSR, EdgeList, GraphMeta
from .loader import (load_edgelist, load_csr, register_engine, get_engine,
                     available_engines, LoaderEngine, LoadOptions)
from .source import open_graph, GraphSource, SourceInfo, slice_csr
from .cache import SourceCache, query, default_cache
from .edgelist import read_edgelist, read_edgelist_numpy, symmetrize
from .csr import convert_to_csr, read_csr, csr_to_dense
from .mtx import read_mtx, read_mtx_csr, write_mtx, mtx_to_snapshot
from .snapshot import save_snapshot, read_snapshot, Snapshot, SnapshotError
from .codecs import (register_codec, get_codec, available_codecs,
                     compress_file_framed, write_framed)
from .generate import make_graph_file, rmat_edges, uniform_edges, grid_edges, write_edgelist
from .distributed import (load_csr_sharded, load_csr_sharded_stream,
                          host_shard_and_load)
from .faults import (FaultPlan, FaultSpec, StageTimeout, ShardLoadError,
                     CorruptGraphError, set_fault_plan, fault_plan,
                     plan_from_env)
from . import (baselines, build, cache, codecs, compat, degrees, env, faults,
               loader, parse, parse_np, blocks, snapshot, source, tune)

__all__ = [
    "CSR", "EdgeList", "GraphMeta",
    "open_graph", "GraphSource", "SourceInfo", "LoadOptions", "slice_csr",
    "SourceCache", "query", "default_cache",
    "load_edgelist", "load_csr", "register_engine", "get_engine",
    "available_engines", "LoaderEngine",
    "save_snapshot", "read_snapshot", "Snapshot", "SnapshotError",
    "register_codec", "get_codec", "available_codecs",
    "compress_file_framed", "write_framed",
    "read_edgelist", "read_edgelist_numpy", "symmetrize",
    "convert_to_csr", "read_csr", "csr_to_dense",
    "read_mtx", "read_mtx_csr", "write_mtx", "mtx_to_snapshot",
    "make_graph_file", "rmat_edges", "uniform_edges", "grid_edges",
    "write_edgelist",
    "load_csr_sharded", "load_csr_sharded_stream", "host_shard_and_load",
    "FaultPlan", "FaultSpec", "StageTimeout", "ShardLoadError",
    "CorruptGraphError", "set_fault_plan", "fault_plan", "plan_from_env",
    "baselines", "build", "cache", "codecs", "compat", "degrees", "faults",
    "loader", "parse", "parse_np", "blocks", "snapshot", "source", "tune",
    "env",
]
