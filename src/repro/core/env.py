"""Computation-environment configuration for the loader stack.

One place for the process-level platform knobs the rest of the package
reads implicitly — float width, backend selection, forced host device
count, XLA flags — plus the *fingerprint* of the resolved platform that
keys every measured artifact (:mod:`repro.core.tune` autotuner
profiles).  Two rules:

* Setters that only take effect before the JAX backend initializes
  (:func:`set_platform`, :func:`set_host_devices`) say so and warn when
  called too late, instead of silently doing nothing.
* ``XLA_FLAGS`` is merged flag-by-flag, never clobbered — a user's
  pre-set flags survive ours and vice versa.

Typical use, before any jax import does real work::

    from repro.core import env
    env.set_host_devices(4)      # 4 forced host devices (sharded loads)
    env.set_platform("cpu")

and afterwards ``env.fingerprint()`` names the configuration —
``linux-x86_64-cpu8-cpu-d4-x32`` — so profiles measured under one
device split or float regime are never served to another.
"""
from __future__ import annotations

import os
import platform as _platform
import re
import warnings
from typing import Dict, Optional

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"

# XLA flags recommended for GPU latency hiding (jax gpu performance
# tips); harmless elsewhere but only applied when the gpu platform is
# selected explicitly.
_GPU_FLAGS = {
    "--xla_gpu_enable_latency_hiding_scheduler": "true",
    "--xla_gpu_triton_gemm_any": "True",
}


def _jax_initialized() -> bool:
    """Whether the JAX backend already committed to a platform/device
    split (late platform/device changes are silently ignored by jax)."""
    import jax
    try:
        return jax._src.xla_bridge._backends != {}
    except AttributeError:       # private layout moved; assume the worst
        return True


def get_xla_flags() -> Dict[str, Optional[str]]:
    """Parse ``XLA_FLAGS`` into a ``{flag: value}`` dict (value ``None``
    for bare flags)."""
    out: Dict[str, Optional[str]] = {}
    for tok in os.environ.get("XLA_FLAGS", "").split():
        name, sep, val = tok.partition("=")
        out[name] = val if sep else None
    return out


def set_xla_flag(name: str, value: Optional[str]) -> None:
    """Merge one flag into ``XLA_FLAGS`` (replacing that flag only)."""
    flags = get_xla_flags()
    flags[str(name)] = None if value is None else str(value)
    os.environ["XLA_FLAGS"] = " ".join(
        k if v is None else f"{k}={v}" for k, v in flags.items())


def enable_x64(flag: bool = True) -> None:
    """Switch the default JAX float/int width to 64 bits (or back).

    The loader stack is int32-native by design (see
    ``build.INT32_OFFSETS_LIMIT``); x64 matters for downstream numerics
    that consume the loaded graphs.  Takes effect immediately.
    """
    import jax
    jax.config.update("jax_enable_x64", bool(flag))


def set_debug_nan(flag: bool = True) -> None:
    """Raise on NaN production in jitted programs (debugging aid)."""
    import jax
    jax.config.update("jax_debug_nans", bool(flag))


def set_platform(name: str = "cpu") -> None:
    """Select the JAX platform ('cpu' | 'gpu' | 'tpu').

    Only effective before the backend initializes; a late call warns.
    Selecting ``gpu`` also merges the latency-hiding XLA flags from the
    jax GPU performance guide into ``XLA_FLAGS``.
    """
    import jax
    if _jax_initialized():
        warnings.warn("set_platform called after the JAX backend "
                      "initialized; the platform will not change",
                      RuntimeWarning, stacklevel=2)
    if name == "gpu":
        for k, v in _GPU_FLAGS.items():
            set_xla_flag(k, v)
    jax.config.update("jax_platform_name", name)


def set_host_devices(n: int) -> None:
    """Force the CPU backend to expose ``n`` devices (the sharded
    loader's mesh width).  Only effective before backend init; clamped
    to the physical core count with a warning, like the cores knob in
    every JAX environment helper."""
    n = int(n)
    cores = os.cpu_count() or 1
    if n > cores:
        warnings.warn(f"only {cores} CPUs available; forcing {cores} "
                      f"host devices instead of {n}",
                      RuntimeWarning, stacklevel=2)
        n = cores
    if _jax_initialized():
        warnings.warn("set_host_devices called after the JAX backend "
                      "initialized; the device count will not change",
                      RuntimeWarning, stacklevel=2)
    set_xla_flag(_DEVICE_COUNT_FLAG, str(max(n, 1)))


def forced_host_devices() -> Optional[int]:
    """The ``--xla_force_host_platform_device_count`` currently in
    ``XLA_FLAGS``, or None when unset (natural device count)."""
    val = get_xla_flags().get(_DEVICE_COUNT_FLAG)
    if val is None:
        return None
    m = re.fullmatch(r"\d+", val)
    return int(m.group()) if m else None


def platform_profile() -> Dict[str, object]:
    """The resolved platform configuration, as data.

    Everything that changes where the streaming loader's throughput
    knee sits: machine + core count (staging bandwidth), backend
    (which XLA lowers the fused parse), device count (XLA splits its
    host threadpool across forced devices), and the float-width regime.
    """
    import jax
    return {
        "system": _platform.system().lower(),
        "machine": _platform.machine(),
        "cpu_count": os.cpu_count() or 1,
        "backend": jax.default_backend(),
        "device_count": forced_host_devices() or jax.device_count(),
        "x64": bool(jax.config.jax_enable_x64),
    }


def fingerprint() -> str:
    """Canonical profile key for measured artifacts (tune profiles):
    ``{system}-{machine}-cpu{N}-{backend}-d{devices}-x{32|64}``."""
    p = platform_profile()
    return (f"{p['system']}-{p['machine']}-cpu{p['cpu_count']}"
            f"-{p['backend']}-d{p['device_count']}"
            f"-x{64 if p['x64'] else 32}")
