"""Edgelist -> CSR construction strategies (GVEL §4.2.3-4.2.4, TPU-adapted).

There is no fetch-add on TPU, so PIGO's "claim a slot atomically" becomes a
deterministic *rank*: edge e with source u lands at offsets[u] + (rank of e
among u's edges).  Ranks come from a stable sort, which makes construction
a pure gather/scatter with provably disjoint destinations.

* ``csr_global``    — one global stable sort over all edges
                      (single-stage; the PIGO-shaped baseline).
* ``csr_staged``    — GVEL's multi-stage build: edges are cut into rho
                      contiguous partitions; each partition sorts locally
                      (smaller sorts, independent -> parallel across cores
                      or devices) and is merged into the global CSR through
                      per-partition base offsets.  Stage-local work is
                      contention-free; only the merge touches shared state,
                      and its destinations are disjoint by construction.
* ``csr_binned``    — propagation-blocking-style binned build: vertices are
                      cut into contiguous ranges ("bins") of 2**bin_bits,
                      and edges are grouped one bin digit per level with the
                      cumulative-count algebra from the PR-5/PR-6 parse and
                      exchange paths — no argsort, no comparator sort with
                      payloads, and no scatters at all.  Each level packs
                      (digit << pos_bits) | position into one int32 and
                      value-sorts it (XLA's single-operand fast path, ~5x
                      the throughput of the comparator argsort on CPU); the
                      low bits of the sorted keys ARE the level permutation,
                      so composing levels and filling targets/weights is
                      pure gathers whose destinations are disjoint by
                      construction.  Offsets come from one degree histogram
                      + cumsum.  ~2x over ``csr_staged`` on the CI host.

Fixed-capacity buffers use src == -1 as padding; padding sorts to the end
(key |V|) and is dropped by capacity slicing.

Offsets dtype contract: the device builds accumulate offsets in int32 (the
natural device width); ``_check_offsets_width`` rejects edge counts that
could wrap instead of silently overflowing.  The host oracle emits int64.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import CSR

I32 = jnp.int32

# Device builds accumulate offsets as int32: cumsum(deg) wraps once the edge
# count reaches 2**31.  Checked at trace time (shapes are static) so the
# failure is a clear error, never a silently wrapped CSR.  Module-level so
# tests can exercise the guard without a 2B-edge graph.
INT32_OFFSETS_LIMIT = 2**31 - 1


def _check_offsets_width(num_edges: int) -> None:
    if num_edges > INT32_OFFSETS_LIMIT:
        raise ValueError(
            f"edge count {num_edges} exceeds int32 offsets "
            f"(limit {INT32_OFFSETS_LIMIT}); the device builds accumulate "
            "offsets in int32 — shard the load (load_csr_sharded) or build "
            "on host (csr_np) for graphs this large")


def _ceil_log2(n: int) -> int:
    return max(int(n - 1).bit_length(), 0)


def _rank_in_group(sorted_key: jax.Array, num_vertices: int) -> jax.Array:
    """rank of each sorted element within its equal-key run."""
    first = jnp.searchsorted(sorted_key, jnp.arange(num_vertices + 1, dtype=I32),
                             side="left")
    return jnp.arange(sorted_key.shape[0], dtype=I32) - first[
        jnp.clip(sorted_key, 0, num_vertices)]


@functools.partial(jax.jit, static_argnames=("num_vertices", "weighted"))
def csr_global(
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array],
    num_vertices: int,
    *,
    weighted: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Single-stage build: one global stable sort (baseline)."""
    _check_offsets_width(src.shape[0])
    v = num_vertices
    key = jnp.where(src >= 0, src, v).astype(I32)
    order = jnp.argsort(key, stable=True)
    targets = dst[order]
    w = weights[order] if weighted else None
    deg = jnp.zeros((v,), I32).at[key].add(1, mode="drop")
    offsets = jnp.concatenate([jnp.zeros((1,), I32), jnp.cumsum(deg, dtype=I32)])
    return offsets, targets, w


@functools.partial(jax.jit, static_argnames=("num_vertices", "rho", "weighted"))
def csr_staged(
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array],
    num_vertices: int,
    *,
    rho: int = 4,
    weighted: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """GVEL multi-stage build (Algorithm 2, rank-based).

    Stage 1: rho contiguous edge partitions, each locally sorted by source
             -> rho partition CSRs (vmapped: independent work).
    Stage 2: partition degrees -> global offsets + per-partition bases;
             every partition edge's destination =
             offsets[u] + (edges of u in earlier partitions) + local rank.
    The scatter destinations are disjoint, so the merge is race-free.
    """
    _check_offsets_width(src.shape[0])
    v = num_vertices
    e = src.shape[0]
    pcap = -(-e // rho)
    pad = rho * pcap - e
    key = jnp.where(src >= 0, src, v).astype(I32)
    if pad:
        key = jnp.concatenate([key, jnp.full((pad,), v, I32)])
        dst = jnp.concatenate([dst, jnp.full((pad,), -1, I32)])
        if weighted:
            weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
    key = key.reshape(rho, pcap)
    dstp = dst.reshape(rho, pcap)
    wp = weights.reshape(rho, pcap) if weighted else None

    # ---- stage 1: partition-local sorts (independent, parallelizable) ----
    if wp is None:
        wp = jnp.zeros_like(key, jnp.float32)   # dummy; DCE'd when unweighted

    def local(keys, dsts, ws):
        order = jnp.argsort(keys, stable=True)
        skey = keys[order]
        deg = jnp.zeros((v,), I32).at[skey].add(1, mode="drop")
        rank = _rank_in_group(skey, v)
        return skey, dsts[order], deg, rank, ws[order]

    skey, sdst, pdeg, rank, sw = jax.vmap(local)(key, dstp, wp)

    # ---- stage 2: global offsets + disjoint merge -------------------------
    deg = jnp.sum(pdeg, axis=0, dtype=I32)                       # (V,)
    offsets = jnp.concatenate([jnp.zeros((1,), I32), jnp.cumsum(deg, dtype=I32)])
    before = jnp.cumsum(pdeg, axis=0, dtype=I32) - pdeg          # (rho, V) excl
    base = offsets[:-1][None, :] + before                        # (rho, V)
    dest = jnp.take_along_axis(base, jnp.clip(skey, 0, v - 1), axis=1) + rank
    dest = jnp.where(skey < v, dest, e)                          # drop padding
    targets = jnp.full((e,), -1, I32).at[dest.reshape(-1)].set(
        sdst.reshape(-1), mode="drop")
    w = None
    if weighted:
        w = jnp.zeros((e,), weights.dtype).at[dest.reshape(-1)].set(
            sw.reshape(-1), mode="drop")
    return offsets, targets, w


def _bin_level_widths(v_bits: int, bin_bits: int, avail: int) -> Tuple[int, ...]:
    """Digit widths per level, low bits first.  Each level handles one
    ``bin_bits``-wide slice of the vertex id (clamped to ``avail``, the bits
    an int32 key has left after the position field and the padding
    sentinel); the top level's digit is the bin index itself."""
    width = max(1, min(bin_bits, avail))
    widths = []
    rem = max(v_bits, 1)
    while rem > 0:
        widths.append(min(width, rem))
        rem -= widths[-1]
    return tuple(widths)


@functools.partial(jax.jit, static_argnames=("num_vertices", "bin_bits",
                                             "weighted"))
def csr_binned(
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array],
    num_vertices: int,
    *,
    bin_bits: Optional[int] = None,
    weighted: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Propagation-blocking-style binned build (sort-free rank algebra).

    Vertices are cut into contiguous ranges of 2**bin_bits ("bins"); the
    build groups edges one bin digit per level, low bits first, so the
    final level buckets whole bins and every earlier level is the
    contention-free within-bin fill.  Per level the digit and the current
    position are packed into one int32 — (digit << pos_bits) | position —
    and value-sorted: positions make keys unique, so the (unstable,
    fast-path) value sort realizes exactly the stable cumulative-count
    rank, and the low bits of the sorted keys are the level's permutation.
    Levels compose by gather; targets/weights fill by gather through the
    final permutation (disjoint destinations by construction); offsets are
    one degree histogram + cumsum.  No argsort, no payload-carrying
    comparator sort, no scatters.

    Padding (src == -1) carries a sentinel digit in the top level only —
    lexicographically that is enough to sink it below every real edge.

    bin_bits defaults to the widest digit an int32 key can carry, which
    minimizes the level count (usually 1-2 levels).
    """
    _check_offsets_width(src.shape[0])
    v = num_vertices
    e = src.shape[0]
    v_bits = _ceil_log2(v)
    pos_bits = max(_ceil_log2(e), 1)
    avail = 31 - pos_bits - 1          # -1: top-level padding sentinel bit
    if avail < 1:
        raise ValueError(
            f"csr_binned needs ceil(log2(E)) <= 29 to pack int32 level keys "
            f"(E={e}); use csr_staged or shard the load")
    widths = _bin_level_widths(v_bits, avail if bin_bits is None else bin_bits,
                               avail)
    valid = src >= 0
    iota = jnp.arange(e, dtype=I32)
    pos_mask = (1 << pos_bits) - 1
    perm = iota
    shift = 0
    for li, width in enumerate(widths):
        cur = src if li == 0 else src[perm]
        dig = (cur >> shift) & ((1 << width) - 1)
        if li == len(widths) - 1:
            pad = valid if li == 0 else valid[perm]
            dig = jnp.where(pad, dig, 1 << width)
        key = (dig.astype(I32) << pos_bits) | iota
        level = jax.lax.sort(key) & pos_mask
        perm = level if li == 0 else perm[level]
        shift += width
    targets = dst[perm]
    w = weights[perm] if weighted else None
    deg = jnp.zeros((v,), I32).at[jnp.clip(src, 0, v - 1)].add(
        valid.astype(I32))
    offsets = jnp.concatenate([jnp.zeros((1,), I32), jnp.cumsum(deg, dtype=I32)])
    return offsets, targets, w


def csr_binned_np(src: np.ndarray, dst: np.ndarray,
                  weights: Optional[np.ndarray], num_vertices: int, *,
                  bin_bits: Optional[int] = None,
                  num_workers: int = 1) -> CSR:
    """Host binned build: bucket edges by contiguous vertex range, then
    fill each bin independently (cache-sized subproblems; threads across
    bins — numpy's sort releases the GIL).

    Bucketing is the cumulative-count rank, one pass per bin (B small):
    dest = bin_start[bin] + arrival rank within bin.  The per-bin fill
    value-sorts (local_id << 32) | within_bin_position packed into int64 —
    unique keys, so the plain value sort is the stable rank, and targets /
    weights land by gather through disjoint per-bin destinations."""
    from concurrent.futures import ThreadPoolExecutor

    v = num_vertices
    m = src >= 0
    src = np.ascontiguousarray(src[m], np.int64)
    dst = dst[m]
    weights = weights[m] if weights is not None else None
    e = len(src)
    v_bits = _ceil_log2(v)
    if bin_bits is None:
        bin_bits = max(v_bits - 4, 1)        # ~16 bins by default
    bin_bits = max(bin_bits, 1)
    nbins = max((v + (1 << bin_bits) - 1) >> bin_bits, 1)

    deg = np.bincount(src, minlength=v)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    targets = np.empty(e, np.int32)
    wout = np.empty(e, weights.dtype) if weights is not None else None
    if e == 0:
        return CSR(offsets, targets, wout, v)

    # ---- bucket: cumulative-count rank into bins (one cumsum per bin) ----
    bins = src >> bin_bits
    bcount = np.bincount(bins, minlength=nbins)
    bstart = np.zeros(nbins + 1, np.int64)
    np.cumsum(bcount, out=bstart[1:])
    dest1 = np.empty(e, np.int64)
    for b in range(nbins):
        hit = bins == b
        dest1[hit] = bstart[b] + np.arange(int(bcount[b]))
    perm1 = np.empty(e, np.int64)
    perm1[dest1] = np.arange(e)

    # ---- per-bin contention-free fills (threadable, cache-sized) --------
    def fill(b):
        lo, hi = int(bstart[b]), int(bstart[b + 1])
        if lo == hi:
            return
        edges = perm1[lo:hi]
        local = src[edges] & ((1 << bin_bits) - 1)
        packed = (local << 32) | np.arange(hi - lo)
        order = np.sort(packed) & 0xFFFFFFFF
        csr_order = edges[order]
        targets[lo:hi] = dst[csr_order]
        if wout is not None:
            wout[lo:hi] = weights[csr_order]

    if num_workers == 1 or nbins == 1:
        for b in range(nbins):
            fill(b)
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            list(pool.map(fill, range(nbins)))
    return CSR(offsets, targets, wout, v)


def csr_staged_np(src: np.ndarray, dst: np.ndarray,
                  weights: Optional[np.ndarray], num_vertices: int, *,
                  rho: int = 4, num_workers: int = 1) -> CSR:
    """Host (numpy) staged build with a thread pool over partitions —
    the multicore realization of Algorithm 2: partition-local sorts run
    on separate cores (numpy sort releases the GIL), then the disjoint
    merge scatters in parallel."""
    from concurrent.futures import ThreadPoolExecutor

    v = num_vertices
    e = len(src)
    cuts = np.linspace(0, e, rho + 1).astype(np.int64)

    def local(p):
        s = src[cuts[p]:cuts[p + 1]]
        d = dst[cuts[p]:cuts[p + 1]]
        order = np.argsort(s, kind="stable")
        skey = s[order]
        deg = np.bincount(skey, minlength=v)
        w = weights[cuts[p]:cuts[p + 1]][order] if weights is not None else None
        return skey, d[order], deg, w

    if num_workers == 1:
        parts = [local(p) for p in range(rho)]
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            parts = list(pool.map(local, range(rho)))

    pdeg = np.stack([p[2] for p in parts])                 # (rho, V)
    deg = pdeg.sum(axis=0)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    before = np.cumsum(pdeg, axis=0) - pdeg                # (rho, V) excl
    targets = np.empty(e, np.int32)
    wout = np.empty(e, np.float32) if weights is not None else None

    def merge(p):
        skey, sdst, pdg, w = parts[p]
        local_off = np.zeros(v + 1, np.int64)
        np.cumsum(pdg, out=local_off[1:])
        rank = np.arange(len(skey)) - local_off[skey]
        dest = offsets[skey] + before[p][skey] + rank
        targets[dest] = sdst
        if wout is not None:
            wout[dest] = w

    if num_workers == 1:
        for p in range(rho):
            merge(p)
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            list(pool.map(merge, range(rho)))
    return CSR(offsets, targets, wout, v)


def csr_np(src: np.ndarray, dst: np.ndarray, weights: Optional[np.ndarray],
           num_vertices: int) -> CSR:
    """Host oracle: numpy stable sort."""
    m = src >= 0
    src, dst = src[m], dst[m]
    weights = weights[m] if weights is not None else None
    order = np.argsort(src, kind="stable")
    deg = np.bincount(src, minlength=num_vertices)
    offsets = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    return CSR(offsets, dst[order].astype(np.int32),
               None if weights is None else weights[order],
               num_vertices)
