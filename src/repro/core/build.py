"""Edgelist -> CSR construction strategies (GVEL §4.2.3-4.2.4, TPU-adapted).

There is no fetch-add on TPU, so PIGO's "claim a slot atomically" becomes a
deterministic *rank*: edge e with source u lands at offsets[u] + (rank of e
among u's edges).  Ranks come from a stable sort, which makes construction
a pure gather/scatter with provably disjoint destinations.

* ``csr_global``    — one global stable sort over all edges
                      (single-stage; the PIGO-shaped baseline).
* ``csr_staged``    — GVEL's multi-stage build: edges are cut into rho
                      contiguous partitions; each partition sorts locally
                      (smaller sorts, independent -> parallel across cores
                      or devices) and is merged into the global CSR through
                      per-partition base offsets.  Stage-local work is
                      contention-free; only the merge touches shared state,
                      and its destinations are disjoint by construction.

Fixed-capacity buffers use src == -1 as padding; padding sorts to the end
(key |V|) and is dropped by capacity slicing.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import CSR

I32 = jnp.int32


def _rank_in_group(sorted_key: jax.Array, num_vertices: int) -> jax.Array:
    """rank of each sorted element within its equal-key run."""
    first = jnp.searchsorted(sorted_key, jnp.arange(num_vertices + 1, dtype=I32),
                             side="left")
    return jnp.arange(sorted_key.shape[0], dtype=I32) - first[
        jnp.clip(sorted_key, 0, num_vertices)]


@functools.partial(jax.jit, static_argnames=("num_vertices", "weighted"))
def csr_global(
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array],
    num_vertices: int,
    *,
    weighted: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Single-stage build: one global stable sort (baseline)."""
    v = num_vertices
    key = jnp.where(src >= 0, src, v).astype(I32)
    order = jnp.argsort(key, stable=True)
    targets = dst[order]
    w = weights[order] if weighted else None
    deg = jnp.zeros((v,), I32).at[key].add(1, mode="drop")
    offsets = jnp.concatenate([jnp.zeros((1,), I32), jnp.cumsum(deg, dtype=I32)])
    return offsets, targets, w


@functools.partial(jax.jit, static_argnames=("num_vertices", "rho", "weighted"))
def csr_staged(
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array],
    num_vertices: int,
    *,
    rho: int = 4,
    weighted: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """GVEL multi-stage build (Algorithm 2, rank-based).

    Stage 1: rho contiguous edge partitions, each locally sorted by source
             -> rho partition CSRs (vmapped: independent work).
    Stage 2: partition degrees -> global offsets + per-partition bases;
             every partition edge's destination =
             offsets[u] + (edges of u in earlier partitions) + local rank.
    The scatter destinations are disjoint, so the merge is race-free.
    """
    v = num_vertices
    e = src.shape[0]
    pcap = -(-e // rho)
    pad = rho * pcap - e
    key = jnp.where(src >= 0, src, v).astype(I32)
    if pad:
        key = jnp.concatenate([key, jnp.full((pad,), v, I32)])
        dst = jnp.concatenate([dst, jnp.full((pad,), -1, I32)])
        if weighted:
            weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
    key = key.reshape(rho, pcap)
    dstp = dst.reshape(rho, pcap)
    wp = weights.reshape(rho, pcap) if weighted else None

    # ---- stage 1: partition-local sorts (independent, parallelizable) ----
    if wp is None:
        wp = jnp.zeros_like(key, jnp.float32)   # dummy; DCE'd when unweighted

    def local(keys, dsts, ws):
        order = jnp.argsort(keys, stable=True)
        skey = keys[order]
        deg = jnp.zeros((v,), I32).at[skey].add(1, mode="drop")
        rank = _rank_in_group(skey, v)
        return skey, dsts[order], deg, rank, ws[order]

    skey, sdst, pdeg, rank, sw = jax.vmap(local)(key, dstp, wp)

    # ---- stage 2: global offsets + disjoint merge -------------------------
    deg = jnp.sum(pdeg, axis=0, dtype=I32)                       # (V,)
    offsets = jnp.concatenate([jnp.zeros((1,), I32), jnp.cumsum(deg, dtype=I32)])
    before = jnp.cumsum(pdeg, axis=0, dtype=I32) - pdeg          # (rho, V) excl
    base = offsets[:-1][None, :] + before                        # (rho, V)
    dest = jnp.take_along_axis(base, jnp.clip(skey, 0, v - 1), axis=1) + rank
    dest = jnp.where(skey < v, dest, e)                          # drop padding
    targets = jnp.full((e,), -1, I32).at[dest.reshape(-1)].set(
        sdst.reshape(-1), mode="drop")
    w = None
    if weighted:
        w = jnp.zeros((e,), weights.dtype).at[dest.reshape(-1)].set(
            sw.reshape(-1), mode="drop")
    return offsets, targets, w


def csr_staged_np(src: np.ndarray, dst: np.ndarray,
                  weights: Optional[np.ndarray], num_vertices: int, *,
                  rho: int = 4, num_workers: int = 1) -> CSR:
    """Host (numpy) staged build with a thread pool over partitions —
    the multicore realization of Algorithm 2: partition-local sorts run
    on separate cores (numpy sort releases the GIL), then the disjoint
    merge scatters in parallel."""
    from concurrent.futures import ThreadPoolExecutor

    v = num_vertices
    e = len(src)
    cuts = np.linspace(0, e, rho + 1).astype(np.int64)

    def local(p):
        s = src[cuts[p]:cuts[p + 1]]
        d = dst[cuts[p]:cuts[p + 1]]
        order = np.argsort(s, kind="stable")
        skey = s[order]
        deg = np.bincount(skey, minlength=v)
        w = weights[cuts[p]:cuts[p + 1]][order] if weights is not None else None
        return skey, d[order], deg, w

    if num_workers == 1:
        parts = [local(p) for p in range(rho)]
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            parts = list(pool.map(local, range(rho)))

    pdeg = np.stack([p[2] for p in parts])                 # (rho, V)
    deg = pdeg.sum(axis=0)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    before = np.cumsum(pdeg, axis=0) - pdeg                # (rho, V) excl
    targets = np.empty(e, np.int32)
    wout = np.empty(e, np.float32) if weights is not None else None

    def merge(p):
        skey, sdst, pdg, w = parts[p]
        local_off = np.zeros(v + 1, np.int64)
        np.cumsum(pdg, out=local_off[1:])
        rank = np.arange(len(skey)) - local_off[skey]
        dest = offsets[skey] + before[p][skey] + rank
        targets[dest] = sdst
        if wout is not None:
            wout[dest] = w

    if num_workers == 1:
        for p in range(rho):
            merge(p)
    else:
        with ThreadPoolExecutor(num_workers) as pool:
            list(pool.map(merge, range(rho)))
    return CSR(offsets, targets, wout, v)


def csr_np(src: np.ndarray, dst: np.ndarray, weights: Optional[np.ndarray],
           num_vertices: int) -> CSR:
    """Host oracle: numpy stable sort."""
    m = src >= 0
    src, dst = src[m], dst[m]
    weights = weights[m] if weights is not None else None
    order = np.argsort(src, kind="stable")
    deg = np.bincount(src, minlength=num_vertices)
    offsets = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    return CSR(offsets, dst[order].astype(np.int32),
               None if weights is None else weights[order],
               num_vertices)
