"""Deterministic fault injection + the self-healing IO machinery it tests.

The IO stack (streaming loader -> sharded mesh load -> snapshot mmap ->
SourceCache -> ServeRuntime) is the hot path this repo exists to make
fast; this module is what keeps it *alive* when the bytes misbehave.
Two halves, deliberately in one file so the recovery code and the chaos
harness that exercises it can never drift apart:

* **Injection** — a seeded :class:`FaultPlan` of :class:`FaultSpec`
  entries, activated process-wide via :func:`set_fault_plan`, the
  :func:`fault_plan` context manager, or the ``REPRO_FAULTS`` env var
  (``"seed=7;block:oserror@3*2;frame:bitflip@0"``).  Hooks at four
  sites — ``block`` (staged block batches, via
  :class:`FaultyBlockSource`), ``frame`` (compressed-frame decodes in
  :mod:`repro.core.codecs`), ``open`` (:class:`~repro.core.cache.
  SourceCache` cold opens) and ``mmap`` (:func:`repro.core.blocks.
  mmap_bytes`) — inject transient ``OSError`` s, latency spikes,
  stuck-reader stalls, truncations and bit-flips at chosen indices.
  With no active plan every hook is a single ``is None`` test: the
  disabled path adds no measurable overhead (the perf gates in
  scripts/verify.sh run with this layer compiled in).

* **Recovery** — :func:`call_with_retries` (bounded exponential
  backoff over the *transient* ``OSError`` class; ``REPRO_IO_RETRIES``),
  the :data:`WATCHDOG_S` budget every prefetch/staging wait honours
  (``REPRO_WATCHDOG_S``), and the structured errors the rest of the
  stack raises: :class:`StageTimeout` (a stuck reader, naming the byte
  span), :class:`ShardLoadError` (a shard's retry budget exhausted,
  carrying the per-attempt fault log) and :class:`CorruptGraphError`
  (a quarantined ``(path, section)`` in the serving path).

Injection raises/stalls *before* delegating to the wrapped reader, so
a retried call observes exactly the state the failed call did —
bitwise-identical re-execution is what the chaos matrix asserts.
Semantics and knobs: docs/robustness.md.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FaultSpec", "FaultPlan", "FaultyBlockSource",
    "StageTimeout", "ShardLoadError", "CorruptGraphError",
    "set_fault_plan", "active_plan", "fault_plan", "plan_from_env",
    "inject", "corrupt_bytes", "wrap_block_source",
    "call_with_retries", "is_transient",
    "counters", "reset_counters",
]

SITES = ("block", "frame", "open", "mmap")
KINDS = ("oserror", "latency", "stall", "truncate", "bitflip")

# -- knobs (module globals so tests monkeypatch them; env sets defaults) ------

#: attempts per IO call (1 = no retry); $REPRO_IO_RETRIES
DEFAULT_ATTEMPTS = max(1, int(os.environ.get("REPRO_IO_RETRIES", "3")))
#: first-retry sleep; doubles per attempt; $REPRO_IO_BACKOFF_S
DEFAULT_BACKOFF_S = float(os.environ.get("REPRO_IO_BACKOFF_S", "0.005"))
#: seconds a staging/prefetch wait may block before StageTimeout;
#: $REPRO_WATCHDOG_S
WATCHDOG_S = float(os.environ.get("REPRO_WATCHDOG_S", "120"))
#: extra re-executions of a whole shard span after its in-span retries
#: are exhausted; $REPRO_SHARD_RETRIES
SHARD_RETRIES = max(0, int(os.environ.get("REPRO_SHARD_RETRIES", "2")))

#: OSError errnos retried as transient.  Deliberately narrow: missing
#: files, permissions, and directory mistakes are programming errors
#: and fail immediately.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO, errno.EAGAIN, errno.EINTR, errno.EBUSY,
    errno.ETIMEDOUT, errno.ESTALE, errno.ECONNRESET,
})


# -- structured errors --------------------------------------------------------


class StageTimeout(TimeoutError):
    """A staging/prefetch worker produced nothing within the watchdog
    budget.  The message names the file and byte span so a stuck NFS
    mount or wedged decompressor is diagnosable from the error alone;
    the stuck thread is abandoned (never joined) so the caller's
    control flow continues."""


class ShardLoadError(RuntimeError):
    """One shard of a sharded streaming load exhausted its re-execution
    budget.  ``fault_log`` holds one line per failed attempt."""

    def __init__(self, message: str, *, shard: int = -1,
                 fault_log: Sequence[str] = ()):
        super().__init__(message)
        self.shard = int(shard)
        self.fault_log = list(fault_log)


class CorruptGraphError(RuntimeError):
    """Structured corruption error for the serving path: the graph at
    ``path`` has a quarantined ``section`` (CRC/decode failure).  Other
    sections and other graphs in the same cache keep serving; the
    quarantine lifts when the file is swapped on disk."""

    def __init__(self, message: str, *, path: str = "",
                 section: str = "unknown", op: Optional[str] = None):
        super().__init__(message)
        self.path = str(path)
        self.section = str(section)
        self.op = op


# -- fault plans --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault.

    ``site``   -- where it fires: ``block`` (block id), ``frame``
                  (frame index), ``open`` / ``mmap`` (index is always 0;
                  use ``path`` to choose the file).
    ``kind``   -- ``oserror`` (transient EIO), ``latency`` (short
                  sleep), ``stall`` (sleep ``delay_s`` — set it past the
                  watchdog to simulate a stuck reader), ``truncate``
                  (drop trailing bytes), ``bitflip`` (flip one seeded
                  bit).
    ``index``  -- site-local index the fault targets.
    ``times``  -- injections before the spec is spent (< 0: unlimited).
    ``path``   -- substring filter on the target's description.
    """
    site: str
    kind: str
    index: int = 0
    times: int = 1
    path: str = ""
    delay_s: float = 0.05

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"FaultSpec: unknown site {self.site!r}; "
                             f"sites: {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"FaultSpec: unknown kind {self.kind!r}; "
                             f"kinds: {KINDS}")


class FaultPlan:
    """A seeded, thread-safe schedule of :class:`FaultSpec` s.

    ``match`` consumes spec budgets atomically, so concurrent staging
    threads injecting from one plan see a deterministic total count;
    data corruption (:meth:`corrupt`) is a pure function of
    ``(seed, spec, salt)`` so chaos runs reproduce bit-for-bit.
    """

    def __init__(self, faults: Iterable[FaultSpec], *, seed: int = 0):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._fired = [0] * len(self.faults)
        self._counts: Dict[str, int] = {}

    def has_site(self, site: str) -> bool:
        return any(f.site == site for f in self.faults)

    def match(self, site: str, index: int, where: str = "") -> List[FaultSpec]:
        """Specs firing for this event; consumes their budgets."""
        out: List[FaultSpec] = []
        with self._lock:
            for i, f in enumerate(self.faults):
                if f.site != site or f.index != int(index):
                    continue
                if f.path and f.path not in where:
                    continue
                if f.times >= 0 and self._fired[i] >= f.times:
                    continue
                self._fired[i] += 1
                key = f"{f.site}:{f.kind}"
                self._counts[key] = self._counts.get(key, 0) + 1
                out.append(f)
        return out

    def injected(self) -> Dict[str, int]:
        """``{"site:kind": count}`` of faults actually fired."""
        with self._lock:
            return dict(self._counts)

    def total_injected(self) -> int:
        with self._lock:
            return sum(self._counts.values())

    def corrupt(self, data: bytes, spec: FaultSpec, salt: int = 0) -> bytes:
        """Deterministically damaged copy of ``data`` per ``spec``."""
        if not data:
            return data
        rng = np.random.default_rng((self.seed, spec.index, salt))
        if spec.kind == "truncate":
            keep = max(1, len(data) - max(1, len(data) // 4))
            return data[:keep]
        if spec.kind == "bitflip":
            buf = bytearray(data)
            buf[int(rng.integers(len(buf)))] ^= 1 << int(rng.integers(8))
            return bytes(buf)
        return data


# -- activation ---------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None
_ACTIVE_LOCK = threading.Lock()


def set_fault_plan(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install ``plan`` process-wide (``None`` disables injection)."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = plan
    return plan


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def fault_plan(plan: Optional[FaultPlan]):
    """Activate ``plan`` for the dynamic extent.  ``None`` is a no-op
    (the surrounding plan, if any, stays active) so callers can thread
    an optional ``LoadOptions.faults`` through unconditionally."""
    global _ACTIVE
    if plan is None:
        yield None
        return
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev


def plan_from_env(spec: Optional[str] = None) -> Optional[FaultPlan]:
    """Parse a ``REPRO_FAULTS`` spec into a plan (``None`` if empty).

    Grammar (``;``-separated entries)::

        seed=<int>
        <site>:<kind>[@<index>][*<times>][~<path-substring>]

    e.g. ``"seed=7;block:oserror@3*2;frame:bitflip@0~web.gvel"``.
    """
    if spec is None:
        spec = os.environ.get("REPRO_FAULTS", "")
    spec = spec.strip()
    if not spec:
        return None
    seed, faults = 0, []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        if part.startswith("seed="):
            seed = int(part[len("seed="):])
            continue
        site, sep, rest = part.partition(":")
        if not sep:
            raise ValueError(f"REPRO_FAULTS: bad entry {part!r} "
                             f"(want site:kind[@index][*times][~path])")
        path, times, index = "", 1, 0
        if "~" in rest:
            rest, path = rest.split("~", 1)
        if "*" in rest:
            rest, times_s = rest.split("*", 1)
            times = int(times_s)
        kind, _, tail = rest.partition("@")
        if tail:
            index = int(tail)
        faults.append(FaultSpec(site=site, kind=kind, index=index,
                                times=times, path=path))
    return FaultPlan(faults, seed=seed)


# a REPRO_FAULTS env plan is live from import (how the chaos lane arms
# subprocesses without touching their code)
set_fault_plan(plan_from_env())


# -- injection hooks ----------------------------------------------------------


def inject(site: str, index: int, *, where: str = "") -> List[FaultSpec]:
    """Fire the active plan's faults for one event.

    Raising kinds (``oserror``) raise here; sleeping kinds
    (``latency``/``stall``) sleep here — both *before* the caller
    touches its underlying reader, which is what makes a retry safe.
    Data kinds (``truncate``/``bitflip``) are returned for the caller
    to apply to the bytes it is about to produce.
    """
    plan = _ACTIVE
    if plan is None:
        return []
    mutators: List[FaultSpec] = []
    for f in plan.match(site, index, where):
        if f.kind in ("latency", "stall"):
            time.sleep(f.delay_s)
        elif f.kind == "oserror":
            raise OSError(
                errno.EIO,
                f"injected transient IO error at {where or site} "
                f"(index {index})")
        else:
            mutators.append(f)
    return mutators


def corrupt_bytes(data: bytes, spec: FaultSpec, salt: int = 0) -> bytes:
    plan = _ACTIVE
    return data if plan is None else plan.corrupt(data, spec, salt)


class FaultyBlockSource:
    """A ``BlockSource`` wrapper injecting ``block``-site faults.

    Raising/sleeping faults fire *before* delegation, so the inner
    source's cursor (``SequentialBlockSource`` advances ``_next_block``
    at entry) is untouched by an injected failure and the retried
    ``stage`` call is exact.  Data faults corrupt a copy of the staged
    bytes (the arena buffer itself is never damaged).
    """

    def __init__(self, inner, where: str):
        self._inner = inner
        self._where = str(where)
        self._describe = getattr(inner, "_describe", self._where)

    @property
    def length(self):
        return self._inner.length

    def stage(self, plan, block_ids, arena=None, check_lines: bool = False):
        ids = np.asarray(block_ids, dtype=np.int64)
        mutators: List[Tuple[FaultSpec, int]] = []
        for b in ids:
            for f in inject("block", int(b), where=self._where):
                mutators.append((f, int(b)))
        out = self._inner.stage(plan, block_ids, arena=arena,
                                check_lines=check_lines)
        if mutators:
            out = np.array(out, copy=True)   # never damage the arena ring
            for f, b in mutators:
                row = int(np.nonzero(ids == b)[0][0])
                raw = out[row].tobytes()
                bad = corrupt_bytes(raw, f, salt=b)
                out[row] = np.frombuffer(           # truncation keeps the
                    bad.ljust(len(raw), b"\n"),     # staged shape: pad \n
                    np.uint8)
        return out

    def finish(self) -> None:
        self._inner.finish()


def wrap_block_source(source, where: str):
    """Wrap ``source`` when the active plan has block-site faults;
    otherwise return it untouched (the zero-fault path has no wrapper
    in the stack at all)."""
    plan = _ACTIVE
    if plan is None or not plan.has_site("block"):
        return source
    return FaultyBlockSource(source, where)


# -- retries + counters -------------------------------------------------------

_COUNT_LOCK = threading.Lock()
_COUNTERS = {"io_retries": 0, "stage_timeouts": 0, "shard_retries": 0}


def _count(key: str, n: int = 1) -> None:
    with _COUNT_LOCK:
        _COUNTERS[key] = _COUNTERS.get(key, 0) + n


def counters() -> Dict[str, int]:
    """Process-wide recovery counters (retries, timeouts, shard
    re-executions) — surfaced via ``SourceCache.stats()["faults"]``."""
    with _COUNT_LOCK:
        return dict(_COUNTERS)


def reset_counters() -> None:
    with _COUNT_LOCK:
        for k in _COUNTERS:
            _COUNTERS[k] = 0


def is_transient(exc: BaseException) -> bool:
    """True for the OSError class worth retrying: EIO/EAGAIN/... but
    never missing files or permission errors."""
    return (isinstance(exc, OSError)
            and exc.errno in TRANSIENT_ERRNOS)


def call_with_retries(fn: Callable[[], "object"], *,
                      describe: str = "io operation",
                      attempts: Optional[int] = None,
                      backoff_s: Optional[float] = None,
                      on_retry: Optional[Callable[[BaseException], None]]
                      = None):
    """``fn()`` with bounded retry of *transient* failures.

    Exponential backoff starting at ``backoff_s`` (defaults are the
    module knobs, resolved at call time so tests can monkeypatch).
    Non-transient exceptions, and the last transient one, propagate
    unchanged.
    """
    attempts = DEFAULT_ATTEMPTS if attempts is None else max(1, int(attempts))
    backoff_s = DEFAULT_BACKOFF_S if backoff_s is None else float(backoff_s)
    for attempt in range(attempts):
        try:
            return fn()
        except BaseException as exc:
            if not is_transient(exc) or attempt + 1 >= attempts:
                raise
            _count("io_retries")
            if on_retry is not None:
                on_retry(exc)
            time.sleep(backoff_s * (2 ** attempt))
