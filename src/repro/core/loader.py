"""Unified streaming loader: the engine registry and engine-call layer.

The user-facing front door is :mod:`repro.core.source` —
``open_graph(path) -> GraphSource`` — which resolves format/codec/
engine once and serves lazy, memoized products.  This module keeps the
layer underneath it: the engine registry, the normalized
:class:`LoadOptions` every engine call is expanded from, the streaming
pipeline, and the historical ``load_edgelist`` (file -> EdgeList) /
``load_csr`` (file -> CSR) wrappers, with the parse backend selected by
name from the registry:

    ==========  ================================================
    engine      implementation
    ==========  ================================================
    device      streaming double-buffered block pipeline ->
                jitted ``parse_blocks`` -> packed device buffers
    pallas      same pipeline, but parsing runs through
                ``kernels.parse_edges.parse_edges_accumulate``
                (the Mosaic kernel on TPU, its XLA twin elsewhere)
    numpy       single-pass vectorized numpy parser (host)
    threads     thread pool over newline-aligned chunks (host)
    snapshot    zero-parse mmap of a binary ``.gvel`` snapshot
                (``core.snapshot``; write once, load many)
    ==========  ================================================

The device/pallas engines are *streaming* (GVEL's pipelined read):

  1. a host prefetch thread stages the next batch of overlap-padded
     byte blocks (``blocks.stage_blocks``, through a reusable
     :class:`~repro.core.blocks.StagingArena` — no per-batch
     allocation) while the device parses the current one — read IO and
     parse compute overlap, the madvise / double-buffer effect the
     paper measures;
  2. each batch runs ONE jitted program (``parse.parse_accumulate``)
     that parses the blocks and writes the edges straight into packed
     device accumulators at the running offset, with the accumulator
     buffers *donated* so the update is in-place — per-block parse
     outputs never materialize between programs and the capacity-sized
     buffers are not copied per batch (the pallas engine runs the same
     fused-donated shape through ``kernels.parse_edges``);
  3. the final short batch runs a remainder-sized program instead of
     being padded with ``NEWLINE`` blocks to ``batch_blocks`` — small
     inputs don't pay full-batch parse cost for padding;
  4. ``load_csr`` hands the packed device buffers straight to the
     rank-based CSR builders (``build.csr_global``/``csr_staged``/
     ``csr_binned``), so file -> CSR never materializes a host-side
     EdgeList.

Block geometry (``beta`` x ``batch_blocks``) defaults to
``DEFAULT_BETA``/``DEFAULT_BATCH_BLOCKS`` and can be *measured* instead:
``tune=True`` (via ``LoadOptions`` / ``open_graph``) fills un-pinned
geometry from the per-host profile in :mod:`repro.core.tune` (a GVEL
Fig. 2 style sweep, run once and cached).  See docs/performance.md.

Compressed inputs are transparent at every entry point: gzip and
framed files (``core.codecs``) are sniffed by magic, streamed through
the same double-buffered pipeline with decompression in the prefetch
thread, and handed decompressed to the host engines.  New formats or
backends register with :func:`register_engine`; the registry is the
extension point for new loaders (see ROADMAP.md "Open items").

Engine contract: ``read_edgelist`` must return the raw (asymmetric)
edge set; symmetrization happens once, in the front door.
"""
from __future__ import annotations

import dataclasses
import functools
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from typing import (Any, Callable, Dict, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from . import build
from . import faults
from . import parse as parse_mod
from .blocks import StagingArena, flat_len, owned_range, plan_blocks
from .parse import donation_supported, parse_accumulate
from .types import CSR, EdgeList

I32 = jnp.int32

# the per-product engine defaults the wrappers have always used: host
# EdgeLists parse fastest on the numpy engine; CSR builds run fused on
# the streaming device engine
DEFAULT_EDGELIST_ENGINE = "numpy"
DEFAULT_CSR_ENGINE = "device"

# fallback streaming block geometry (GVEL's paper values), used when the
# caller pins nothing and tuning is off; `tune=True` replaces them with
# the measured per-host profile (core.tune)
DEFAULT_BETA = 256 * 1024
DEFAULT_BATCH_BLOCKS = 8
DEFAULT_OVERLAP = 64


@dataclasses.dataclass(frozen=True)
class LoadOptions:
    """The normalized loading knobs, consolidated from the kwargs that
    used to be scattered across every ``load_*``/``read_*`` signature.

    One instance travels from the front door (:func:`repro.core.source.
    open_graph` / a ``GraphSource``) down to every engine call — the
    expansion helpers below are the *only* place option names map onto
    engine-call keywords, so an engine can never see a half-normalized
    set.

    ``engine=None`` means "per-product default" (``numpy`` for
    edgelists, ``device`` for CSRs); ``weighted=None`` means "what the
    file says" (snapshot flags / MTX banner; plain text has no header,
    so it resolves to False).  ``engine_kw`` carries engine tuning
    knobs (``beta``, ``batch_blocks``, ``num_workers``, ...) verbatim.
    ``tune=True`` fills un-pinned streaming block geometry from the
    measured per-host profile (:mod:`repro.core.tune`); explicit
    ``engine_kw`` values always win, and non-streaming engines ignore
    it.

    ``method``/``bin_bits`` pick the CSR build strategy for every
    ``.csr()``-family product off this handle (``method=None`` means the
    per-call default, ``staged``); a per-call ``method=`` always wins.
    ``bin_bits`` is the binned build's vertex-range width knob and is
    ignored by the sort-based methods.

    ``faults`` pins a :class:`repro.core.faults.FaultPlan` on the
    handle: every product call runs under that plan (chaos testing a
    single source without touching the process-wide plan).  Never
    expanded into engine kwargs.
    """

    engine: Optional[str] = None
    weighted: Optional[bool] = None
    symmetric: bool = False
    base: int = 1
    num_vertices: Optional[int] = None
    offset: int = 0
    tune: bool = False
    method: Optional[str] = None
    bin_bits: Optional[int] = None
    faults: Optional[Any] = None
    engine_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)

    _OWN_FIELDS = ("engine", "weighted", "symmetric", "base",
                   "num_vertices", "offset", "tune", "method", "bin_bits",
                   "faults")

    def __post_init__(self):
        if self.base not in (0, 1):
            raise ValueError(f"base must be 0 or 1, got {self.base!r}")
        if self.offset < 0:
            raise ValueError(f"offset must be >= 0, got {self.offset!r}")
        if self.method not in (None, "global", "staged", "binned"):
            raise ValueError(f"unknown method {self.method!r}; expected "
                             f"'global', 'staged' or 'binned'")
        dup = sorted(set(self.engine_kw) & set(self._OWN_FIELDS))
        if dup:
            raise ValueError(f"option(s) {dup} passed both named and via "
                             f"engine_kw")

    def replace(self, **changes) -> "LoadOptions":
        return dataclasses.replace(self, **changes)

    def read_kwargs(self) -> Dict[str, Any]:
        """Keywords for an engine's ``read_edgelist``."""
        return dict(self.engine_kw, weighted=bool(self.weighted),
                    base=self.base, num_vertices=self.num_vertices,
                    offset=self.offset)

    def stream_kwargs(self) -> Dict[str, Any]:
        """Keywords for an engine's ``stream`` (no ``num_vertices`` —
        streams infer or take the front door's hint)."""
        return dict(self.engine_kw, weighted=bool(self.weighted),
                    base=self.base, offset=self.offset)

    def prebuilt_kwargs(self) -> Dict[str, Any]:
        """Keywords for an engine's ``read_csr_prebuilt``."""
        return dict(self.engine_kw, weighted=bool(self.weighted),
                    num_vertices=self.num_vertices, offset=self.offset)

# (src, dst, weights-or-None, num_edges device scalar) — packed device
# buffers with -1 padding past num_edges; the streaming engines' output.
DeviceEdges = Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array]


@runtime_checkable
class LoaderEngine(Protocol):
    """A parse backend. ``read_edgelist`` is mandatory; engines that can
    leave edges on device additionally implement ``stream`` (the fused
    ``load_csr`` path probes for it with ``hasattr``)."""

    name: str

    def read_edgelist(self, path: str, *, weighted: bool, base: int,
                      num_vertices: Optional[int], offset: int,
                      **kw) -> EdgeList: ...


_REGISTRY: Dict[str, "LoaderEngine"] = {}


def register_engine(engine: LoaderEngine) -> LoaderEngine:
    """Register an engine instance under ``engine.name`` (last wins)."""
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> LoaderEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown loader engine {name!r}; available: {available_engines()}"
        ) from None


def available_engines() -> list[str]:
    return sorted(_REGISTRY)


def csr_convert_engine(engine: str) -> str:
    """Map a loader engine name to a ``convert_to_csr`` backend: host
    parsers keep the numpy builder, everything else builds on device."""
    return "numpy" if engine in ("numpy", "threads") else "jax"


# ---------------------------------------------------------------------------
# streaming device pipeline
# ---------------------------------------------------------------------------

def _accumulate_impl(acc_src, acc_dst, acc_w, total, src_b, dst_b, w_b,
                     counts, *, cap: int):
    nb, bcap = src_b.shape
    starts = total + jnp.cumsum(counts) - counts
    within = jnp.arange(bcap, dtype=I32)[None, :]
    valid = within < counts[:, None]
    dest = jnp.where(valid, starts[:, None] + within, cap).reshape(-1)
    acc_src = acc_src.at[dest].set(src_b.reshape(-1), mode="drop")
    acc_dst = acc_dst.at[dest].set(dst_b.reshape(-1), mode="drop")
    if acc_w is not None and w_b is not None:
        acc_w = acc_w.at[dest].set(w_b.reshape(-1), mode="drop")
    return acc_src, acc_dst, acc_w, total + jnp.sum(counts, dtype=I32)


@functools.lru_cache(maxsize=None)
def _accumulate_jit(donate: bool):
    return jax.jit(_accumulate_impl, static_argnames=("cap",),
                   donate_argnums=(0, 1, 2) if donate else ())


def _accumulate_batch(acc_src, acc_dst, acc_w, total, src_b, dst_b, w_b,
                      counts, *, cap: int, donate: Optional[bool] = None):
    """Scatter one batch of per-block fixed-capacity parses into the
    packed accumulator at the running offset.

    The device-side analogue of gluing per-thread edgelists: an exclusive
    scan over per-block counts gives each block a disjoint destination
    range starting at ``total``.  Replaces the old per-batch
    device->numpy copy + final np.concatenate.  Kept as the two-step
    reference pipeline (the fused-loader parity tests pin it); both
    streaming engines now run fused —
    :func:`repro.core.parse.parse_accumulate` for ``device``,
    ``kernels.parse_edges.parse_edges_accumulate`` for ``pallas``.

    ``donate=None`` probes the backend once and donates the accumulator
    buffers when supported, making the scatter in-place instead of
    copying the capacity-sized buffers every batch.  Donated inputs are
    consumed — rebind, never reuse, the passed accumulators.
    ``donate=False`` is the fallback for backends that refuse donation.
    """
    if donate is None:
        donate = donation_supported()
    return _accumulate_jit(bool(donate))(
        acc_src, acc_dst, acc_w, total, src_b, dst_b, w_b, counts, cap=cap)


def _guard_int32_cap(path: str, cap: int) -> None:
    """Scatter destinations are int32 (jax default dtype regime); a
    wrapped index would silently drop edges via mode="drop", so refuse
    loudly instead."""
    if cap > np.iinfo(np.int32).max:
        raise ValueError(
            f"{path}: edge capacity {cap} exceeds int32 indexing for the "
            f"streaming engine; use engine='numpy'/'threads' or shard the "
            f"file (load_csr_sharded)")


def _parse_span(
    source,
    plan,
    block_lo: int,
    block_hi: int,
    *,
    weighted: bool,
    base: int,
    batch_blocks: int,
    parse: str,
    cap: int,
    device=None,
    prefetch: bool = True,
) -> DeviceEdges:
    """Stage and fused-parse blocks ``[block_lo, block_hi)`` of ``plan``
    from ``source`` into fresh packed accumulators of ``cap`` slots.

    The single-span streaming loop shared by :func:`_stream_edges`
    (whole file, ``prefetch=True``) and the sharded loader
    (:mod:`repro.core.distributed`, one call per mesh shard's byte
    range).  ``device`` commits the accumulators — and every staged
    batch — to one device, so the donated parse chain executes there;
    ``prefetch=False`` stages inline instead of spawning a prefetch
    thread (the sharded loader's callers *are* per-shard threads:
    inline staging of batch i+1 already overlaps the async-dispatched
    device parse of batch i, without d extra threads).
    """
    os_, oe = owned_range(plan)
    edge_cap = plan.edge_cap
    nspan = max(block_hi - block_lo, 0)
    num_batches = -(-nspan // batch_blocks)
    acc_src, acc_dst, acc_w, total = parse_mod.make_accumulators(
        cap, weighted=weighted, device=device)
    if num_batches == 0:
        return acc_src, acc_dst, acc_w, total

    def put(x):
        return jnp.asarray(x) if device is None else jax.device_put(x, device)

    arena = StagingArena(flat_len(min(batch_blocks, nspan), plan))
    where = getattr(source, "_describe", None) or "block source"

    def batch_bytes(i: int) -> Tuple[int, int]:
        """Post-offset byte span batch ``i`` stages (for error text)."""
        start = block_lo + i * batch_blocks
        stop = min(start + batch_blocks, block_hi)
        return start * plan.beta, min(stop * plan.beta, plan.file_len)

    def stage(i: int) -> np.ndarray:
        start = block_lo + i * batch_blocks
        ids = np.arange(start, min(start + batch_blocks, block_hi))
        # retries are safe here: injected faults fire before the source
        # cursor moves, and raw (mmap) staging is idempotent.  A retry
        # that still fails escalates to the shard/load level, where
        # re-execution reopens the source from scratch.
        return faults.call_with_retries(
            lambda: source.stage(plan, ids, arena=arena, check_lines=True),
            describe=f"{where}: stage blocks "
                     f"[{int(ids[0])}, {int(ids[-1]) + 1})")

    ostart = put(np.full((batch_blocks,), os_, np.int32))
    oend = put(np.full((batch_blocks,), oe, np.int32))

    def consume(i: int, bufs: np.ndarray) -> None:
        nonlocal acc_src, acc_dst, acc_w, total
        nb = bufs.shape[0]          # < batch_blocks on the tail batch
        if parse == "pallas":
            from ..kernels import parse_edges_accumulate
            acc_src, acc_dst, acc_w, total = parse_edges_accumulate(
                acc_src, acc_dst, acc_w, total, put(bufs), os_, oe,
                weighted=weighted, base=base, edge_bound=nb * edge_cap)
        else:
            acc_src, acc_dst, acc_w, total = parse_accumulate(
                acc_src, acc_dst, acc_w, total, put(bufs),
                ostart[:nb], oend[:nb], weighted=weighted, base=base,
                edge_bound=nb * edge_cap)

    if prefetch:
        # not a with-block: a stuck staging thread must be *abandoned*
        # (shutdown(wait=False)), never joined — joining would turn the
        # watchdog timeout back into the hang it exists to prevent
        pool = ThreadPoolExecutor(1, thread_name_prefix="loader-prefetch")
        try:
            fut = pool.submit(stage, 0)
            for i in range(num_batches):
                try:
                    bufs = fut.result(timeout=faults.WATCHDOG_S)
                except _FutTimeout:
                    faults._count("stage_timeouts")
                    lo_b, hi_b = batch_bytes(i)
                    raise faults.StageTimeout(
                        f"{where}: staging of byte span [{lo_b}, {hi_b}) "
                        f"(batch {i + 1}/{num_batches}) produced nothing "
                        f"within the {faults.WATCHDOG_S:.1f}s watchdog "
                        f"budget (REPRO_WATCHDOG_S); reader is stuck"
                    ) from None
                if i + 1 < num_batches:
                    fut = pool.submit(stage, i + 1)     # double buffer
                consume(i, bufs)
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
    else:
        for i in range(num_batches):
            consume(i, stage(i))
    return acc_src, acc_dst, acc_w, total


def _stream_edges(
    path: str,
    *,
    weighted: bool,
    base: int,
    offset: int,
    beta: int,
    overlap: int,
    batch_blocks: int,
    parse: str,
) -> Tuple[DeviceEdges, int]:
    """File -> packed device edge buffers, double-buffered.

    Returns ((src, dst, w, total), capacity).  The prefetch thread stages
    batch i+1 (into a reusable :class:`StagingArena` ring — one memcpy
    per batch, no allocation) while the (async-dispatched) fused
    parse+accumulate program works on batch i, so host staging overlaps
    device compute.  The final short batch is *not* padded to
    ``batch_blocks``: it runs a second, remainder-sized program, so a
    2-block file parses 2 blocks, not ``batch_blocks``.

    Compressed inputs (``.el.gz`` / framed — sniffed by magic in
    :func:`codecs.open_block_source`) ride the same pipeline: the block
    source decompresses inside ``stage``, i.e. in the prefetch thread,
    so decompression overlaps the device parse exactly like raw-file IO
    does.  Framed files force ``beta`` to the file's frame size so
    frames map 1:1 onto staging blocks.

    Lines longer than ``overlap`` bytes that cross a block boundary are
    detected during staging and raise ``ValueError``
    (:func:`repro.core.blocks.check_line_overlap`) instead of silently
    mis-parsing.
    """
    from .codecs import open_block_source
    source, forced_beta = open_block_source(path, offset)
    if forced_beta is not None and forced_beta > overlap:
        beta = forced_beta
    plan = plan_blocks(source.length, beta=beta, overlap=overlap)
    # GVEL over-allocation: a bytes-derived bound on the final edge count
    # (~file_len/4 slots).  This trades device memory (~1 int32 per file
    # byte across src+dst) for a single allocation and in-place (donated)
    # accumulation; load_csr shrinks to a pow-2 prefix before sorting.
    # Growable buffers for accelerator-memory-bound inputs are an open
    # item (ROADMAP.md).  Because batches are trimmed (never padded), the
    # per-batch windows tile [0, cap) exactly and the running offset can
    # never push a window past the end.
    cap = plan.num_blocks * plan.edge_cap
    _guard_int32_cap(path, cap)
    edges = _parse_span(source, plan, 0, plan.num_blocks, weighted=weighted,
                        base=base, batch_blocks=batch_blocks, parse=parse,
                        cap=cap)
    # A stream shorter/longer than its header declared (truncated file,
    # lying gzip trailer) must fail here, not return a partial graph.
    source.finish()
    return edges, cap


def _device_num_vertices(src: jax.Array, dst: jax.Array) -> int:
    """max id + 1 over the packed buffers (-1 padding never wins)."""
    return int(jnp.maximum(jnp.max(src, initial=-1),
                           jnp.max(dst, initial=-1))) + 1


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

class _StreamingEngine:
    """Shared streaming pipeline; ``parse`` picks the block parser."""

    def __init__(self, name: str, parse: str):
        self.name = name
        self._parse = parse

    def stream(self, path: str, *, weighted: bool = False, base: int = 1,
               offset: int = 0, beta: Optional[int] = None,
               overlap: Optional[int] = None,
               batch_blocks: Optional[int] = None
               ) -> Tuple[DeviceEdges, int]:
        return _stream_edges(
            path, weighted=weighted, base=base, offset=offset,
            beta=DEFAULT_BETA if beta is None else beta,
            overlap=DEFAULT_OVERLAP if overlap is None else overlap,
            batch_blocks=(DEFAULT_BATCH_BLOCKS if batch_blocks is None
                          else batch_blocks),
            parse=self._parse)

    def read_edgelist(self, path: str, *, weighted: bool = False,
                      base: int = 1, num_vertices: Optional[int] = None,
                      offset: int = 0, **kw) -> EdgeList:
        (src, dst, w, total), _ = self.stream(
            path, weighted=weighted, base=base, offset=offset, **kw)
        n = int(total)
        src_h = np.asarray(src[:n])
        dst_h = np.asarray(dst[:n])
        w_h = np.asarray(w[:n]) if weighted else None
        if num_vertices is None:
            num_vertices = int(max(src_h.max(initial=-1),
                                   dst_h.max(initial=-1))) + 1
        return EdgeList(src_h, dst_h, w_h, np.int64(n), num_vertices)


class _HostEngine:
    """Adapter around the host parsers in :mod:`repro.core.edgelist`."""

    def __init__(self, name: str, fn: Callable):
        self.name = name
        self._fn = fn

    def read_edgelist(self, path: str, *, weighted: bool = False,
                      base: int = 1, num_vertices: Optional[int] = None,
                      offset: int = 0, **kw) -> EdgeList:
        return self._fn(path, weighted=weighted, base=base,
                        num_vertices=num_vertices, offset=offset, **kw)


def _register_builtin_engines() -> None:
    from . import edgelist, snapshot
    register_engine(_StreamingEngine("device", parse="xla"))
    register_engine(_StreamingEngine("pallas", parse="pallas"))
    register_engine(_HostEngine("numpy", edgelist.read_edgelist_numpy))
    register_engine(_HostEngine("threads", edgelist.read_edgelist_threads))
    register_engine(snapshot.SnapshotEngine())


# ---------------------------------------------------------------------------
# engine-call implementations (shared by GraphSource and the wrappers)
# ---------------------------------------------------------------------------

def resolve_tuned(opts: LoadOptions, *, shards: int = 1) -> LoadOptions:
    """Fill un-pinned streaming block geometry from the measured
    per-host profile when ``opts.tune`` is set.

    Only streaming engines have ``beta``/``batch_blocks`` geometry;
    tuning is a no-op for host/snapshot engines.  Explicit ``engine_kw``
    values always win over the profile (pin one, tune the other).  The
    first tuned load on a host runs the measurement sweep and caches it
    (:func:`repro.core.tune.tuned_geometry`).  ``shards`` selects the
    per-shard-count profile slot for the sharded streaming path — d
    concurrent parse pipelines over 1/d of the bytes have a different
    throughput knee than one pipeline over all of them.
    """
    if not opts.tune or not isinstance(_REGISTRY.get(opts.engine),
                                       _StreamingEngine):
        return opts
    kw = dict(opts.engine_kw)
    if "beta" in kw and "batch_blocks" in kw:
        return opts
    from .tune import tuned_geometry
    g = tuned_geometry(weighted=bool(opts.weighted), shards=int(shards))
    kw.setdefault("beta", g["beta"])
    kw.setdefault("batch_blocks", g["batch_blocks"])
    return opts.replace(engine_kw=kw)


def read_edgelist_via(path: str, opts: LoadOptions) -> EdgeList:
    """File -> EdgeList through ``opts.engine`` (must be concrete).
    Symmetrization happens here, once — engines return the raw edge
    set (the engine contract, docs/extending.md)."""
    opts = resolve_tuned(opts)
    el = get_engine(opts.engine).read_edgelist(path, **opts.read_kwargs())
    if opts.symmetric:
        from .edgelist import symmetrize
        el = symmetrize(el)
    return el


def read_csr_via(path: str, opts: LoadOptions, *,
                 method: Optional[str] = None, rho: int = 4,
                 bin_bits: Optional[int] = None,
                 fallback_edgelist: Optional[Callable[[], EdgeList]] = None,
                 ) -> CSR:
    """File -> CSR through ``opts.engine`` (must be concrete).

    Probes the engine's optional fast paths in speedup order:
    ``read_csr_prebuilt`` (no parse, no build), then ``stream`` (fused
    device build, no host EdgeList), then the EdgeList + convert route.
    ``fallback_edgelist`` lets a :class:`~repro.core.source.GraphSource`
    feed its memoized edgelist into that last route instead of
    re-reading the file.  Symmetric graphs always take the EdgeList
    route (reverse-edge expansion is a host concatenation today).
    ``method=None`` falls back to ``opts.method``, then ``staged``.
    """
    opts = resolve_tuned(opts)
    method = method or opts.method or "staged"
    bin_bits = bin_bits if bin_bits is not None else opts.bin_bits
    weighted = bool(opts.weighted)
    eng = get_engine(opts.engine)
    if hasattr(eng, "read_csr_prebuilt") and not opts.symmetric:
        csr = eng.read_csr_prebuilt(path, **opts.prebuilt_kwargs())
        if csr is not None:
            return csr
    if hasattr(eng, "stream") and not opts.symmetric:
        num_vertices = opts.num_vertices
        if num_vertices is None and hasattr(eng, "num_vertices_hint"):
            num_vertices = eng.num_vertices_hint(path)
        (src, dst, w, total), _cap = eng.stream(path, **opts.stream_kwargs())
        n = int(total)
        if num_vertices is None:
            num_vertices = _device_num_vertices(src, dst) if n else 0
        # Shrink the over-allocated buffers to the next power of two >= n
        # before sorting: padding is all at the tail, so a prefix slice
        # keeps every valid edge while bounding the sort size at 2n (and
        # the pow-2 ladder bounds recompiles at log2(capacity) programs).
        cap2 = 1 << max(n - 1, 1).bit_length()
        if cap2 < src.shape[0]:
            src, dst = src[:cap2], dst[:cap2]
            w = w[:cap2] if weighted else None
        if method == "global":
            offsets, targets, ww = build.csr_global(
                src, dst, w, num_vertices, weighted=weighted)
        elif method == "staged":
            offsets, targets, ww = build.csr_staged(
                src, dst, w, num_vertices, rho=rho, weighted=weighted)
        elif method == "binned":
            offsets, targets, ww = build.csr_binned(
                src, dst, w, num_vertices, bin_bits=bin_bits,
                weighted=weighted)
        else:
            raise ValueError(f"unknown method {method!r}")
        return CSR(np.asarray(offsets).astype(np.int64),
                   np.asarray(targets[:n]),
                   np.asarray(ww[:n]) if weighted else None,
                   num_vertices)
    from .csr import convert_to_csr
    el = (fallback_edgelist() if fallback_edgelist is not None
          else read_edgelist_via(path, opts))
    return convert_to_csr(el, method=method, rho=rho, bin_bits=bin_bits,
                          engine=csr_convert_engine(opts.engine))


def read_csr_sharded_via(path: str, opts: LoadOptions, *, mesh,
                         axis: str = "data", rho: int = 4,
                         method: Optional[str] = None,
                         bin_bits: Optional[int] = None) -> CSR:
    """File -> mesh-sharded CSR through ``opts.engine`` (must be a
    streaming engine — the byte-range shard plan only exists for the
    block streaming pipeline).

    Expands ``LoadOptions`` onto :func:`repro.core.distributed.
    load_csr_sharded_stream`: each mesh shard along ``axis`` streams its
    own byte span of the file through the fused parse pipeline and the
    packed per-shard edges feed the degree-psum / all_to_all / local
    CSR build with no host detour.  ``tune=True`` resolves against the
    per-shard-count profile slot.
    """
    if axis not in dict(getattr(mesh, "shape", {})):
        raise ValueError(f"mesh has no axis {axis!r} "
                         f"(axes: {tuple(dict(mesh.shape))})")
    opts = resolve_tuned(opts, shards=int(mesh.shape[axis]))
    if opts.symmetric:
        raise ValueError(
            "sharded streaming load does not support symmetric=True "
            "(reverse-edge expansion is a host concatenation; load the "
            "CSR unsharded or pre-symmetrize the file)")
    eng = get_engine(opts.engine)
    if not isinstance(eng, _StreamingEngine):
        raise ValueError(
            f"engine {opts.engine!r} has no sharded streaming path; use a "
            f"streaming engine ('device' or 'pallas')")
    from . import distributed
    return distributed.load_csr_sharded_stream(
        mesh, axis, path, num_vertices=opts.num_vertices, rho=rho,
        method=method or opts.method or "staged",
        bin_bits=bin_bits if bin_bits is not None else opts.bin_bits,
        parse=eng._parse, **opts.stream_kwargs())


# ---------------------------------------------------------------------------
# front door (thin wrappers over repro.core.source.open_graph)
# ---------------------------------------------------------------------------

def load_edgelist(
    path: str,
    *,
    engine: str = DEFAULT_EDGELIST_ENGINE,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    offset: int = 0,
    tune: bool = False,
    **engine_kw,
) -> EdgeList:
    """File -> EdgeList through the named engine.

    A thin wrapper over the :class:`~repro.core.source.GraphSource`
    front door — equivalent to ``open_graph(path, ...).edgelist()``.
    ``offset`` skips a header prefix (MTX bodies); ``engine_kw`` is
    forwarded to the engine (beta/batch_blocks for device, num_workers
    for threads, chunk_bytes for numpy, ...); ``tune=True`` fills
    un-pinned streaming geometry from the measured per-host profile.
    Binary ``.gvel`` files are detected by magic and routed to the
    snapshot engine.
    """
    from .source import open_graph
    return open_graph(path, engine=engine, weighted=weighted,
                      symmetric=symmetric, base=base,
                      num_vertices=num_vertices, offset=offset, tune=tune,
                      validate=False, **engine_kw).edgelist()


def load_csr(
    path: str,
    *,
    engine: str = DEFAULT_CSR_ENGINE,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    method: str = "staged",
    rho: int = 4,
    bin_bits: Optional[int] = None,
    offset: int = 0,
    tune: bool = False,
    **engine_kw,
) -> CSR:
    """File -> CSR through the named engine.

    A thin wrapper over the :class:`~repro.core.source.GraphSource`
    front door — equivalent to ``open_graph(path, ...).csr(...)``.
    Streaming engines (device, pallas) run fused: one jitted program
    per batch parses the blocks and accumulates the edges in packed
    (donated) device buffers that feed the rank-based builders
    (``csr_global``/``csr_staged``/``csr_binned``) directly — no host
    EdgeList in between.  ``tune=True`` fills un-pinned streaming
    geometry from the measured per-host profile.  Host engines read an
    EdgeList and convert.  Binary ``.gvel`` files are detected by magic
    and routed to the snapshot engine; an embedded prebuilt CSR is
    served straight from mmap (``method``/``rho``/``bin_bits`` do not
    apply — the stored CSR wins).
    """
    from .source import open_graph
    return open_graph(path, engine=engine, weighted=weighted,
                      symmetric=symmetric, base=base,
                      num_vertices=num_vertices, offset=offset, tune=tune,
                      validate=False, **engine_kw).csr(method=method, rho=rho,
                                                       bin_bits=bin_bits)


_register_builtin_engines()
