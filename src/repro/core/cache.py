"""Process-level hot-graph cache: a bounded, thread-safe LRU of open
:class:`~repro.core.source.GraphSource` handles, and the serve-facing
``query(path, op)`` entry built on it.

A graph-query service (ParaGrapher's serving scenario: thousands of
point/range reads per second against a snapshot corpus) must not pay
open-and-validate per request, must notice when a snapshot is swapped
under it, and must bound how many mmaps / decoded sections it pins.
This module is that layer:

    from repro.core.cache import query

    nbrs = query("web.gvel", "neighbors", vertex=42)
    rows = query("web.gvel", "rows", rows=range(100, 200))
    csr  = query("web.gvel", "csr")

* **Keyed by content, not path**: entries are validated against
  ``(mtime_ns, size)`` on every hit — overwriting a snapshot (the
  swap-under-the-server scenario) invalidates its entry on the next
  request, which reopens the new file.  No TTLs, no staleness window
  beyond the filesystem's mtime granularity.
* **Bounded LRU**: at most ``capacity`` open handles; the least
  recently used is evicted (dropping its mmap and decoded-section
  memos with it).
* **Thread-safe, single-open**: concurrent requests for the same path
  coordinate through a pending slot so a cold file is opened and
  validated exactly once, not once per waiting thread; every wait-er
  gets the same handle.  Product access on a shared handle is safe:
  section decodes are lock-guarded per section
  (:mod:`repro.core.snapshot`) and memoized products are immutable.

The default process cache (capacity from ``$REPRO_CACHE_CAPACITY``,
else 16) serves the module-level :func:`query`; build explicit
:class:`SourceCache` instances for isolation (tests, per-tenant
caches).  Cache semantics and invalidation rules: ``docs/query.md``.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from . import faults as faults_mod
from .faults import CorruptGraphError, StageTimeout
from .snapshot import SnapshotError
from .source import GraphSource, open_graph

_DEFAULT_CAPACITY = int(os.environ.get("REPRO_CACHE_CAPACITY", "16"))

# sections each query op may touch — the quarantine scope of the op.
# A quarantined section only blocks ops that would read it; "info" is
# header-only and keeps serving (the health probe must outlive the
# corruption it reports).
_OP_SECTIONS: Dict[str, Tuple[str, ...]] = {
    "info": (),
    "csr": ("csr_offsets", "csr_indices", "csr_weights"),
    "full": ("csr_offsets", "csr_indices", "csr_weights"),
    "rows": ("csr_offsets", "csr_indices", "csr_weights"),
    "csr_rows": ("csr_offsets", "csr_indices", "csr_weights"),
    "range": ("csr_offsets", "csr_indices", "csr_weights"),
    "neighbors": ("csr_offsets", "csr_indices", "csr_weights"),
    "point": ("csr_offsets", "csr_indices", "csr_weights"),
    "degree": ("csr_offsets",),
    "edgelist": ("src", "dst", "edge_weights"),
}


class _Pending:
    """One in-flight open: waiters block on ``event``; the opener
    publishes ``source`` or ``error`` before setting it."""

    __slots__ = ("event", "source", "error")

    def __init__(self):
        self.event = threading.Event()
        self.source: Optional[GraphSource] = None
        self.error: Optional[BaseException] = None


class _Entry:
    __slots__ = ("key", "source")

    def __init__(self, key, source):
        self.key = key
        self.source = source


def _stat_key(path: str) -> Tuple[int, int]:
    st = os.stat(path)
    return st.st_mtime_ns, st.st_size


class SourceCache:
    """Bounded, thread-safe LRU of open :class:`GraphSource` handles,
    keyed by ``(path, mtime_ns, size, open-kwargs)``.

    ``get`` returns the cached handle when the file on disk still
    matches the entry's stat key, else drops the stale entry and
    reopens.  ``capacity`` bounds simultaneously-open handles (mmaps +
    decoded sections); eviction is strict LRU.  All open keyword
    arguments participate in the key, so ``get(p)`` and
    ``get(p, weighted=False)`` are distinct entries (kwarg values must
    be hashable).
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY, *, open_fn=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._open_fn = open_graph if open_fn is None else open_fn
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._pending: Dict[tuple, _Pending] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0
        # (path, section) -> {"stat": (mtime_ns, size) | None,
        #                     "error": str, "count": int}
        self._quarantined: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._faults = {"open_retries": 0, "open_faults": 0,
                        "corrupt_errors": 0, "quarantines": 0,
                        "recovered": 0, "wait_timeouts": 0}

    # -- core ----------------------------------------------------------------

    def get(self, path: str, **open_kw) -> GraphSource:
        """The cached handle for ``path`` (opened with ``open_kw``),
        opening at most once per (path, stat, kwargs) across threads.
        A changed file (mtime or size) invalidates the old entry and
        reopens; raising opens are not cached (the next request
        retries)."""
        path = str(path)
        slot = (path, tuple(sorted(open_kw.items())))
        while True:
            key = _stat_key(path)       # raises for missing paths — uncached
            with self._lock:
                ent = self._entries.get(slot)
                if ent is not None:
                    if ent.key == key:
                        self._hits += 1
                        self._entries.move_to_end(slot)
                        return ent.source
                    # snapshot swapped under us: drop and reopen (the
                    # swap also lifts any quarantine on the path)
                    del self._entries[slot]
                    self._invalidations += 1
                    self._clear_quarantine_locked(path, key)
                pending = self._pending.get(slot)
                if pending is None:
                    pending = self._pending[slot] = _Pending()
                    opener = True
                else:
                    opener = False
            if not opener:
                # watchdogged wait: a wedged opener (stuck IO inside
                # open) must not strand every other request forever
                if not pending.event.wait(faults_mod.WATCHDOG_S):
                    with self._lock:
                        self._faults["wait_timeouts"] += 1
                    raise StageTimeout(
                        f"SourceCache: open of {path} still pending after "
                        f"{faults_mod.WATCHDOG_S:.1f}s (REPRO_WATCHDOG_S); "
                        f"the opening thread is stuck")
                if pending.source is not None:
                    # served the opener's handle: a hit, like any other
                    # request answered without opening the file
                    with self._lock:
                        self._hits += 1
                    return pending.source
                # the opener failed; retry (surfacing our own error)
                continue
            # the pending event MUST be set on every exit from this
            # opener block — an exception anywhere (the open itself, or
            # bookkeeping after it) that skipped the set would leave
            # every waiter blocked forever on a slot nobody owns
            try:
                source = faults_mod.call_with_retries(
                    lambda: self._open_once(path, open_kw),
                    describe=f"SourceCache open {path}",
                    on_retry=self._note_open_retry)
                pending.source = source
                with self._lock:
                    self._misses += 1
                    self._entries[slot] = _Entry(key, source)
                    self._entries.move_to_end(slot)
                    while len(self._entries) > self.capacity:
                        self._entries.popitem(last=False)
                        self._evictions += 1
                return source
            except BaseException as exc:
                pending.error = exc
                raise
            finally:
                with self._lock:
                    self._pending.pop(slot, None)
                pending.event.set()

    def _open_once(self, path: str, open_kw: Dict[str, Any]) -> GraphSource:
        if faults_mod._ACTIVE is not None:      # chaos hook (open site)
            faults_mod.inject("open", 0, where=path)
        return self._open_fn(path, **open_kw)

    def _note_open_retry(self, exc: BaseException) -> None:
        with self._lock:
            self._faults["open_retries"] += 1

    def query(self, path: str, op: str, *, rows=None, vertex=None,
              method: str = "staged", rho: int = 4,
              with_weights: bool = False, **open_kw) -> Any:
        """One request against the cache.  ``op`` selects the product:

        ==============  ==================================================
        op              result
        ==============  ==================================================
        ``info``        :class:`~repro.core.source.SourceInfo`
        ``csr``         the full :class:`~repro.core.types.CSR`
        ``rows``        ``.csr(rows=rows)`` — row-local CSR slice
        ``neighbors``   ``.neighbors(vertex)`` point lookup
        ``degree``      ``.degree(vertex)``
        ``edgelist``    the full :class:`~repro.core.types.EdgeList`
        ==============  ==================================================

        A corrupt section (CRC/decode failure, surfaced as
        :class:`~repro.core.snapshot.SnapshotError`) quarantines
        ``(path, section)``: this and subsequent requests touching that
        section get a structured :class:`CorruptGraphError` while other
        sections and other graphs keep serving; swapping the file on
        disk lifts the quarantine (see docs/robustness.md).
        """
        self.check_quarantine(path, _OP_SECTIONS.get(op))
        src = self.get(path, **open_kw)
        try:
            if op == "info":
                return src.info()
            if op in ("csr", "full"):
                return src.csr(method=method, rho=rho)
            if op in ("rows", "csr_rows", "range"):
                if rows is None:
                    raise ValueError("op 'rows' needs rows=")
                return src.csr(method=method, rho=rho, rows=rows)
            if op in ("neighbors", "point"):
                if vertex is None:
                    raise ValueError("op 'neighbors' needs vertex=")
                return src.neighbors(vertex, with_weights=with_weights)
            if op == "degree":
                if vertex is None:
                    raise ValueError("op 'degree' needs vertex=")
                return src.degree(vertex)
            if op == "edgelist":
                return src.edgelist()
        except SnapshotError as exc:
            raise self.report_corrupt(path, exc, op=op) from exc
        raise ValueError(
            f"unknown query op {op!r}; one of: info, csr, rows, neighbors, "
            f"degree, edgelist")

    # -- corruption quarantine -----------------------------------------------

    def check_quarantine(self, path: str,
                         sections: Optional[Tuple[str, ...]] = None) -> None:
        """Raise :class:`CorruptGraphError` when a live quarantine entry
        for ``path`` covers one of ``sections`` (any section when
        ``None``).  Entries whose file changed on disk since the
        corrupt read (stat key differs) are *cleared* instead — the
        swap-recovery contract."""
        path = str(path)
        with self._lock:
            entries = [(k, rec) for k, rec in self._quarantined.items()
                       if k[0] == path]
        if not entries:
            return
        try:
            key = _stat_key(path)
        except OSError:
            key = None                  # vanished file: treat as swapped
        hit = None
        with self._lock:
            for (p, sec), rec in entries:
                if rec["stat"] != key:
                    if self._quarantined.pop((p, sec), None) is not None:
                        self._faults["recovered"] += 1
                    continue
                # an op with an empty section tuple ("info") reads no
                # payload and is never blocked, even by an "unknown"
                # quarantine — health probes must outlive the corruption
                if sections is None or (len(sections) > 0 and
                                        (sec in sections or sec == "unknown")):
                    hit = (sec, rec)
            if hit is not None:
                self._faults["corrupt_errors"] += 1
                hit[1]["count"] += 1
        if hit is not None:
            sec, rec = hit
            raise CorruptGraphError(
                f"{path}: section {sec!r} is quarantined after a corrupt "
                f"read ({rec['error']}); serving resumes when the file is "
                f"replaced on disk",
                path=path, section=sec)

    def report_corrupt(self, path: str, exc: BaseException, *,
                       op: Optional[str] = None) -> CorruptGraphError:
        """Record a corrupt read of ``path`` (quarantining the section
        named by ``exc.section``, or ``"unknown"``) and return the
        structured error for the caller to raise.  Idempotent per
        section; counts every report."""
        path = str(path)
        section = getattr(exc, "section", None) or "unknown"
        try:
            key = _stat_key(path)
        except OSError:
            key = None
        with self._lock:
            rec = self._quarantined.get((path, section))
            if rec is None:
                rec = self._quarantined[(path, section)] = {
                    "stat": key, "error": str(exc), "count": 0}
                self._faults["quarantines"] += 1
            rec["count"] += 1
            rec["stat"] = key
            rec["error"] = str(exc)
            self._faults["corrupt_errors"] += 1
        return CorruptGraphError(
            f"{path}: corrupt read of section {section!r}"
            f"{f' during op {op!r}' if op else ''}: {exc}",
            path=path, section=section, op=op)

    def quarantined(self) -> List[Dict[str, Any]]:
        """Live quarantine entries (path, section, error, count)."""
        with self._lock:
            return [{"path": p, "section": s, "error": rec["error"],
                     "count": rec["count"]}
                    for (p, s), rec in self._quarantined.items()]

    def _clear_quarantine_locked(self, path: str, new_key) -> None:
        """Drop quarantine entries for ``path`` whose recorded stat no
        longer matches ``new_key`` (the file was swapped).  Caller holds
        the lock."""
        for k in [k for k in self._quarantined if k[0] == path]:
            if self._quarantined[k]["stat"] != new_key:
                del self._quarantined[k]
                self._faults["recovered"] += 1

    # -- management ----------------------------------------------------------

    def invalidate(self, path: Optional[str] = None) -> int:
        """Drop entries for ``path`` (all its kwarg variants), or every
        entry with ``path=None``.  Returns the number dropped.  In-use
        handles stay valid for their holders — only the cache forgets
        them."""
        with self._lock:
            if path is None:
                n = len(self._entries)
                self._entries.clear()
            else:
                path = str(path)
                stale = [s for s in self._entries if s[0] == path]
                for s in stale:
                    del self._entries[s]
                n = len(stale)
            self._invalidations += n
            return n

    def clear(self) -> None:
        self.invalidate(None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, path: str) -> bool:
        with self._lock:
            return any(s[0] == str(path) for s in self._entries)

    def stats(self) -> Dict[str, Any]:
        """Counters since construction: ``hits``/``misses`` (misses ==
        opens that were cached), ``evictions`` (capacity),
        ``invalidations`` (stat-key changes + explicit), ``size``, and
        ``frame_cache`` — the decoded-frame memo counters summed over
        the hot handles' pinned snapshots (bytes held, hits, LRU
        evictions past ``snapshot.FRAME_CACHE_BYTES``), the memory the
        selective-read path pins on this cache's behalf.

        ``faults`` is the robustness health block: per-cache counters
        (open retries, corrupt reads, quarantines entered/recovered,
        watchdogged waits), the live quarantine list, the process-wide
        recovery counters from :mod:`repro.core.faults` (IO retries,
        stage timeouts, shard re-executions), and — when a fault plan
        is active — the injected-fault counts by ``site:kind``."""
        plan = faults_mod.active_plan()
        with self._lock:
            frame = {"frames": 0, "bytes": 0, "hits": 0, "evictions": 0}
            for ent in self._entries.values():
                fc = getattr(ent.source, "frame_cache_stats", None)
                fc = fc() if callable(fc) else None
                if fc:
                    for k in frame:
                        frame[k] += fc.get(k, 0)
            faults = dict(self._faults)
            faults["quarantined"] = [
                {"path": p, "section": s, "count": rec["count"]}
                for (p, s), rec in self._quarantined.items()]
            faults.update(faults_mod.counters())
            faults["injected"] = {} if plan is None else plan.injected()
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "invalidations": self._invalidations,
                    "size": len(self._entries),
                    "capacity": self.capacity,
                    "frame_cache": frame,
                    "faults": faults}


_default: Optional[SourceCache] = None
_default_lock = threading.Lock()


def default_cache() -> SourceCache:
    """The process-wide cache behind the module-level :func:`query`."""
    global _default
    with _default_lock:
        if _default is None:
            _default = SourceCache()
        return _default


def query(path: str, op: str, **kw) -> Any:
    """Serve one graph query through the process-wide hot-graph cache —
    the front door for the query service (see :meth:`SourceCache.query`
    for ops).  ``repro.serve`` / benchmark drivers call this."""
    return default_cache().query(path, op, **kw)
