"""Process-level hot-graph cache: a bounded, thread-safe LRU of open
:class:`~repro.core.source.GraphSource` handles, and the serve-facing
``query(path, op)`` entry built on it.

A graph-query service (ParaGrapher's serving scenario: thousands of
point/range reads per second against a snapshot corpus) must not pay
open-and-validate per request, must notice when a snapshot is swapped
under it, and must bound how many mmaps / decoded sections it pins.
This module is that layer:

    from repro.core.cache import query

    nbrs = query("web.gvel", "neighbors", vertex=42)
    rows = query("web.gvel", "rows", rows=range(100, 200))
    csr  = query("web.gvel", "csr")

* **Keyed by content, not path**: entries are validated against
  ``(mtime_ns, size)`` on every hit — overwriting a snapshot (the
  swap-under-the-server scenario) invalidates its entry on the next
  request, which reopens the new file.  No TTLs, no staleness window
  beyond the filesystem's mtime granularity.
* **Bounded LRU**: at most ``capacity`` open handles; the least
  recently used is evicted (dropping its mmap and decoded-section
  memos with it).
* **Thread-safe, single-open**: concurrent requests for the same path
  coordinate through a pending slot so a cold file is opened and
  validated exactly once, not once per waiting thread; every wait-er
  gets the same handle.  Product access on a shared handle is safe:
  section decodes are lock-guarded per section
  (:mod:`repro.core.snapshot`) and memoized products are immutable.

The default process cache (capacity from ``$REPRO_CACHE_CAPACITY``,
else 16) serves the module-level :func:`query`; build explicit
:class:`SourceCache` instances for isolation (tests, per-tenant
caches).  Cache semantics and invalidation rules: ``docs/query.md``.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

from .source import GraphSource, open_graph

_DEFAULT_CAPACITY = int(os.environ.get("REPRO_CACHE_CAPACITY", "16"))


class _Pending:
    """One in-flight open: waiters block on ``event``; the opener
    publishes ``source`` or ``error`` before setting it."""

    __slots__ = ("event", "source", "error")

    def __init__(self):
        self.event = threading.Event()
        self.source: Optional[GraphSource] = None
        self.error: Optional[BaseException] = None


class _Entry:
    __slots__ = ("key", "source")

    def __init__(self, key, source):
        self.key = key
        self.source = source


def _stat_key(path: str) -> Tuple[int, int]:
    st = os.stat(path)
    return st.st_mtime_ns, st.st_size


class SourceCache:
    """Bounded, thread-safe LRU of open :class:`GraphSource` handles,
    keyed by ``(path, mtime_ns, size, open-kwargs)``.

    ``get`` returns the cached handle when the file on disk still
    matches the entry's stat key, else drops the stale entry and
    reopens.  ``capacity`` bounds simultaneously-open handles (mmaps +
    decoded sections); eviction is strict LRU.  All open keyword
    arguments participate in the key, so ``get(p)`` and
    ``get(p, weighted=False)`` are distinct entries (kwarg values must
    be hashable).
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY, *, open_fn=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._open_fn = open_graph if open_fn is None else open_fn
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._pending: Dict[tuple, _Pending] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0

    # -- core ----------------------------------------------------------------

    def get(self, path: str, **open_kw) -> GraphSource:
        """The cached handle for ``path`` (opened with ``open_kw``),
        opening at most once per (path, stat, kwargs) across threads.
        A changed file (mtime or size) invalidates the old entry and
        reopens; raising opens are not cached (the next request
        retries)."""
        path = str(path)
        slot = (path, tuple(sorted(open_kw.items())))
        while True:
            key = _stat_key(path)       # raises for missing paths — uncached
            with self._lock:
                ent = self._entries.get(slot)
                if ent is not None:
                    if ent.key == key:
                        self._hits += 1
                        self._entries.move_to_end(slot)
                        return ent.source
                    # snapshot swapped under us: drop and reopen
                    del self._entries[slot]
                    self._invalidations += 1
                pending = self._pending.get(slot)
                if pending is None:
                    pending = self._pending[slot] = _Pending()
                    opener = True
                else:
                    opener = False
            if not opener:
                pending.event.wait()
                if pending.source is not None:
                    # served the opener's handle: a hit, like any other
                    # request answered without opening the file
                    with self._lock:
                        self._hits += 1
                    return pending.source
                # the opener failed; retry (surfacing our own error)
                continue
            # the pending event MUST be set on every exit from this
            # opener block — an exception anywhere (the open itself, or
            # bookkeeping after it) that skipped the set would leave
            # every waiter blocked forever on a slot nobody owns
            try:
                source = self._open_fn(path, **open_kw)
                pending.source = source
                with self._lock:
                    self._misses += 1
                    self._entries[slot] = _Entry(key, source)
                    self._entries.move_to_end(slot)
                    while len(self._entries) > self.capacity:
                        self._entries.popitem(last=False)
                        self._evictions += 1
                return source
            except BaseException as exc:
                pending.error = exc
                raise
            finally:
                with self._lock:
                    self._pending.pop(slot, None)
                pending.event.set()

    def query(self, path: str, op: str, *, rows=None, vertex=None,
              method: str = "staged", rho: int = 4,
              with_weights: bool = False, **open_kw) -> Any:
        """One request against the cache.  ``op`` selects the product:

        ==============  ==================================================
        op              result
        ==============  ==================================================
        ``info``        :class:`~repro.core.source.SourceInfo`
        ``csr``         the full :class:`~repro.core.types.CSR`
        ``rows``        ``.csr(rows=rows)`` — row-local CSR slice
        ``neighbors``   ``.neighbors(vertex)`` point lookup
        ``degree``      ``.degree(vertex)``
        ``edgelist``    the full :class:`~repro.core.types.EdgeList`
        ==============  ==================================================
        """
        src = self.get(path, **open_kw)
        if op == "info":
            return src.info()
        if op in ("csr", "full"):
            return src.csr(method=method, rho=rho)
        if op in ("rows", "csr_rows", "range"):
            if rows is None:
                raise ValueError("op 'rows' needs rows=")
            return src.csr(method=method, rho=rho, rows=rows)
        if op in ("neighbors", "point"):
            if vertex is None:
                raise ValueError("op 'neighbors' needs vertex=")
            return src.neighbors(vertex, with_weights=with_weights)
        if op == "degree":
            if vertex is None:
                raise ValueError("op 'degree' needs vertex=")
            return src.degree(vertex)
        if op == "edgelist":
            return src.edgelist()
        raise ValueError(
            f"unknown query op {op!r}; one of: info, csr, rows, neighbors, "
            f"degree, edgelist")

    # -- management ----------------------------------------------------------

    def invalidate(self, path: Optional[str] = None) -> int:
        """Drop entries for ``path`` (all its kwarg variants), or every
        entry with ``path=None``.  Returns the number dropped.  In-use
        handles stay valid for their holders — only the cache forgets
        them."""
        with self._lock:
            if path is None:
                n = len(self._entries)
                self._entries.clear()
            else:
                path = str(path)
                stale = [s for s in self._entries if s[0] == path]
                for s in stale:
                    del self._entries[s]
                n = len(stale)
            self._invalidations += n
            return n

    def clear(self) -> None:
        self.invalidate(None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, path: str) -> bool:
        with self._lock:
            return any(s[0] == str(path) for s in self._entries)

    def stats(self) -> Dict[str, Any]:
        """Counters since construction: ``hits``/``misses`` (misses ==
        opens that were cached), ``evictions`` (capacity),
        ``invalidations`` (stat-key changes + explicit), ``size``, and
        ``frame_cache`` — the decoded-frame memo counters summed over
        the hot handles' pinned snapshots (bytes held, hits, LRU
        evictions past ``snapshot.FRAME_CACHE_BYTES``), the memory the
        selective-read path pins on this cache's behalf."""
        with self._lock:
            frame = {"frames": 0, "bytes": 0, "hits": 0, "evictions": 0}
            for ent in self._entries.values():
                fc = getattr(ent.source, "frame_cache_stats", None)
                fc = fc() if callable(fc) else None
                if fc:
                    for k in frame:
                        frame[k] += fc.get(k, 0)
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "invalidations": self._invalidations,
                    "size": len(self._entries),
                    "capacity": self.capacity,
                    "frame_cache": frame}


_default: Optional[SourceCache] = None
_default_lock = threading.Lock()


def default_cache() -> SourceCache:
    """The process-wide cache behind the module-level :func:`query`."""
    global _default
    with _default_lock:
        if _default is None:
            _default = SourceCache()
        return _default


def query(path: str, op: str, **kw) -> Any:
    """Serve one graph query through the process-wide hot-graph cache —
    the front door for the query service (see :meth:`SourceCache.query`
    for ops).  ``repro.serve`` / benchmark drivers call this."""
    return default_cache().query(path, op, **kw)
