"""Vertex-degree computation strategies (GVEL §4.2.1-4.2.2, TPU-adapted).

On CPU the contrast is global-atomics vs rho-partitioned atomics.  XLA has
no fetch-add; its scatter-add serializes colliding updates the same way a
contended cache line does, so the partitioned variant maps to rho
*independent* scatter-adds into disjoint accumulators that are then
tree-combined — identical contention math, associative implementation.
Edges are assigned to partitions by chunk index mod rho, mirroring the
paper's `thread_id mod rho`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def degrees_global(src: jax.Array, num_vertices: int) -> jax.Array:
    """Single shared accumulator (degree-global, PIGO-like baseline)."""
    idx = jnp.where(src >= 0, src, num_vertices)
    return jnp.zeros((num_vertices,), I32).at[idx].add(1, mode="drop")


@functools.partial(jax.jit, static_argnames=("num_vertices", "rho"))
def degrees_partitioned(src: jax.Array, num_vertices: int, rho: int = 4) -> jax.Array:
    """rho partition-local accumulators (degree-thread / mod-rho of the paper).

    Returns (rho, V) partial degrees; ``combine_degrees`` sums them.
    """
    e = src.shape[0]
    chunk = -(-e // rho)
    part = (jnp.arange(e, dtype=I32) // chunk) % rho
    idx = jnp.where(src >= 0, src, num_vertices)
    return jnp.zeros((rho, num_vertices), I32).at[part, idx].add(1, mode="drop")


@jax.jit
def combine_degrees(pdeg: jax.Array) -> jax.Array:
    return jnp.sum(pdeg, axis=0, dtype=I32)


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def degrees_sort(src: jax.Array, num_vertices: int) -> jax.Array:
    """Sort + segment-boundary differences: contention-free alternative."""
    key = jnp.where(src >= 0, src, num_vertices)
    s = jnp.sort(key)
    # first occurrence index of each vertex in the sorted array
    lo = jnp.searchsorted(s, jnp.arange(num_vertices, dtype=I32), side="left")
    hi = jnp.searchsorted(s, jnp.arange(num_vertices, dtype=I32), side="right")
    return (hi - lo).astype(I32)


def degrees_np(src: np.ndarray, num_vertices: int) -> np.ndarray:
    """Host oracle."""
    src = src[src >= 0]
    return np.bincount(src, minlength=num_vertices).astype(np.int64)


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def offsets_from_degrees(deg: jax.Array, num_vertices: int) -> jax.Array:
    """Exclusive scan -> CSR offsets (V+1,)."""
    return jnp.concatenate([jnp.zeros((1,), deg.dtype), jnp.cumsum(deg)])
