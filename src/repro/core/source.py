"""GraphSource: the lazy, introspectable front door for graph loading.

GVEL's thesis is that loading should pay only for what the caller
actually consumes.  This module is where that becomes an API contract:

    from repro.core import open_graph

    src = open_graph("web.gvel")      # resolve format/codec/engine ONCE
    src.info()                        # header-only probe: V/E/codec/size
    src.csr()                         # lazy, memoized; decodes only the
                                      # CSR sections of a .gvel snapshot
    src.edgelist()                    # lazy, memoized
    src.save("web.z.gvel", compress="zlib")   # write-once snapshot path

A :class:`GraphSource` is a cheap handle.  Opening one sniffs the
format (``.gvel`` snapshot magic / MTX banner / plain text) and the
compression codec (gzip / framed, by magic, never extension) exactly
once; every product is computed on first request and memoized on the
handle.  Laziness is real, not cosmetic:

* ``info()`` reads *headers only* — a ``.gvel`` header + section
  table (never payload bytes), an MTX banner + size line, a framed
  container header.  ``info()`` on a multi-MB text edgelist does not
  parse it (plain text has no header, so V/E report as unknown).
* ``csr()`` on a both-sections compressed snapshot decompresses only
  the CSR sections; the edgelist frame streams are never decoded
  (:mod:`repro.core.snapshot` decodes per section, on first access).
* The price of laziness is **deferred corruption errors**: damage
  inside a compressed section payload surfaces (as
  :class:`~repro.core.snapshot.SnapshotError`) at first access of a
  product needing that section, not at ``open_graph``.  Structural
  damage — bad magic, truncated table, unknown codec — still fails at
  open (with ``validate=True``, the default).  See ``docs/api.md``.

The historical free functions (``load_edgelist``/``load_csr``/
``read_edgelist*``/``read_csr``) remain as thin wrappers delegating to
a ``GraphSource``, so existing call sites keep working unchanged.

``python -m repro.core.source <path>`` prints ``info()`` as JSON — a
quick "what is this file?" probe for CI and humans.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .faults import fault_plan
from .loader import (DEFAULT_CSR_ENGINE, DEFAULT_EDGELIST_ENGINE, LoadOptions,
                     available_engines, csr_convert_engine, get_engine,
                     read_csr_sharded_via, read_csr_via, read_edgelist_via,
                     resolve_tuned)
from .types import CSR, EdgeList

FORMAT_GVEL = "gvel"
FORMAT_MTX = "mtx"
FORMAT_TEXT = "text"

_MTX_BANNER = b"%%MatrixMarket"


def _normalize_rows(rows) -> Tuple[int, int]:
    """``rows`` -> ``(lo, hi)``: a ``range`` with step 1 or a
    ``(lo, hi)`` pair; bounds checked against |V| downstream."""
    if isinstance(rows, range):
        if rows.step != 1:
            raise ValueError(f"rows must have step 1, got {rows!r}")
        return rows.start, max(rows.start, rows.stop)
    try:
        lo, hi = rows
    except (TypeError, ValueError):
        raise ValueError(
            f"rows must be a step-1 range or a (lo, hi) pair, "
            f"got {rows!r}") from None
    lo, hi = int(lo), int(hi)
    if hi < lo:
        raise ValueError(f"rows (lo, hi) must have lo <= hi, got {rows!r}")
    return lo, hi


def slice_csr(csr: CSR, lo: int, hi: int) -> CSR:
    """Vertex rows ``[lo, hi)`` of a global CSR as a row-local CSR:
    ``offsets`` rebased to 0, ``row_start=lo``, global ``num_vertices``
    — the same layout the snapshot partial-read path serves, so the
    fallback (slice the full product) and the fast path (decode only
    the touched frames) are interchangeable."""
    if csr.row_start != 0:
        raise ValueError("slice_csr expects a global CSR (row_start == 0)")
    if not 0 <= lo <= hi <= csr.num_rows:
        raise IndexError(
            f"row range [{lo}, {hi}) outside [0, {csr.num_rows})")
    offsets = np.asarray(csr.offsets)
    off = offsets[lo:hi + 1]
    e_lo = int(off[0]) if off.size else 0
    e_hi = int(off[-1]) if off.size else 0
    local = off if e_lo == 0 else off - off.dtype.type(e_lo)
    targets = np.asarray(csr.targets)[e_lo:e_hi]
    w = None if csr.weights is None else np.asarray(csr.weights)[e_lo:e_hi]
    return CSR(local, targets, w, csr.num_vertices, row_start=lo)


@dataclasses.dataclass(frozen=True)
class SourceInfo:
    """Cheap metadata about a graph file — headers only, no payloads.

    ``None`` means "unknown without parsing": plain text has no header,
    so its ``num_vertices``/``num_edges``/``weighted`` are None, while
    ``.gvel`` and MTX report theirs straight from the header.  For MTX,
    ``num_edges`` is the declared entry count (pre symmetric
    expansion).  ``raw_bytes`` is the uncompressed payload size when a
    header declares it (framed container, ``.gvel`` table, gzip
    trailer hint), else the on-disk size for raw files.
    """

    path: str
    format: str                       # "gvel" | "mtx" | "text"
    codec: Optional[str]              # "gzip" / "framed-zlib" / section codec
    size_bytes: int                   # on-disk size
    raw_bytes: Optional[int]          # uncompressed size, when known
    version: Optional[int]            # .gvel container version
    num_vertices: Optional[int]
    num_edges: Optional[int]
    weighted: Optional[bool]
    symmetric: Optional[bool]         # MTX banner symmetry (None elsewhere)
    has_edgelist: Optional[bool]      # .gvel sections present
    has_csr: Optional[bool]
    engine: Optional[str]             # engine pinned at open (None = default)
    # per-section frame counts of a compressed .gvel's sections
    # ({"csr_offsets": 3, ...}; empty for raw sections, None for non-gvel)
    # — the partial-decode planner's view: a row range decodes only the
    # frames its byte span touches, and this is how many there are.
    section_frames: Optional[Dict[str, int]] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _detect(path: str, offset: int) -> Tuple[str, Optional[str]]:
    """(format, compression-kind) by magic sniff, never extension.

    ``offset != 0`` means the caller is handing us body bytes embedded
    in another container (an MTX body) — never a snapshot or a nested
    MTX, so only the compression sniff applies.  Unreadable/missing
    paths sniff as raw text so non-file engines (tests, RPC) keep
    working; existence is ``validate``'s job.
    """
    from .codecs import compression_of, peek_bytes
    from .snapshot import MAGIC, is_snapshot

    kind = compression_of(path)
    if offset != 0:
        return FORMAT_TEXT, kind
    if is_snapshot(path):
        return FORMAT_GVEL, None
    if kind is not None and peek_bytes(path, len(MAGIC)) == MAGIC:
        # A whole-file-compressed snapshot would decode as text
        # garbage; .gvel v2 compresses *inside* the container.
        raise ValueError(
            f"{path}: externally compressed .gvel snapshot; "
            f"decompress it, or recreate it with internal section "
            f"compression (scripts/convert.py --compress)")
    if peek_bytes(path, len(_MTX_BANNER)) == _MTX_BANNER:
        return FORMAT_MTX, kind
    return FORMAT_TEXT, kind


class GraphSource:
    """A lazy handle on one graph file.

    Construction (via :func:`open_graph`) resolves format, compression
    codec, and engine once; products — :meth:`info`, :meth:`edgelist`,
    :meth:`csr`, :meth:`stream` — are computed on first request and
    memoized on the handle (``src.csr() is src.csr()``).  The handle
    never re-sniffs the file; reopen after rewriting a path.

    Laziness/memoization guarantees and the deferred-corruption-error
    semantics are documented in ``docs/api.md``.
    """

    def __init__(self, path: str, opts: LoadOptions, *, validate: bool = True):
        self.path = str(path)
        fmt, ckind = _detect(self.path, opts.offset)
        if fmt == FORMAT_GVEL:
            # any engine request routes to snapshot: a text parser
            # pointed at a binary snapshot would decode garbage
            opts = opts.replace(engine="snapshot")
        self.options = opts
        self.format = fmt
        self._ckind = ckind                   # "gzip" | "framed" | None
        self._info: Optional[SourceInfo] = None
        self._el: Optional[EdgeList] = None
        self._el_engine: Optional[str] = None
        self._csrs: Dict[Tuple[str, int], CSR] = {}
        self._sharded_csrs: Dict[Tuple[Any, str, int], CSR] = {}
        self._mtx_hdr = None
        self._gvel_peek = None                # (version, flags, V, E, entries)
        self._framed_hdr = None               # codecs.FramedInfo
        self._snap = None                     # pinned lazy Snapshot (gvel)
        if validate:
            self._validate()

    def __repr__(self) -> str:
        eng = self.options.engine or "auto"
        codec = f", codec={self._ckind}" if self._ckind else ""
        return (f"GraphSource({self.path!r}, format={self.format}"
                f"{codec}, engine={eng})")

    # -- open-time checks ----------------------------------------------------

    def _validate(self) -> None:
        """Cheap structural validation at open: existence, container
        headers, engine name, section codec ids.  Never touches
        section payloads."""
        os.stat(self.path)
        if self.options.engine is not None:
            get_engine(self.options.engine)
        if self.format == FORMAT_GVEL:
            from . import codecs
            from .snapshot import SnapshotError
            entries = self._peek_gvel()[4]
            for sid, _code, _off, _nbytes, codec_id, _raw in entries:
                if codec_id:
                    try:                      # table metadata, not payload:
                        codecs.codec_for_id(codec_id)   # fail at open
                    except ValueError as exc:
                        raise SnapshotError(
                            f"{self.path}: section {sid}: {exc}") from None
        elif self.format == FORMAT_MTX:
            self._mtx_header()
        elif self._ckind == "framed":
            self._framed_info()

    def _peek_gvel(self):
        if self._gvel_peek is None:
            from .snapshot import peek_table
            self._gvel_peek = peek_table(self.path)
        return self._gvel_peek

    def _mtx_header(self):
        if self._mtx_hdr is None:
            from .mtx import read_header
            self._mtx_hdr = read_header(self.path)
        return self._mtx_hdr

    def _framed_info(self):
        if self._framed_hdr is None:
            from .codecs import read_framed_header
            self._framed_hdr = read_framed_header(self.path)
        return self._framed_hdr

    # -- option resolution ---------------------------------------------------

    def _weighted(self) -> bool:
        """Resolve ``weighted=None`` ("what the file says") once."""
        if self.options.weighted is not None:
            return self.options.weighted
        if self.format == FORMAT_GVEL:
            from .snapshot import FLAG_WEIGHTED
            return bool(self._peek_gvel()[1] & FLAG_WEIGHTED)
        if self.format == FORMAT_MTX:
            return self._mtx_header().meta.weighted
        return False                          # text has no header to ask

    def _opts_for(self, product: str) -> LoadOptions:
        engine = self.options.engine
        if engine is None:
            engine = (DEFAULT_EDGELIST_ENGINE if product == "edgelist"
                      else DEFAULT_CSR_ENGINE)
        return self.options.replace(engine=engine, weighted=self._weighted())

    # -- products ------------------------------------------------------------

    def info(self) -> SourceInfo:
        """Header-only metadata probe; memoized.  Reads the ``.gvel``
        header + section table, the MTX banner + size line, or the
        framed-container header — never a section payload and never a
        text parse."""
        if self._info is not None:
            return self._info
        size = os.path.getsize(self.path)
        codec = self._external_codec_name()
        version = v = e = None
        weighted = symmetric = has_el = has_csr = None
        section_frames = None
        raw = size if codec is None else None
        if self.format == FORMAT_GVEL:
            from . import codecs
            from .snapshot import (FLAG_CSR, FLAG_EDGELIST, FLAG_WEIGHTED,
                                   section_frame_counts)
            version, flags, v, e, entries = self._peek_gvel()
            weighted = bool(flags & FLAG_WEIGHTED)
            has_el = bool(flags & FLAG_EDGELIST)
            has_csr = bool(flags & FLAG_CSR)
            raw = sum(entry[5] for entry in entries)
            ids = {entry[4] for entry in entries} - {0}
            if ids:
                names = []
                for cid in sorted(ids):
                    try:
                        names.append(codecs.codec_for_id(cid).name)
                    except ValueError:
                        names.append(f"id{cid}")
                codec = "+".join(names)
                # frame counts per compressed section: a header walk
                # over the 12-byte frame headers (never a payload
                # decompression) — what the partial-decode planner sees
                section_frames = section_frame_counts(self.path)
        elif self.format == FORMAT_MTX:
            hdr = self._mtx_header()
            v, e = hdr.meta.num_vertices, hdr.meta.num_edges
            weighted, symmetric = hdr.meta.weighted, hdr.meta.symmetric
        if self._ckind == "framed":
            raw = self._framed_info().orig_len
        elif self._ckind == "gzip":
            from .codecs import gzip_length_hint
            try:
                raw = gzip_length_hint(self.path)
            except ValueError:
                raw = None
        self._info = SourceInfo(
            path=self.path, format=self.format, codec=codec,
            size_bytes=size, raw_bytes=raw, version=version,
            num_vertices=v, num_edges=e, weighted=weighted,
            symmetric=symmetric, has_edgelist=has_el, has_csr=has_csr,
            engine=self.options.engine, section_frames=section_frames)
        return self._info

    def _external_codec_name(self) -> Optional[str]:
        if self._ckind == "framed":
            return f"framed-{self._framed_info().codec.name}"
        return self._ckind                    # "gzip" or None

    def edgelist(self) -> EdgeList:
        """The graph as an :class:`EdgeList`; computed on first call,
        memoized on the handle."""
        if self._el is None:
            opts = self._opts_for("edgelist")
            with fault_plan(opts.faults):
                if self.format == FORMAT_MTX:
                    self._el = self._mtx_edgelist(opts)
                else:
                    self._el = read_edgelist_via(self.path, opts)
            self._el_engine = opts.engine
        return self._el

    def _build_method(self, method: Optional[str]) -> str:
        """Per-call ``method`` wins; else the handle's
        ``LoadOptions.method``; else ``staged``."""
        return method or self.options.method or "staged"

    def csr(self, *, method: Optional[str] = None, rho: int = 4,
            bin_bits: Optional[int] = None, rows=None) -> CSR:
        """The graph as a :class:`CSR`; computed on first call per
        ``(method, rho, bin_bits)``, memoized on the handle.
        ``method=None`` resolves to the handle's ``LoadOptions.method``
        (``open_graph(..., method="binned")``), then ``staged``.  A
        ``.gvel`` snapshot with an embedded CSR serves it straight from
        mmap (``method``/``rho``/``bin_bits`` do not apply — the stored
        CSR wins).

        ``rows`` selects a vertex-range slice: a ``range`` with step 1
        (or a ``(lo, hi)`` pair), returning a row-local CSR —
        ``offsets`` rebased to 0, ``row_start=lo``, global
        ``num_vertices`` — per the selective-read contract in
        ``docs/query.md``.  On a ``.gvel`` snapshot with an embedded
        CSR this is a *partial load*: raw sections are sliced straight
        off the mmap (no full-section copy) and compressed sections
        decode only the frames the row range's byte span touches.
        Other sources (text, MTX, edgelist-only snapshots) fall back to
        slicing the full — memoized — CSR, so the result is identical
        either way.  Row slices are not memoized (the full product is;
        slices are cheap and unbounded in number)."""
        method = self._build_method(method)
        if bin_bits is None:
            bin_bits = self.options.bin_bits
        if rows is not None:
            return self._csr_rows(rows, method=method, rho=rho,
                                  bin_bits=bin_bits)
        key = (method, rho, bin_bits)
        if key not in self._csrs:
            if self.format == FORMAT_MTX:
                from .csr import convert_to_csr
                opts = self._opts_for("csr")
                csr = convert_to_csr(self.edgelist(), method=method, rho=rho,
                                     bin_bits=bin_bits,
                                     engine=csr_convert_engine(opts.engine))
            else:
                opts = self._opts_for("csr")
                with fault_plan(opts.faults):
                    csr = read_csr_via(
                        self.path, opts, method=method, rho=rho,
                        bin_bits=bin_bits,
                        fallback_edgelist=lambda: self._edgelist_for(opts))
            self._csrs[key] = csr
        return self._csrs[key]

    def _selective_snap(self):
        """The pinned lazy :class:`Snapshot` when selective reads can
        serve this source: ``.gvel`` format, no symmetrize/offset
        transform, an embedded CSR, and any forced ``num_vertices``
        agreeing with the header — else ``None`` (callers fall back to
        slicing the full product).

        Pinned on the handle, not fetched through the snapshot engine's
        single-slot memo: the serving cache (:mod:`repro.core.cache`)
        keeps handles hot across a multi-snapshot corpus, and a point
        read is only decode-free on repeat if the partially-decoded
        frame cache survives with the handle."""
        if (self.format != FORMAT_GVEL or self.options.symmetric
                or self.options.offset):
            return None
        snap = self._snap
        if snap is None:
            from .snapshot import read_snapshot
            snap = self._snap = read_snapshot(self.path, eager=False)
        if not snap.has_csr:
            return None
        nv = self.options.num_vertices
        if nv is not None and int(nv) != snap.num_vertices:
            return None
        return snap

    def frame_cache_stats(self) -> Optional[dict]:
        """Decoded-frame memo counters of the pinned lazy snapshot
        handle (:meth:`repro.core.snapshot.Snapshot.frame_cache_stats`),
        or ``None`` when no snapshot is pinned — non-``.gvel`` sources,
        or a selective path never touched."""
        snap = self._snap
        return None if snap is None else snap.frame_cache_stats()

    def _csr_rows(self, rows, *, method: str, rho: int,
                  bin_bits: Optional[int] = None) -> CSR:
        lo, hi = _normalize_rows(rows)
        snap = self._selective_snap()
        if snap is not None:
            return snap.csr_rows(lo, hi, weighted=self._weighted())
        return slice_csr(self.csr(method=method, rho=rho, bin_bits=bin_bits),
                         lo, hi)

    def neighbors(self, u: int, *, with_weights: bool = False):
        """Point lookup: vertex ``u``'s neighbor ids as a 1-D int32
        array (ids and weights as a pair with ``with_weights=True``).
        On a CSR-embedded ``.gvel`` snapshot this reads only the bytes
        vertex ``u``'s adjacency spans — two offsets plus the target
        run — decoding at most the frames that span touches; other
        sources fall back to slicing the full memoized CSR.  Not
        memoized (see ``docs/query.md``; the hot-graph cache in
        :mod:`repro.core.cache` is the serving layer's memo)."""
        u = int(u)
        if with_weights and not self._weighted():
            raise ValueError(
                f"{self.path}: with_weights=True but source is unweighted")
        snap = self._selective_snap()
        if snap is not None:
            # weights decode only when the caller asked for them
            return snap.neighbors(u, weighted=bool(with_weights))
        full = self.csr()
        if not 0 <= u < full.num_rows:
            raise IndexError(f"{self.path}: vertex {u} outside "
                             f"[0, {full.num_rows})")
        lo, hi = int(full.offsets[u]), int(full.offsets[u + 1])
        ids = np.asarray(full.targets)[lo:hi]
        if not with_weights:
            return ids
        return ids, np.asarray(full.weights)[lo:hi]

    def degree(self, u: int) -> int:
        """Vertex ``u``'s out-degree — on a CSR-embedded snapshot this
        touches exactly two offset elements."""
        u = int(u)
        snap = self._selective_snap()
        if snap is not None:
            return snap.degree(u)
        full = self.csr()
        if not 0 <= u < full.num_rows:
            raise IndexError(f"{self.path}: vertex {u} outside "
                             f"[0, {full.num_rows})")
        return int(full.offsets[u + 1]) - int(full.offsets[u])

    def csr_sharded(self, mesh, *, axis: str = "data", rho: int = 4,
                    method: Optional[str] = None,
                    bin_bits: Optional[int] = None) -> CSR:
        """The graph as a :class:`CSR` sharded row-wise across ``mesh``
        along ``axis``; computed on first call per ``(mesh, axis, rho,
        method, bin_bits)``, memoized on the handle.

        Each mesh shard streams only its byte-range span of the file
        through the fused parse pipeline (:func:`repro.core.blocks.
        shard_plan` partitions the block plan; line ownership at span
        boundaries follows the terminating-newline rule, so no edge is
        parsed twice) and the packed per-shard device edges feed the
        distributed degree-psum / ``all_to_all`` / local-CSR build with
        no host detour.  ``offsets`` is the per-shard local offsets
        stacked along the mesh axis; see docs/distributed.md for the
        result layout.  Only text edgelists shard this way: MTX raises
        (banner semantics apply to :meth:`csr` only) and ``.gvel``
        snapshots raise (already parsed — no text to byte-partition).
        """
        if self.format == FORMAT_MTX:
            raise ValueError(
                f"{self.path}: csr_sharded() does not apply MTX banner "
                f"attributes; convert to a plain edgelist first or use "
                f".csr()")
        if self.format == FORMAT_GVEL:
            raise ValueError(
                f"{self.path}: .gvel snapshots are already parsed — "
                f"byte-range sharded streaming applies to text "
                f"edgelists; use .csr() and shard the result, or keep "
                f"the original text file for sharded loads")
        method = self._build_method(method)
        if bin_bits is None:
            bin_bits = self.options.bin_bits
        key = (mesh, axis, int(rho), method, bin_bits)
        if key not in self._sharded_csrs:
            with fault_plan(self.options.faults):
                self._sharded_csrs[key] = read_csr_sharded_via(
                    self.path, self._opts_for("csr"), mesh=mesh, axis=axis,
                    rho=rho, method=method, bin_bits=bin_bits)
        return self._sharded_csrs[key]

    def _edgelist_for(self, opts: LoadOptions) -> EdgeList:
        """EdgeList through a specific engine, sharing the memo when the
        engines coincide (always, when the caller pinned one engine at
        open).  Engines may differ in float rounding at the last ulp,
        so the CSR fallback never silently substitutes another
        engine's parse."""
        if self._el is not None and self._el_engine == opts.engine:
            return self._el
        el = read_edgelist_via(self.path, opts)
        if self._el is None:
            self._el, self._el_engine = el, opts.engine
        return el

    def _mtx_edgelist(self, opts: LoadOptions) -> EdgeList:
        from .mtx import read_mtx
        hdr = self._mtx_header()
        if opts.weighted and not hdr.meta.weighted:
            raise ValueError(
                f"{self.path}: weighted load requested but the MTX field "
                f"is 'pattern' (no weight column)")
        if (opts.num_vertices is not None
                and opts.num_vertices != hdr.meta.num_vertices):
            raise ValueError(
                f"{self.path}: num_vertices={opts.num_vertices} conflicts "
                f"with the MTX size line ({hdr.meta.num_vertices})")
        el = read_mtx(self.path, engine=opts.engine, **opts.engine_kw)
        if el.weights is not None and not opts.weighted:
            el = EdgeList(el.src, el.dst, None, el.num_edges, el.num_vertices)
        if opts.symmetric and not hdr.meta.symmetric:
            from .edgelist import symmetrize
            el = symmetrize(el)
        return el

    def stream(self, **kw):
        """Packed device edge buffers ``((src, dst, w, total), cap)``
        from a streaming-capable engine — the fused-build feed.  Not
        memoized (the buffers pin device memory).  Raises for host-only
        engines and for MTX (whose banner semantics — symmetry, field —
        only the EdgeList/CSR products apply)."""
        if self.format == FORMAT_MTX:
            raise ValueError(
                f"{self.path}: stream() does not apply MTX banner "
                f"attributes; use .edgelist() or .csr()")
        opts = resolve_tuned(self._opts_for("csr"))
        eng = get_engine(opts.engine)
        if not hasattr(eng, "stream"):
            raise ValueError(
                f"engine {opts.engine!r} has no stream fast path; "
                f"streaming engines: "
                f"{[n for n in available_engines() if hasattr(get_engine(n), 'stream')]}")
        with fault_plan(opts.faults):
            return eng.stream(self.path, **{**opts.stream_kwargs(), **kw})

    # -- write path ----------------------------------------------------------

    def save(self, out_path: str, *, compress: Optional[str] = None,
             compress_level: Optional[int] = None, csr: bool = True,
             method: Optional[str] = None, rho: int = 4) -> "GraphSource":
        """Write this graph as a ``.gvel`` snapshot and return a handle
        on the output — the symmetric write path ("write once, load
        many").  ``compress`` accepts a codec spec (``"zlib"``,
        ``"zstd:9"``); ``csr=False`` stores only the packed edgelist.
        Products are reused: a memoized edgelist/CSR is not recomputed.
        """
        from .snapshot import SnapshotError, save_snapshot
        method = self._build_method(method)
        if compress is not None:
            from .codecs import parse_codec_spec
            codec, level = parse_codec_spec(compress)
            compress = codec.name
            if compress_level is None:
                compress_level = level
        if self.format == FORMAT_GVEL and not self.info().has_edgelist:
            if not csr:
                raise SnapshotError(
                    f"{self.path}: csr=False requested but this CSR-only "
                    f"snapshot has no edgelist sections to save")
            el, csr_obj = None, self.csr()    # CSR-only snapshots re-save
        else:
            el = self.edgelist()
            csr_obj = None
            if csr:
                key = (method, rho)
                if self.format == FORMAT_TEXT and key not in self._csrs:
                    # both products are needed: build the CSR from the
                    # edgelist just parsed instead of re-parsing the file
                    # on the streaming fast path (one parse per save)
                    from .csr import convert_to_csr
                    opts = self._opts_for("csr")
                    self._csrs[key] = convert_to_csr(
                        el, method=method, rho=rho,
                        engine=csr_convert_engine(opts.engine))
                csr_obj = self.csr(method=method, rho=rho)
        save_snapshot(out_path, edgelist=el, csr=csr_obj, compress=compress,
                      compress_level=compress_level)
        return GraphSource(out_path, LoadOptions(), validate=True)


def open_graph(
    path: str,
    *,
    engine: Optional[str] = None,
    weighted: Optional[bool] = None,
    base: Optional[int] = None,
    offset: int = 0,
    validate: bool = True,
    symmetric: bool = False,
    num_vertices: Optional[int] = None,
    tune: bool = False,
    method: Optional[str] = None,
    bin_bits: Optional[int] = None,
    faults: Optional[Any] = None,
    **engine_kw,
) -> GraphSource:
    """Open a graph file as a lazy :class:`GraphSource` handle.

    Format (``.gvel`` / MTX / text) and compression (gzip / framed) are
    sniffed by magic once, here.  ``engine=None`` picks the per-product
    default (``numpy`` for edgelists, ``device`` for fused CSR builds;
    ``.gvel`` files always route to the snapshot engine).
    ``weighted=None`` means "what the file says" (snapshot flags / MTX
    banner; text resolves to False).  ``base=None`` defaults to the
    1-based text convention (snapshots are canonical 0-based and ignore
    it).  ``validate=True`` runs cheap structural checks at open —
    existence, container headers, engine name — but never touches
    section payloads; ``validate=False`` defers even those to first
    access (useful for paths only a custom engine knows how to read).
    ``engine_kw`` carries engine tuning knobs (``beta``,
    ``batch_blocks``, ``num_workers``, ...).  ``tune=True`` fills
    un-pinned streaming block geometry from the measured per-host
    profile (:mod:`repro.core.tune`; first use on a host runs the
    sweep and caches it — see docs/performance.md).  ``method``
    (``"global"``/``"staged"``/``"binned"``) pins the CSR build
    strategy for every ``.csr()``-family product off the handle, and
    ``bin_bits`` sets the binned build's vertex-range width; a per-call
    ``csr(method=...)`` still wins.  ``faults`` pins a
    :class:`repro.core.faults.FaultPlan` on the handle — every product
    load runs under that plan (see docs/robustness.md).
    """
    opts = LoadOptions(engine=engine, weighted=weighted, symmetric=symmetric,
                       base=1 if base is None else base,
                       num_vertices=num_vertices, offset=offset, tune=tune,
                       method=method, bin_bits=bin_bits, faults=faults,
                       engine_kw=dict(engine_kw))
    return GraphSource(path, opts, validate=validate)


def _main(argv: Optional[list] = None) -> int:
    """``python -m repro.core.source <path> [path ...]`` — print
    ``info()`` for each path as JSON (one object, or a list)."""
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.source",
        description="Probe graph files: print GraphSource.info() as JSON")
    ap.add_argument("paths", nargs="+", help="graph files (.el/.mtx/.gvel, "
                    "raw or compressed)")
    args = ap.parse_args(argv)
    out, failed = [], False
    for p in args.paths:
        try:
            out.append(open_graph(p).info().to_dict())
        except (OSError, ValueError) as exc:
            out.append({"path": p, "error": str(exc)})
            failed = True
    print(json.dumps(out[0] if len(out) == 1 else out, indent=2))
    if failed:
        print("probe failed for one or more paths", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(_main())
