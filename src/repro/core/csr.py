"""End-to-end CSR reading: file -> EdgeList -> CSR (GVEL csr-partition-rho).

``convert_to_csr`` exposes the strategy ladder measured in the paper's
Figure 3/4 (csr-global vs csr-partition-k); ``read_csr`` composes a reader
with a converter and optionally *fuses* degree counting into the read loop,
the analogue of GVEL counting degrees while parsing (Alg. 1 line 25).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import build, degrees
from .types import CSR, EdgeList


def convert_to_csr(
    el: EdgeList,
    *,
    method: str = "staged",
    rho: int = 4,
    bin_bits: Optional[int] = None,
    engine: str = "jax",
) -> CSR:
    """Convert an in-memory EdgeList to CSR.

    method: 'global' (single-stage baseline) | 'staged' (GVEL, rho
    partitions) | 'binned' (propagation-blocking bins of 2**bin_bits
    vertices)
    engine: 'jax' | 'numpy'
    """
    method = method or "staged"
    n = int(el.num_edges)
    v = el.num_vertices
    weighted = el.weights is not None
    if engine == "numpy":
        s = np.asarray(el.src[:n])
        d = np.asarray(el.dst[:n])
        w = None if not weighted else np.asarray(el.weights[:n])
        if method == "binned":
            return build.csr_binned_np(s, d, w, v, bin_bits=bin_bits)
        return build.csr_np(s, d, w, v)
    src = jnp.asarray(el.src[:n])
    dst = jnp.asarray(el.dst[:n])
    w = jnp.asarray(el.weights[:n]) if weighted else None
    if method == "global":
        offsets, targets, ww = build.csr_global(src, dst, w, v, weighted=weighted)
    elif method == "staged":
        offsets, targets, ww = build.csr_staged(src, dst, w, v, rho=rho,
                                                weighted=weighted)
    elif method == "binned":
        offsets, targets, ww = build.csr_binned(src, dst, w, v,
                                                bin_bits=bin_bits,
                                                weighted=weighted)
    else:
        raise ValueError(f"unknown method {method!r}")
    return CSR(np.asarray(offsets), np.asarray(targets),
               None if ww is None else np.asarray(ww), v)


def read_csr(
    path: str,
    *,
    weighted: bool = False,
    symmetric: bool = False,
    base: int = 1,
    num_vertices: Optional[int] = None,
    method: str = "staged",
    rho: int = 4,
    bin_bits: Optional[int] = None,
    engine: str = "jax",
    **reader_kwargs,
) -> CSR:
    """File -> CSR through the unified loader (back-compat wrapper).

    ``engine="jax"`` maps to the streaming ``device`` engine, whose
    parse -> CSR path is fused on device; see loader.load_csr.  Binary
    ``.gvel`` snapshots are detected by magic in the front door and
    served zero-parse (an embedded CSR skips the build entirely).
    """
    from .loader import load_csr
    return load_csr(path, engine="device" if engine == "jax" else engine,
                    weighted=weighted, symmetric=symmetric, base=base,
                    num_vertices=num_vertices, method=method, rho=rho,
                    bin_bits=bin_bits, **reader_kwargs)


def csr_to_dense(csr: CSR) -> np.ndarray:
    """Small-graph debugging helper."""
    v = csr.num_vertices
    out = np.zeros((csr.num_rows, v), np.int64)
    off = np.asarray(csr.offsets)
    tgt = np.asarray(csr.targets)
    for u in range(csr.num_rows):
        for t in tgt[off[u]:off[u + 1]]:
            out[u, t] += 1
    return out
