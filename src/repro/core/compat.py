"""JAX API compatibility shims.

The repo targets the modern mesh/shard_map surface (``jax.shard_map``
with ``check_vma``/``axis_names``, ``jax.make_mesh`` with explicit
``AxisType``), but the pinned container ships jax 0.4.37 where those
spell ``jax.experimental.shard_map.shard_map`` with ``check_rep``/
``auto`` and ``make_mesh`` takes no ``axis_types``.  Every mesh or
shard_map construction in src/ and tests/ goes through this module so
the code runs unchanged on either API.
"""
from __future__ import annotations

import jax
import numpy as np

try:                                    # newer jax
    from jax.sharding import AxisType
except ImportError:                     # 0.4.x
    AxisType = None


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def device_mesh(devices, axes):
    """jax.sharding.Mesh over an explicit device array."""
    devices = np.asarray(devices)
    if AxisType is not None:
        return jax.sharding.Mesh(devices, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Un-checked shard_map on either API.

    ``axis_names`` (when given) is the set of *manual* axes, matching
    the modern keyword; on old jax it becomes the complement ``auto``
    set of the experimental entry point.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kw)
    # Old jax: partial-auto shard_map lowers axis_index to a PartitionId
    # instruction the CPU SPMD partitioner rejects, so run fully manual.
    # Bodies in this repo only issue collectives over their manual axes
    # and take replicated (P()) specs elsewhere, so results are identical;
    # only auto-axis GSPMD propagation is lost, which no caller relies on.
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
