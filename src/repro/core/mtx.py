"""Matrix Market (MTX) support — honoring header attributes.

The paper notes PIGO *disregards* MTX attributes (symmetric graphs are only
half-loaded, under-reporting runtimes).  We parse the banner properly:

  %%MatrixMarket matrix coordinate <field> <symmetry>
  % comments...
  <rows> <cols> <nnz>

field: real|integer|pattern (pattern -> unweighted), symmetry:
general|symmetric (symmetric -> reverse edges are materialized).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import convert_to_csr
from .types import CSR, EdgeList, GraphMeta


@dataclasses.dataclass(frozen=True)
class MtxHeader:
    meta: GraphMeta
    body_offset: int          # byte offset of the first entry line
    rows: int
    cols: int


def read_header(path: str) -> MtxHeader:
    # open_stream decompresses gzip/framed MTX transparently; tell() is in
    # uncompressed coordinates, so body_offset means the same thing either
    # way (the engines apply offsets after decompression too).
    from .codecs import open_stream
    with open_stream(path) as f:
        banner = f.readline()
        if not banner.startswith(b"%%MatrixMarket"):
            raise ValueError(f"{path}: missing MatrixMarket banner")
        parts = banner.decode().strip().lower().split()
        if len(parts) < 5 or parts[1] != "matrix" or parts[2] != "coordinate":
            raise ValueError(f"{path}: unsupported banner {banner!r}")
        field, symmetry = parts[3], parts[4]
        if field not in ("real", "integer", "pattern"):
            raise ValueError(f"{path}: unsupported field {field!r}")
        if symmetry not in ("general", "symmetric"):
            raise ValueError(f"{path}: unsupported symmetry {symmetry!r}")
        pos = f.tell()
        line = f.readline()
        while line.startswith(b"%"):
            pos = f.tell()
            line = f.readline()
        rows, cols, nnz = (int(x) for x in line.split()[:3])
        body = f.tell()
    meta = GraphMeta(
        num_vertices=max(rows, cols),
        num_edges=nnz,
        weighted=field in ("real", "integer"),
        symmetric=symmetry == "symmetric",
        base=1,
        pattern=field == "pattern",
    )
    return MtxHeader(meta, body, rows, cols)


def _read_body(path: str, hdr: MtxHeader, engine: str, **kw) -> EdgeList:
    """Parse entries after the header via the unified loader.

    The header/size lines contain numbers that would parse as edges, so
    we hand the engine ``offset=body_offset``; comment lines inside the
    body are rejected by the parser's bad-char line mask anyway.
    """
    from .loader import load_edgelist
    engine = "device" if engine == "jax" else engine   # legacy alias
    el = load_edgelist(path, engine=engine, weighted=hdr.meta.weighted,
                       base=1, num_vertices=hdr.meta.num_vertices,
                       offset=hdr.body_offset, **kw)
    return el


def read_mtx(path: str, *, engine: str = "numpy", **engine_kw) -> EdgeList:
    """Read an MTX file to an EdgeList, honoring field/symmetry."""
    hdr = read_header(path)
    el = _read_body(path, hdr, engine, **engine_kw)
    if int(el.num_edges) != hdr.meta.num_edges:
        raise ValueError(
            f"{path}: parsed {int(el.num_edges)} entries, header says "
            f"{hdr.meta.num_edges}")
    if hdr.meta.symmetric:
        from .edgelist import symmetrize
        n = int(el.num_edges)
        src, dst = np.asarray(el.src[:n]), np.asarray(el.dst[:n])
        keep = src != dst                     # do not duplicate self-loops
        rs, rd = dst[keep], src[keep]
        w = el.weights
        if w is not None:
            w = np.concatenate([w[:n], np.asarray(w[:n])[keep]])
        el = EdgeList(np.concatenate([src, rs]), np.concatenate([dst, rd]),
                      w, np.int64(n + keep.sum()), el.num_vertices)
    return el


def read_mtx_csr(path: str, *, method: str = "staged", rho: int = 4,
                 engine: str = "numpy") -> CSR:
    return convert_to_csr(read_mtx(path, engine=engine), method=method,
                          rho=rho, engine=engine)


def mtx_to_snapshot(path: str, out_path: str, *, engine: str = "numpy",
                    csr: bool = True, method: str = "staged", rho: int = 4,
                    compress: str | None = None,
                    compress_level: int | None = None) -> GraphMeta:
    """Convert an MTX file to a binary ``.gvel`` snapshot (parse once).

    Header attributes are honored during the conversion — a symmetric
    MTX is materialized with its reverse edges, a pattern field stays
    unweighted — so the snapshot is the *resolved* graph and reloads
    with no MTX-specific handling at all.  With ``csr=True`` (default)
    a prebuilt CSR is embedded, making ``load_csr(out_path)`` a pure
    mmap.  Returns the source header's :class:`GraphMeta`.

    A thin wrapper over ``open_graph(path).save(out_path, ...)`` — the
    :class:`~repro.core.source.GraphSource` write path.
    """
    from .source import open_graph

    src = open_graph(path, engine=engine)
    if src.format != "mtx":
        raise ValueError(f"{path}: missing MatrixMarket banner")
    src.save(out_path, csr=csr, method=method, rho=rho, compress=compress,
             compress_level=compress_level)
    return src._mtx_header().meta


def write_mtx(path: str, src, dst, weights=None, *, num_vertices: int,
              symmetric: bool = False) -> None:
    field = "pattern" if weights is None else "real"
    sym = "symmetric" if symmetric else "general"
    with open(path, "w") as f:
        f.write(f"%%MatrixMarket matrix coordinate {field} {sym}\n")
        f.write(f"% generated by repro.core.mtx\n")
        f.write(f"{num_vertices} {num_vertices} {len(src)}\n")
        if weights is None:
            for u, v in zip(src, dst):
                f.write(f"{u + 1} {v + 1}\n")
        else:
            for u, v, w in zip(src, dst, weights):
                f.write(f"{u + 1} {v + 1} {w}\n")
