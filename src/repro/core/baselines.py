"""Baseline loaders the paper compares against, reimplemented faithfully.

* ``read_edgelist_naive``   — sequential line loop + str.split: the
                              fstream-plain / Hornet / Gunrock analogue
                              (stream extraction, one entry at a time).
* ``read_edgelist_loadtxt`` — np.loadtxt: the "use the library" baseline.
* ``read_edgelist_pigo``    — PIGO's algorithm: mmap the file, split into
                              one equal part per worker, *two passes*
                              (pass 1 counts newlines to size and offset
                              the output; pass 2 parses into the shared
                              array).  Single-address-space numpy version.
* ``csr_pigo``              — PIGO's single-stage CSR: global degree count
                              + one global construction pass (vs GVEL's
                              staged rho-partition build).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import parse_np
from .types import CSR, EdgeList


def read_edgelist_naive(path: str, *, weighted: bool = False, base: int = 1,
                        num_vertices: Optional[int] = None) -> EdgeList:
    srcs, dsts, ws = [], [], []
    with open(path, "rb") as f:
        for line in f:
            parts = line.split()
            if len(parts) < 2 or not parts[0].isdigit():
                continue
            srcs.append(int(parts[0]) - base)
            dsts.append(int(parts[1]) - base)
            if weighted:
                ws.append(float(parts[2]) if len(parts) > 2 else 1.0)
    src = np.asarray(srcs, np.int32)
    dst = np.asarray(dsts, np.int32)
    w = np.asarray(ws, np.float32) if weighted else None
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    return EdgeList(src, dst, w, np.int64(len(src)), num_vertices)


def read_edgelist_loadtxt(path: str, *, weighted: bool = False, base: int = 1,
                          num_vertices: Optional[int] = None) -> EdgeList:
    cols = np.loadtxt(path, dtype=np.float64, ndmin=2)
    src = cols[:, 0].astype(np.int32) - base
    dst = cols[:, 1].astype(np.int32) - base
    w = cols[:, 2].astype(np.float32) if weighted and cols.shape[1] > 2 else (
        np.ones(len(src), np.float32) if weighted else None)
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    return EdgeList(src, dst, w, np.int64(len(src)), num_vertices)


def read_edgelist_pigo(path: str, *, weighted: bool = False, base: int = 1,
                       num_vertices: Optional[int] = None,
                       num_workers: int = 8) -> EdgeList:
    """PIGO two-pass algorithm (COO::read_el_): equal split per worker,
    newline-count pass to compute per-worker write offsets, then parse pass
    into one shared pre-sized array."""
    data = np.memmap(path, dtype=np.uint8, mode="r")
    bounds = parse_np.chunk_bounds(data, num_workers)
    # pass 1: count lines per part (PIGO counts newlines)
    counts = [int(np.count_nonzero(np.asarray(data[lo:hi]) == 10) +
                  (0 if hi == lo or data[hi - 1] == 10 else 1))
              for lo, hi in bounds]
    offsets = np.zeros(len(counts) + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    total = int(offsets[-1])
    src = np.full(total, -1, np.int32)
    dst = np.full(total, -1, np.int32)
    w = np.zeros(total, np.float32) if weighted else None
    # pass 2: parse each part into its reserved range
    for (lo, hi), o in zip(bounds, offsets[:-1]):
        s, d, ww, c = parse_np.parse_chunk_np(np.asarray(data[lo:hi]),
                                              weighted=weighted, base=base)
        src[o:o + c] = s
        dst[o:o + c] = d
        if weighted:
            w[o:o + c] = ww
    valid = src >= 0
    src, dst = src[valid], dst[valid]
    if weighted:
        w = w[valid]
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    return EdgeList(src, dst, w, np.int64(len(src)), num_vertices)


def csr_pigo(el: EdgeList) -> CSR:
    """PIGO convert_coo_: global degrees, global offsets, one static-schedule
    population pass over the whole edge array (single-stage)."""
    n = int(el.num_edges)
    src = np.asarray(el.src[:n])
    dst = np.asarray(el.dst[:n])
    v = el.num_vertices
    deg = np.bincount(src, minlength=v)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    # atomic fetch-add slot claim -> deterministic rank via stable sort
    order = np.argsort(src, kind="stable")
    targets = dst[order].astype(np.int32)
    w = None if el.weights is None else np.asarray(el.weights[:n])[order]
    return CSR(offsets, targets, w, v)
