"""Synthetic graph generators + text writers.

SuiteSparse is unavailable offline, so the benchmark suite fabricates
stand-ins with the same *shape characteristics* as the paper's Table 1
classes: RMAT (power-law, high average degree — web graphs), uniform
(Erdos-Renyi — social-ish), and grid (low degree — road networks /
k-mer graphs).  Sizes are scaled to this host.
"""
from __future__ import annotations

import numpy as np


def rmat_edges(scale: int, edge_factor: int = 16, *, a=0.57, b=0.19, c=0.19,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray, int]:
    """Graph500-style RMAT generator (power-law degree distribution)."""
    rng = np.random.default_rng(seed)
    v = 1 << scale
    e = v * edge_factor
    src = np.zeros(e, np.int64)
    dst = np.zeros(e, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(e)
        src_bit = r > ab
        r2 = rng.random(e)
        thresh = np.where(src_bit, c / (c + (1 - abc)) if (c + (1 - abc)) else 0.5,
                          a / ab)
        dst_bit = r2 > thresh
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    perm = rng.permutation(v)               # de-correlate vertex ids
    return perm[src].astype(np.int64), perm[dst].astype(np.int64), v


def uniform_edges(num_vertices: int, num_edges: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, num_vertices, num_edges),
            rng.integers(0, num_vertices, num_edges), num_vertices)


def grid_edges(side: int):
    """2D grid — road-network-like (avg degree ~2 directed)."""
    v = side * side
    idx = np.arange(v).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    e = np.concatenate([right, down], axis=1)
    return e[0], e[1], v


def write_edgelist(path: str, src, dst, weights=None, *, base: int = 1) -> None:
    """Write a plain text edgelist (1-based by default, like the paper)."""
    src = np.asarray(src) + base
    dst = np.asarray(dst) + base
    cols = [src.astype(np.int64), dst.astype(np.int64)]
    if weights is not None:
        with open(path, "w") as f:
            for u, v, w in zip(src, dst, np.asarray(weights)):
                f.write(f"{u} {v} {w:.4f}\n")
        return
    # fast writer: build the byte buffer with numpy
    a = np.char.add(np.char.add(src.astype("U11"), " "), dst.astype("U11"))
    with open(path, "w") as f:
        f.write("\n".join(a.tolist()))
        f.write("\n")


def make_graph_file(path: str, kind: str = "rmat", scale: int = 14,
                    edge_factor: int = 16, weighted: bool = False,
                    seed: int = 0) -> tuple[int, int]:
    """Generate + write a graph; returns (num_vertices, num_edges)."""
    if kind == "rmat":
        src, dst, v = rmat_edges(scale, edge_factor, seed=seed)
    elif kind == "uniform":
        src, dst, v = uniform_edges(1 << scale, (1 << scale) * edge_factor, seed)
    elif kind == "grid":
        src, dst, v = grid_edges(1 << (scale // 2))
    else:
        raise ValueError(kind)
    w = None
    if weighted:
        rng = np.random.default_rng(seed + 1)
        w = rng.random(len(src)).astype(np.float32)
    write_edgelist(path, src, dst, w)
    return v, len(src)
