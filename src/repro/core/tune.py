"""Measured block-geometry autotuning for the streaming loader.

GVEL's Figure 2 sweeps the block size and finds the throughput knee
empirically — the right ``beta`` (owned bytes per block) and
``batch_blocks`` (blocks per jitted program) depend on the host's cache
hierarchy, core count, and XLA backend, not on anything we can derive
statically.  This module replaces the loader's historical
``beta=256 KiB, batch_blocks=8`` magic numbers with the same idea:

* :func:`run_sweep` stages a synthetic in-memory edgelist through the
  *actual* fused streaming step (``StagingArena`` +
  ``parse.parse_accumulate``) for every ``beta x batch_blocks`` combo
  and times it (compile excluded by a warmup pass per combo);
* :func:`tuned_geometry` memoizes the sweep winner in a per-host JSON
  profile — ``$REPRO_TUNE_CACHE`` or ``~/.cache/repro/tune.json`` —
  keyed by :func:`host_key`, so the sweep runs once per host, not once
  per process;
* the loader consults it only when asked (``open_graph(path,
  tune=True)`` / ``LoadOptions(tune=True)``); explicit
  ``beta``/``batch_blocks`` in ``engine_kw`` always win.

``python -m benchmarks.tune_sweep`` runs the sweep standalone and emits
the rows as JSON (the Fig. 2 reproduction artifact); delete the cache
file (or pass ``refresh=True``) to re-measure after a hardware or
jax upgrade.  See docs/performance.md for the full tuning guide.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

PROFILE_VERSION = 1
DEFAULT_BETAS = (64 * 1024, 256 * 1024, 1024 * 1024)
DEFAULT_BATCH_BLOCKS = (2, 4, 8)
SAMPLE_BYTES = 4 * 1024 * 1024
_ENV_CACHE = "REPRO_TUNE_CACHE"


def host_key() -> str:
    """Profile key: geometry is a property of this machine + the
    resolved platform configuration (:func:`repro.core.env.fingerprint`
    — backend, forced device count and float width all move the knee,
    so each gets its own profile)."""
    from .env import fingerprint
    return fingerprint()


def cache_path() -> str:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tune.json")


def clear_cache(path: Optional[str] = None) -> bool:
    """Delete the profile file (next tuned load re-measures).  Returns
    whether a file was removed."""
    p = path or cache_path()
    try:
        os.remove(p)
        return True
    except FileNotFoundError:
        return False


def synthetic_sample(nbytes: int = SAMPLE_BYTES, *, weighted: bool = False,
                     seed: int = 0) -> np.ndarray:
    """An in-memory uniform edgelist of ~``nbytes`` text bytes — the
    sweep's workload proxy (per-host profile, not per-file: the parse
    cost depends on bytes/line shape far more than on graph structure).
    """
    rng = np.random.default_rng(seed)
    # ~"123456 654321[ 0.123]\n" -> estimate lines from the line width
    width = 14 + (6 if weighted else 0)
    n = max(nbytes // width, 16)
    src = rng.integers(1, 999_999, n)
    dst = rng.integers(1, 999_999, n)
    if weighted:
        w = (rng.random(n) * 9).round(3)
        lines = [f"{s} {d} {x}" for s, d, x in zip(src, dst, w)]
    else:
        lines = [f"{s} {d}" for s, d in zip(src, dst)]
    return np.frombuffer(("\n".join(lines) + "\n").encode(), np.uint8)


def measure_geometry(data: np.ndarray, beta: int, batch_blocks: int, *,
                     weighted: bool = False, base: int = 1,
                     overlap: int = 64, repeat: int = 2) -> float:
    """Seconds for one full fused streaming pass over ``data`` at this
    geometry (min over ``repeat`` passes after one compile warmup)."""
    import jax
    import jax.numpy as jnp

    from .blocks import (MemoryBlockSource, StagingArena, flat_len,
                         owned_range, plan_blocks)
    from .parse import make_accumulators, parse_accumulate

    plan = plan_blocks(len(data), beta=beta, overlap=overlap)
    os_, oe = owned_range(plan)
    edge_cap = plan.edge_cap
    cap = plan.num_blocks * edge_cap
    num_batches = -(-plan.num_blocks // batch_blocks)
    arena = StagingArena(flat_len(min(batch_blocks, plan.num_blocks), plan))
    source = MemoryBlockSource(data)

    def one_pass() -> None:
        acc_src, acc_dst, acc_w, total = make_accumulators(
            cap, weighted=weighted)
        for i in range(num_batches):
            start = i * batch_blocks
            ids = np.arange(start, min(start + batch_blocks,
                                       plan.num_blocks))
            bufs = source.stage(plan, ids, arena=arena)
            nb = bufs.shape[0]
            acc_src, acc_dst, acc_w, total = parse_accumulate(
                acc_src, acc_dst, acc_w, total, jnp.asarray(bufs),
                jnp.full((nb,), os_, jnp.int32),
                jnp.full((nb,), oe, jnp.int32),
                weighted=weighted, base=base, edge_bound=nb * edge_cap)
        jax.block_until_ready(total)

    one_pass()                                    # compile both programs
    best = float("inf")
    for _ in range(max(repeat, 1)):
        t0 = time.perf_counter()
        one_pass()
        best = min(best, time.perf_counter() - t0)
    return best


def run_sweep(data: Optional[np.ndarray] = None, *,
              betas: Iterable[int] = DEFAULT_BETAS,
              batch_blocks: Iterable[int] = DEFAULT_BATCH_BLOCKS,
              weighted: bool = False, base: int = 1, overlap: int = 64,
              sample_bytes: int = SAMPLE_BYTES,
              repeat: int = 2) -> List[Dict]:
    """Measure every ``beta x batch_blocks`` combo; rows sorted fastest
    first.  ``data=None`` measures on :func:`synthetic_sample`."""
    if data is None:
        data = synthetic_sample(sample_bytes, weighted=weighted)
    rows = []
    for beta in betas:
        if beta <= overlap:
            continue                      # plan_blocks would reject it
        for bb in batch_blocks:
            secs = measure_geometry(data, int(beta), int(bb),
                                    weighted=weighted, base=base,
                                    overlap=overlap, repeat=repeat)
            rows.append({"beta": int(beta), "batch_blocks": int(bb),
                         "seconds": round(secs, 6),
                         "mb_per_s": round(len(data) / 1e6 / secs, 3)})
    if not rows:
        raise ValueError("empty sweep grid (every beta <= overlap?)")
    rows.sort(key=lambda r: r["seconds"])
    return rows


def best_geometry(rows: List[Dict]) -> Dict[str, int]:
    top = min(rows, key=lambda r: r["seconds"])
    return {"beta": top["beta"], "batch_blocks": top["batch_blocks"]}


def _load_profile(path: str) -> Dict:
    try:
        with open(path) as f:
            prof = json.load(f)
        if isinstance(prof, dict) and prof.get("version") == PROFILE_VERSION:
            return prof
    except (OSError, ValueError):
        pass                               # absent or corrupt: re-measure
    return {"version": PROFILE_VERSION, "hosts": {}}


def _save_profile(path: str, prof: Dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(prof, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)                  # atomic: readers never see half


def _slot_name(weighted: bool, shards: int) -> str:
    """Profile slot: weighted/unweighted, with a ``_d{shards}`` suffix
    for the sharded streaming path (each shard streams ~1/d of the file
    with d parse pipelines contending for the same cores, so its knee
    sits elsewhere than the single-stream one)."""
    slot = "weighted" if weighted else "unweighted"
    if shards > 1:
        slot = f"{slot}_d{int(shards)}"
    return slot


def save_geometry(rows: List[Dict], *, weighted: bool = False,
                  shards: int = 1,
                  path: Optional[str] = None) -> Dict[str, int]:
    """Persist a sweep's winner (plus the full rows) into this host's
    profile slot; returns the winner.  The single place the profile
    entry schema is written — :func:`tuned_geometry` and
    ``benchmarks/tune_sweep.py --apply`` both go through it.  The
    profile is re-read immediately before the atomic replace, so a
    concurrent process persisting the *other* weighted/unweighted slot
    (its sweep takes tens of seconds; this read+write, microseconds) is
    not silently discarded."""
    p = path or cache_path()
    best = best_geometry(rows)
    prof = _load_profile(p)
    prof["hosts"].setdefault(host_key(), {})[_slot_name(weighted, shards)] = {
        **best, "sweep": rows, "measured_at": int(time.time())}
    _save_profile(p, prof)
    return best


def tuned_geometry(*, weighted: bool = False, shards: int = 1,
                   refresh: bool = False, **sweep_kw) -> Dict[str, int]:
    """The measured ``{"beta": ..., "batch_blocks": ...}`` for this host.

    Loads the per-host JSON profile; on a miss (or ``refresh=True``)
    runs :func:`run_sweep` once — tens of seconds of compile+measure —
    and persists the winner alongside the full sweep rows.  Weighted
    and unweighted parses are profiled separately (the weighted program
    does more work per byte), and each shard count gets its own slot
    (``shards`` d>1 measures on a ~1/d sample — the span one of d
    byte-range shards would stream).
    """
    path = cache_path()
    key, slot = host_key(), _slot_name(weighted, shards)
    prof = _load_profile(path)
    entry = prof["hosts"].get(key, {}).get(slot)
    if entry and not refresh:
        return {"beta": int(entry["beta"]),
                "batch_blocks": int(entry["batch_blocks"])}
    if shards > 1:
        sweep_kw.setdefault(
            "sample_bytes", max(SAMPLE_BYTES // int(shards), 256 * 1024))
    rows = run_sweep(weighted=weighted, **sweep_kw)
    return save_geometry(rows, weighted=weighted, shards=shards, path=path)
