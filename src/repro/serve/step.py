"""Serving steps: prefill (prompt -> cache) and decode (one token).

``jit_decode_step``/``jit_prefill_step`` memoize the jitted program per
``(cfg, max_seq, tp)`` — engines come and go (one per ServeRuntime, per
test, per benchmark phase), and each fresh ``jax.jit(make_decode_step(...))``
closure is a new cache key that recompiles an identical program.  The
memo keys on the frozen ModelConfig, so every engine at the same shape
shares one compiled step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models.transformer import forward_decode, forward_prefill


def make_prefill_step(cfg, max_seq: int, *, tp: int = 1):
    def prefill_step(params, batch):
        logits, caches = forward_prefill(params, batch, cfg, max_seq, tp)
        return logits, caches
    return prefill_step


def make_decode_step(cfg, max_seq: int, *, tp: int = 1, greedy: bool = True):
    def decode_step(params, caches, batch):
        logits, caches = forward_decode(params, batch, caches, cfg, max_seq, tp)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches
    return decode_step


@functools.lru_cache(maxsize=None)
def jit_decode_step(cfg, max_seq: int, tp: int = 1, greedy: bool = True):
    return jax.jit(make_decode_step(cfg, max_seq, tp=tp, greedy=greedy))


@functools.lru_cache(maxsize=None)
def jit_prefill_step(cfg, max_seq: int, tp: int = 1):
    return jax.jit(make_prefill_step(cfg, max_seq, tp=tp))
