"""Batched serving engine with continuous-batching-style slot management.

A fixed pool of `batch` slots; finished sequences release their slot and
queued requests claim it (their prompt is prefilled into the slot's cache
rows).  Single-host simulation of the scheduler every real serving stack
(vLLM/JetStream) runs; the jitted decode step is the same program the
dry-run lowers at production shapes.

Scheduling invariants (tests/test_serve.py):

* queued requests are never dropped: a request stays in the queue until
  a slot admits it, slots freed by completions this tick are refilled
  in the same tick, and ``run()`` drains queue + slots to empty by
  default (``max_ticks`` is an explicit safety bound, not a silent
  drop point),
* admission is FIFO: requests enter slots in submit order, so per-slot
  completion order follows admission order,
* ``max_active`` caps how many slots admit concurrently (<= ``batch``);
  the serving runtime lowers it under straggler pressure to degrade
  throughput instead of stalling, and restores it when pressure clears.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import init_caches
from .step import jit_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None    # slot that served it (set at admission)


class ServeEngine:
    def __init__(self, cfg, params, *, batch: int = 8, max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.max_active = batch       # admission width; degradable at runtime
        self.caches = init_caches(cfg, batch, max_seq)
        self.decode = jit_decode_step(cfg, max_seq)   # shared across engines
        self.pos = np.zeros(batch, np.int32)
        self.tok = np.zeros(batch, np.int32)
        self.slots: List[Optional[Request]] = [None] * batch
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _admit(self):
        active = self._active()
        for slot in range(self.batch):
            if active >= self.max_active:
                break
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                req.slot = slot
                self.slots[slot] = req
                active += 1
                # prefill the prompt into this slot by stepping tokens
                # (single-slot prefill keeps the engine simple; a prod
                # deployment jits a batched prefill_step — see launch.serve)
                for i, t in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, int(t), i)
                self.pos[slot] = len(req.prompt) - 1
                self.tok[slot] = int(req.prompt[-1])

    def _step_slot(self, slot: int, token: int, pos: int):
        tok = self.tok.copy()
        ps = self.pos.copy()
        tok[slot] = token
        ps[slot] = pos
        batch = {"token": jnp.asarray(tok), "pos": jnp.asarray(ps)}
        nxt, _, self.caches = self.decode(self.params, self.caches, batch)
        return np.asarray(nxt)

    def step(self) -> int:
        """One engine tick: admit, decode one token for all active
        slots, refill slots freed by completions (so the queue drains
        even when every slot turns over at a tick boundary)."""
        self._admit()
        active = [s for s in range(self.batch) if self.slots[s] is not None]
        if not active:
            return 0
        batch = {"token": jnp.asarray(self.tok), "pos": jnp.asarray(self.pos)}
        nxt, _, self.caches = self.decode(self.params, self.caches, batch)
        nxt = np.asarray(nxt)
        for s in active:
            req = self.slots[s]
            req.out.append(int(nxt[s]))
            self.pos[s] += 1
            self.tok[s] = int(nxt[s])
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_seq - 1:
                req.done = True
                self.completed.append(req)
                self.slots[s] = None
        if self.queue:
            self._admit()             # same-tick refill of freed slots
        return len(active)

    def run(self, max_ticks: Optional[int] = None) -> int:
        """Tick until queue and slots are empty.  ``max_ticks`` bounds
        the loop for tests/timeouts; hitting it raises so a stalled
        scheduler can never silently drop still-queued requests."""
        ticks = 0
        while self.queue or any(r is not None for r in self.slots):
            if max_ticks is not None and ticks >= max_ticks:
                pending = len(self.queue) + self._active()
                raise RuntimeError(
                    f"ServeEngine.run: {pending} requests still pending "
                    f"after max_ticks={max_ticks}")
            self.step()
            ticks += 1
        return ticks
