"""Batched serving engine with continuous-batching-style slot management.

A fixed pool of `batch` slots; finished sequences release their slot and
queued requests claim it (their prompt is prefilled into the slot's cache
rows).  Single-host simulation of the scheduler every real serving stack
(vLLM/JetStream) runs; the jitted decode step is the same program the
dry-run lowers at production shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import init_caches
from .step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, batch: int = 8, max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.caches = init_caches(cfg, batch, max_seq)
        self.decode = jax.jit(make_decode_step(cfg, max_seq))
        self.pos = np.zeros(batch, np.int32)
        self.tok = np.zeros(batch, np.int32)
        self.slots: List[Optional[Request]] = [None] * batch
        self.queue: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                # prefill the prompt into this slot by stepping tokens
                # (single-slot prefill keeps the engine simple; a prod
                # deployment jits a batched prefill_step — see launch.serve)
                for i, t in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, int(t), i)
                self.pos[slot] = len(req.prompt) - 1
                self.tok[slot] = int(req.prompt[-1])

    def _step_slot(self, slot: int, token: int, pos: int):
        tok = self.tok.copy()
        ps = self.pos.copy()
        tok[slot] = token
        ps[slot] = pos
        batch = {"token": jnp.asarray(tok), "pos": jnp.asarray(ps)}
        nxt, _, self.caches = self.decode(self.params, self.caches, batch)
        return np.asarray(nxt)

    def step(self) -> int:
        """One engine tick: admit, decode one token for all active slots."""
        self._admit()
        active = [s for s in range(self.batch) if self.slots[s] is not None]
        if not active:
            return 0
        batch = {"token": jnp.asarray(self.tok), "pos": jnp.asarray(self.pos)}
        nxt, _, self.caches = self.decode(self.params, self.caches, batch)
        nxt = np.asarray(nxt)
        for s in active:
            req = self.slots[s]
            req.out.append(int(nxt[s]))
            self.pos[s] += 1
            self.tok[s] = int(nxt[s])
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_seq - 1:
                req.done = True
                self.slots[s] = None
        return len(active)

    def run(self, max_ticks: int = 1000) -> int:
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
