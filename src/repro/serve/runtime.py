"""Graph-walk serving runtime: snapshot corpus -> continuous batching
under churn.

The layer that turns the fast loader into a servable system (ROADMAP
end-to-end scenario; docs/serving.md).  A :class:`ServeRuntime` owns

* a :class:`~repro.core.cache.SourceCache` — every request resolves its
  graph through an mtime/size-validated handle, so a snapshot swapped
  on disk under the live server is picked up on the **next request**
  with no restart and no dropped in-flight work (in-flight prompts
  were already derived from the old handle and finish normally),
* a continuous-batching :class:`~repro.serve.engine.ServeEngine` —
  walk-LM requests (prompt = a deterministic random walk from the
  requested graph, tokens = vertex ids mod vocab) share decode ticks
  across slots,
* a :class:`~repro.ft.coordinator.Coordinator` — straggler ticks
  *degrade* the engine's admission width (halve ``max_active``)
  instead of stalling, and restore it once pressure clears; preemption
  flags stop serving at a tick boundary,
* a :class:`RuntimeStats` counters object — the subsystem's
  observability surface, exported by :meth:`ServeRuntime.stats` and
  printed by ``benchmarks/serve_walks.py``.

Training-side churn rides the same pieces: :meth:`ServeRuntime.corpus`
opens a step-indexed :class:`~repro.data.corpus.WalkCorpus` stream
through the cache, and the corpus cursor + ``ft.coordinator`` give
kill/restart a bitwise-identical resume (proven in tests/test_runtime.py
and the verify.sh chaos lane).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..core.cache import _OP_SECTIONS, SourceCache
from ..core.snapshot import SnapshotError
from ..data.corpus import CorpusConfig, WalkCorpus
from ..data.walks import I32, random_walks, walk_from, walk_keys
from ..ft.coordinator import Coordinator, FTConfig
from .engine import Request, ServeEngine

import jax.numpy as jnp


@dataclasses.dataclass
class RuntimeStats:
    """Monotonic counters over the runtime's lifetime."""

    requests: int = 0             # requests completed
    tokens: int = 0               # new tokens decoded
    ticks: int = 0                # engine ticks driven by drain()
    active_ticks: int = 0         # sum of active slots over ticks
    seconds: float = 0.0          # wall time inside drain()
    degrades: int = 0             # straggler-driven admission cuts
    restores: int = 0             # admission width restorations
    resumes: int = 0              # corpus streams opened at step > 0
    corrupt: int = 0              # requests refused on corrupt graphs

    def occupancy(self, batch: int) -> float:
        """Mean fraction of slots busy per tick (0 when never ticked)."""
        return self.active_ticks / (self.ticks * batch) if self.ticks else 0.0

    def tokens_per_s(self) -> float:
        return self.tokens / self.seconds if self.seconds else 0.0


class ServeRuntime:
    """Continuous-batching walk-LM server over a snapshot corpus."""

    def __init__(self, cfg, params, *, batch: int = 4, max_seq: int = 64,
                 cache: Optional[SourceCache] = None,
                 coordinator: Optional[Coordinator] = None,
                 ft: Optional[FTConfig] = None,
                 seed: int = 0, prompt_len: int = 8):
        self.cfg = cfg
        self.cache = cache if cache is not None else SourceCache()
        self.engine = ServeEngine(cfg, params, batch=batch, max_seq=max_seq)
        self.coord = coordinator or Coordinator(
            ft or FTConfig(straggler_policy="degrade", straggler_factor=4.0,
                           straggler_window=8))
        self.seed = seed
        self.prompt_len = prompt_len
        self._stats = RuntimeStats()
        self._rids = itertools.count()
        self._completed_seen = 0
        self._ok_streak = 0
        # device-pinned CSR per live GraphSource handle: a swapped
        # snapshot reopens as a NEW handle (new id), so stale graphs
        # can never serve a post-swap request; entries are pruned once
        # they outnumber the cache's open-handle bound.
        self._graphs: Dict[int, tuple] = {}

    # -- graph resolution ----------------------------------------------------

    def _graph(self, path: str, **open_kw):
        # an already-quarantined graph fails fast with the structured
        # error (no admission change: the first detection degraded)
        self.cache.check_quarantine(path, _OP_SECTIONS["csr"])
        src = self.cache.get(path, **open_kw)
        ent = self._graphs.get(id(src))
        if ent is None or ent[0] is not src:
            try:
                csr = src.csr()
            except SnapshotError as exc:
                raise self._on_corrupt(path, exc) from exc
            ent = (src, jnp.asarray(np.asarray(csr.offsets), I32),
                   jnp.asarray(np.asarray(csr.targets), I32),
                   int(csr.num_vertices))
            if len(self._graphs) >= 2 * self.cache.capacity:
                self._graphs.clear()
            self._graphs[id(src)] = ent
        return ent

    def _on_corrupt(self, path: str, exc: SnapshotError):
        """First detection of a corrupt graph: quarantine it in the
        cache, degrade admission (the straggler-degrade path — corrupt
        reads and stragglers are both capacity loss; serving narrows
        instead of stalling), and return the structured error."""
        err = self.cache.report_corrupt(path, exc, op="csr")
        self._stats.corrupt += 1
        if self.coord.observe_fault(f"corrupt graph {path}: {exc}") \
                == "degrade":
            self._degrade_admission()
        return err

    # -- requests ------------------------------------------------------------

    def submit(self, path: str, *, start: Optional[int] = None,
               prompt_len: Optional[int] = None, max_new: int = 8,
               rid: Optional[int] = None, **open_kw) -> Request:
        """Admit one walk-LM request against ``path``.  The prompt is a
        deterministic random walk over the graph as it exists on disk
        *now* (resolved through the cache, so a swapped snapshot serves
        its new contents from this request on).  ``start`` pins the
        walk's first vertex; default start and every neighbor draw are
        pure functions of ``(seed, rid, graph)``."""
        rid = next(self._rids) if rid is None else rid
        n = self.prompt_len if prompt_len is None else int(prompt_len)
        _, offsets, targets, v = self._graph(path, **open_kw)
        key = jax.random.key(self.seed)
        if start is None:
            walk = random_walks(offsets, targets, key, num_walks=1,
                                length=n, num_vertices=v, walk_offset=rid)
        else:
            walk = walk_from(offsets, targets, walk_keys(key, [rid]),
                             [int(start)], length=n)
        prompt = np.asarray(walk[0] % self.cfg.vocab_size, np.int32)
        req = Request(rid, prompt, max_new)
        self.engine.submit(req)
        return req

    # -- serving loop --------------------------------------------------------

    def _degrade_admission(self) -> None:
        """Halve the engine's admission width (floor 1) — shared by the
        straggler policy and the corrupt-graph path."""
        eng = self.engine
        self._ok_streak = 0
        new = max(1, eng.max_active // 2)
        if new < eng.max_active:
            eng.max_active = new
            self._stats.degrades += 1

    def _observe(self, dt: float) -> None:
        action = self.coord.observe_step(dt)
        eng = self.engine
        if action == "straggler-degrade":
            self._degrade_admission()
        elif action == "ok" and eng.max_active < eng.batch:
            self._ok_streak += 1
            if self._ok_streak >= self.coord.cfg.straggler_window:
                eng.max_active = min(eng.batch, eng.max_active * 2)
                self._stats.restores += 1
                self._ok_streak = 0

    def tick(self) -> int:
        """One timed engine tick; feeds the straggler policy and the
        counters.  Returns the number of active slots decoded."""
        t0 = time.perf_counter()
        n = self.engine.step()
        dt = time.perf_counter() - t0
        st = self._stats
        st.ticks += 1
        st.active_ticks += n
        st.seconds += dt
        for req in self.engine.completed[self._completed_seen:]:
            st.requests += 1
            st.tokens += len(req.out)
        self._completed_seen = len(self.engine.completed)
        self._observe(dt)
        return n

    def drain(self, max_ticks: Optional[int] = None) -> int:
        """Tick until every submitted request completes (or the
        coordinator flags preemption — in-flight work stays queued in
        the engine and a fresh ``drain()`` finishes it).  Returns ticks
        run."""
        ticks = 0
        eng = self.engine
        while eng.queue or any(r is not None for r in eng.slots):
            if self.coord.should_stop():
                break
            if max_ticks is not None and ticks >= max_ticks:
                raise RuntimeError(
                    f"ServeRuntime.drain: requests pending after "
                    f"max_ticks={max_ticks}")
            self.tick()
            ticks += 1
        return ticks

    def serve(self, paths, *, max_new: int = 8, **submit_kw) -> List[Request]:
        """Submit one request per path and drain: the benchmark's
        sustained-traffic entry."""
        reqs = [self.submit(p, max_new=max_new, **submit_kw) for p in paths]
        self.drain()
        return reqs

    # -- training-side corpus ------------------------------------------------

    def corpus(self, path: str, ccfg: CorpusConfig, *, start_step: int = 0,
               sharding=None, **open_kw):
        """A step-indexed walk-batch stream over ``path``, resolved
        through the same mtime-validated cache as requests.  A
        ``start_step > 0`` is a resume (counted in stats) and
        continues the stream bitwise-identically."""
        src = self.cache.get(path, **open_kw)
        if start_step:
            self._stats.resumes += 1
        return WalkCorpus(src, ccfg).batches(start_step=start_step,
                                             sharding=sharding)

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """The runtime's counters plus the cache's (hits/misses/
        invalidations and the decoded-frame memo of the hot handles)."""
        st = self._stats
        cache = self.cache.stats()
        return {
            "requests": st.requests,
            "tokens": st.tokens,
            "tokens_per_s": round(st.tokens_per_s(), 3),
            "ticks": st.ticks,
            "occupancy": round(st.occupancy(self.engine.batch), 4),
            "max_active": self.engine.max_active,
            "degrades": st.degrades,
            "restores": st.restores,
            "resumes": st.resumes,
            "corrupt_requests": st.corrupt,
            "seconds": round(st.seconds, 6),
            "cache": cache,
        }

    def close(self) -> None:
        self.coord.close()

    def __enter__(self) -> "ServeRuntime":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
