"""Sharding rules: logical param roles -> PartitionSpecs on the mesh.

Mesh axes: ("pod", "data", "model") multi-pod / ("data", "model") single.
  * "model" carries TP (padded Q heads, d_ff, d_inner, experts-when-divisible)
  * ("pod","data") carry DP; FSDP_ARCHS additionally shard big weight
    matrices over them (weights too large for 16 GB chips under pure TP)
  * optimizer moments get ZeRO-1 sharding over the DP axes on top of the
    param spec (first still-replicated divisible dim).

Rules dispatch on (leaf name, rank); scanned segment stacks get a leading
None for the layer dim.  Every rule degrades to replication when a dim is
not divisible by its axis product — correctness never depends on layout.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, axes, dim: int):
    """axes if dim divides evenly, else None (replicate)."""
    if axes is None or dim % _axsize(mesh, axes) != 0:
        return None
    return axes


def batch_axes(mesh: Mesh, batch: int):
    """Largest prefix-combination of DP axes that divides the batch."""
    cands = []
    if "pod" in mesh.shape:
        cands.append(("pod", "data"))
    cands.append(("data",))
    for c in cands:
        if batch % _axsize(mesh, c) == 0:
            return c
    return None


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...], cfg, mesh: Mesh,
                *, fsdp: bool) -> P:
    name = path[-1]
    stacked = any(p.startswith("seg") for p in path)
    rank = len(shape) - (1 if stacked else 0)
    dims = shape[1:] if stacked else shape
    tp = "model"
    fa = dp_axes(mesh) if fsdp else None
    mb = functools.partial(_maybe, mesh)

    def spec(*parts):
        parts = tuple(parts)
        assert len(parts) == rank, (path, shape, parts)
        return P(*(((None,) if stacked else ()) + parts))

    if name == "embed":
        return P(mb(tp, shape[0]), mb(fa, shape[1]))
    if rank == 1:   # norms, biases, lam, D
        big = dims[0] >= 1024
        return spec(mb(tp, dims[0]) if big and name in ("conv_b", "dt_bias",
                                                        "D", "lam") else None)
    if name == "wq":
        return spec(mb(fa, dims[0]), mb(tp, dims[1]), None)
    if name in ("wk", "wv"):
        return spec(mb(fa, dims[0]), mb(tp, dims[1]), None)
    if name == "wo":
        return spec(mb(tp, dims[0]), None, mb(fa, dims[2]))
    if name in ("w_in", "w_gate") and rank == 2:
        return spec(mb(fa, dims[0]), mb(tp, dims[1]))
    if name == "w_out" and rank == 2:
        return spec(mb(tp, dims[0]), mb(fa, dims[1]))
    if name == "router":
        return spec(mb(fa, dims[0]), None)
    if name in ("w_in", "w_gate") and rank == 3:   # moe (E, D, F)
        if mb(tp, dims[0]) is not None:            # expert parallel
            return spec(tp, mb(fa, dims[1]), None)
        return spec(None, mb(fa, dims[1]), mb(tp, dims[2]))
    if name == "w_out" and rank == 3:              # moe (E, F, D)
        if mb(tp, dims[0]) is not None:
            return spec(tp, None, mb(fa, dims[2]))
        return spec(None, mb(tp, dims[1]), mb(fa, dims[2]))
    if name == "in_proj":                          # (D, 2*inner)
        return spec(mb(fa, dims[0]), mb(tp, dims[1]))
    if name == "out_proj":                         # (inner, D)
        return spec(mb(tp, dims[0]), mb(fa, dims[1]))
    if name == "conv_w":                           # (k, inner)
        return spec(None, mb(tp, dims[1]))
    if name == "x_proj":                           # (inner, dt_rank+2N)
        return spec(mb(tp, dims[0]), None)
    if name == "dt_proj":                          # (dt_rank, inner)
        return spec(None, mb(tp, dims[1]))
    if name == "A_log":                            # (inner, N)
        return spec(mb(tp, dims[0]), None)
    if name in ("wr", "wi"):                       # (W, W) row-parallel
        return spec(mb(tp, dims[0]), None)
    return spec(*([None] * rank))


def param_shardings(abstract_params, cfg, mesh: Mesh, *, fsdp: bool):
    def one(path, leaf):
        names = tuple(str(getattr(k, "key", k)) for k in path)
        return NamedSharding(mesh, param_pspec(names, leaf.shape, cfg, mesh,
                                               fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def zero1_pspec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: shard the first still-replicated divisible dim over DP
    (skipped if the param spec already consumes a DP axis, e.g. FSDP)."""
    da = dp_axes(mesh)
    size = _axsize(mesh, da)
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            used.add(a)
    if any(a in used for a in da):
        return P(*parts)
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % size == 0 and d >= size:
            parts[i] = da
            return P(*parts)
    return P(*parts)


def moment_shardings(abstract_params, param_shardings_tree, mesh: Mesh):
    def one(leaf, sh):
        return NamedSharding(mesh, zero1_pspec(sh.spec, leaf.shape, mesh))
    return jax.tree.map(one, abstract_params, param_shardings_tree)


# ---- activations / batches ---------------------------------------------------

def batch_pspec(mesh: Mesh, batch: int, rank: int) -> P:
    ba = batch_axes(mesh, batch)
    return P(*((ba,) + (None,) * (rank - 1)))


def batch_shardings(mesh: Mesh, abstract_batch):
    def one(leaf):
        return NamedSharding(mesh, batch_pspec(mesh, leaf.shape[0], leaf.ndim))
    return jax.tree.map(one, abstract_batch)


def cache_pspec(path: Tuple[str, ...], shape, cfg, mesh: Mesh) -> P:
    """Cache layout: batch over DP axes; mamba/rglru inner dim over model.
    Leading dim is the stacked layer axis (None)."""
    name = path[-1]
    dims = shape[1:]            # drop layer-stack dim
    b = dims[0] if dims else 1
    ba = batch_axes(mesh, b)
    mb = functools.partial(_maybe, mesh)
    if name in ("k", "v"):
        # (B, S_cache, K, hd): prefer sharding KV heads over "model";
        # fall back to sequence-sharding the cache (distributed softmax
        # is GSPMD-native: reductions over the sharded S dim become small
        # psums) so 32k caches never replicate across TP.
        if mb("model", dims[2]) is not None:
            return P(None, ba, None, "model", None)
        return P(None, ba, mb("model", dims[1]), None, None)
    if name == "conv":
        return P(None, ba, None, mb("model", dims[2]))
    if name == "ssm":
        return P(None, ba, mb("model", dims[1]), None)
    if name == "h":
        return P(None, ba, mb("model", dims[1]))
    return P(*([None] * len(shape)))


def cache_shardings(abstract_caches, cfg, mesh: Mesh):
    def one(path, leaf):
        names = tuple(str(getattr(k, "key", k)) for k in path)
        return NamedSharding(mesh, cache_pspec(names, leaf.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, abstract_caches)
