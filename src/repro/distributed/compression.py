"""Gradient compression: int8 quantization with error feedback.

For DP all-reduce at 1000-node scale the gradient volume dominates the
interconnect; int8 + error feedback (1-bit-Adam-family result) preserves
convergence while cutting wire bytes 4x vs f32 / 2x vs bf16.

Two entry points:
  * quantize/dequantize + error feedback buffers — composed into the
    optimizer step (the simulation path used on this host; convergence
    parity is tested).
  * compressed_psum — a shard_map collective that all-reduces the int8
    payload (+ per-tensor scales) instead of the raw values; this is the
    deployment path, expressed with jax.lax collectives so XLA schedules
    it like any other reduce.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_buf):
    """grads + carried error -> (dequantized grads, new error)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_e = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_e


def init_error_buf(abstract_grads):
    return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32),
                        abstract_grads)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Reference semantics for a quantized all-reduce (shard_map body):
    each shard's contribution passes through int8 quantization before the
    sum.  The psum itself runs dequantized — use ``compressed_allreduce``
    for the wire-efficient schedule; this form exists to test accuracy of
    the quantization in isolation from the collective layout."""
    q, s = quantize_int8(x)
    return jax.lax.psum(dequantize_int8(q, s), axis_name)


def compressed_allreduce(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """Wire-efficient int8 all-reduce: reduce-scatter as an int8
    all_to_all, sum locally in f32, then all_gather the int8 result.

    Wire bytes ~ 2 * P/4 vs 2 * P for an f32 all-reduce: a 4x cut, which
    is the whole point of gradient compression at pod scale.  Accuracy:
    two int8 quantizations (send + result) with per-shard scales.
    """
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    seg = flat.reshape(n, -1)
    q, s = quantize_int8(seg)                       # one scale per device
    # every device receives the n shards of its segment
    shards = jax.lax.all_to_all(q, axis_name, 0, 0)      # (n, seg) int8
    scales = jax.lax.all_gather(s, axis_name)            # (n,)
    summed = jnp.sum(shards.astype(jnp.float32)
                     * scales.reshape(n, *([1] * (q.ndim - 1))), axis=0)
    q2, s2 = quantize_int8(summed)
    out = jax.lax.all_gather(q2, axis_name).astype(jnp.float32)  # (n, seg)
    s2g = jax.lax.all_gather(s2, axis_name)
    out = out * s2g.reshape(n, *([1] * (out.ndim - 1)))
    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)
