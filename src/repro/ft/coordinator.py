"""Fault-tolerance coordinator: checkpoint/restart, stragglers, preemption.

Single-process embodiment of the control plane a 1000-node job needs;
every policy is a pure function of observable timings/flags so the unit
tests can inject failures deterministically.

  * step-granular async checkpointing every `ckpt_every` steps, atomic
    on disk, with deterministic data skip on restart (the data pipeline
    is step-indexed, so resume(step=n) replays nothing),
  * straggler detection: a step slower than `straggler_factor` x the
    trailing-median is flagged; policy "warn" logs, "rebatch" re-issues
    the step with the same data (idempotent because the step index did
    not advance), "degrade" tells the driver to shrink its batch /
    admission width instead of stalling (the serving runtime halves
    engine occupancy; per-walk corpus keying keeps the surviving rows
    bitwise identical — see repro.serve.runtime),
  * preemption: SIGTERM/SIGUSR1 set a flag; the loop checkpoints and
    exits cleanly at the next step boundary,
  * failure injection: `inject_failure(step)` raises inside the loop to
    exercise restart-from-checkpoint in tests,
  * elastic restart: on resume the mesh may have a different device
    count — restore goes through checkpoint.reshard.

Signal handlers are installed only with ``handle_signals=True``, and
the previously-installed handlers are saved and put back by
:meth:`Coordinator.close` (the class is a context manager), so stacked
or sequential coordinators never clobber each other's — or the host
application's — handlers.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional

_POLICIES = ("warn", "rebatch", "degrade")


@dataclasses.dataclass
class FTConfig:
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 20
    straggler_policy: str = "warn"      # warn | rebatch | degrade
    handle_signals: bool = False


class Coordinator:
    def __init__(self, cfg: FTConfig):
        if cfg.straggler_policy not in _POLICIES:
            raise ValueError(
                f"straggler_policy must be one of {_POLICIES}, "
                f"got {cfg.straggler_policy!r}")
        self.cfg = cfg
        self.step_times: List[float] = []
        self.preempted = False
        self.events: List[str] = []
        self._fail_at: Optional[int] = None
        self._prev_handlers: Dict[int, object] = {}
        if cfg.handle_signals:
            for sig in (signal.SIGTERM, signal.SIGUSR1):
                self._prev_handlers[sig] = signal.signal(sig, self._on_signal)

    def _on_signal(self, signum, frame):
        self.preempted = True
        self.events.append(f"preempt signal {signum}")

    def close(self) -> None:
        """Restore the signal handlers this coordinator displaced.
        Idempotent; a coordinator that installed none is a no-op."""
        while self._prev_handlers:
            sig, prev = self._prev_handlers.popitem()
            signal.signal(sig, prev)

    def __enter__(self) -> "Coordinator":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ---- test hooks ----------------------------------------------------------
    def inject_failure(self, step: int):
        self._fail_at = step

    def maybe_fail(self, step: int):
        if self._fail_at is not None and step == self._fail_at:
            self._fail_at = None
            self.events.append(f"injected failure at step {step}")
            raise RuntimeError(f"injected node failure at step {step}")

    # ---- policies -------------------------------------------------------------
    def observe_step(self, seconds: float) -> str:
        """Record a step time; returns action: ok | straggler-warn |
        straggler-rebatch | straggler-degrade."""
        w = self.step_times[-self.cfg.straggler_window:]
        self.step_times.append(seconds)
        if len(w) >= 5:
            med = statistics.median(w)
            if seconds > self.cfg.straggler_factor * med:
                act = f"straggler-{self.cfg.straggler_policy}"
                self.events.append(
                    f"straggler: {seconds:.3f}s vs median {med:.3f}s -> {act}")
                return act
        return "ok"

    def observe_fault(self, description: str) -> str:
        """Record a data-plane fault (corrupt graph section, stuck
        reader) in the event log; returns the action the straggler
        policy implies — ``degrade`` narrows serving instead of
        stalling it, any other policy just logs (``warn``).  The
        serving runtime routes corrupt-graph detections through here so
        the coordinator's event log is the one fault timeline."""
        act = ("degrade" if self.cfg.straggler_policy == "degrade"
               else "warn")
        self.events.append(f"fault: {description} -> {act}")
        return act

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.cfg.ckpt_every == 0

    def should_stop(self) -> bool:
        return self.preempted
