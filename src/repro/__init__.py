"""repro: GVEL graph loading + multi-pod JAX training/serving framework.

Import note: this top-level module must stay import-light (no jax) so
launch/dryrun.py can set XLA_FLAGS before jax initializes.
"""
__version__ = "1.0.0"
