"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    mlp="swiglu",
    moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, capacity_factor=2.0),
    sub_quadratic=False,           # full attention -> long_500k skipped
    notes="true EP: 128 experts / TP=16 = 8 per shard; 40 q heads pad to 48; "
          "FSDP over data axes for the 400B params.",
)
