"""falcon-mamba-7b [ssm]: Mamba-1, attention-free, d_state=16.
[arXiv:2410.05355; unverified]"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    layer_pattern=("mamba",),
    sub_quadratic=True,            # O(1) state per token
    notes="pure mamba blocks, no attention/MLP; d_inner=8192 TP-sharded.",
)
