"""recurrentgemma-2b [hybrid]: RG-LRU + local attention 2:1, GeGLU MLP.
[arXiv:2402.19427; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    mlp="geglu",
    window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    sub_quadratic=True,            # recurrence + windowed attention: O(S)
    notes="8 full (rglru,rglru,attn) super-blocks + 2 trailing rglru; "
          "10 q heads pad to 16 under TP=16.",
)
