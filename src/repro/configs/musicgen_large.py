"""musicgen-large [audio]: decoder-only over EnCodec tokens.
Backbone only — the EnCodec frontend is a stub: train/prefill consume
precomputed frame embeddings (B, S, D); decode consumes code ids.
[arXiv:2306.05284; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp="gelu",
    embed_stub=True,
    sub_quadratic=False,
    notes="MHA (kv == heads == 32, shardable 16-way).",
)
