"""granite-20b [dense]: llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp="swiglu",
    sub_quadratic=False,
    notes="MQA: single kv head replicated across TP (1 % 16 != 0).",
)
