"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    mlp="swiglu",
    sub_quadratic=False,
    notes="24 q heads pad to 32 under TP=16.",
)
