"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, MoEConfig, SSMConfig

from .nemotron_4_15b import CONFIG as _nemotron
from .granite_20b import CONFIG as _granite
from .starcoder2_7b import CONFIG as _starcoder2
from .phi4_mini_3_8b import CONFIG as _phi4
from .recurrentgemma_2b import CONFIG as _rg
from .mixtral_8x22b import CONFIG as _mixtral
from .llama4_maverick_400b import CONFIG as _llama4
from .musicgen_large import CONFIG as _musicgen
from .llama32_vision_11b import CONFIG as _llama_vision
from .falcon_mamba_7b import CONFIG as _falcon_mamba

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _nemotron, _granite, _starcoder2, _phi4, _rg, _mixtral, _llama4,
        _musicgen, _llama_vision, _falcon_mamba,
    ]
}

# archs that need FSDP (params too large for pure TP on 16 GB chips).
# 15-20B dense models fit TP16 + ZeRO-1 comfortably (bf16 compute copy
# ~2-2.5 GB/chip, f32 master+moments sharded over 256 chips) — putting
# them under FSDP costs a full weight all-gather per microbatch per layer
# (measured 1.4 TB/device/step on nemotron train_4k; see EXPERIMENTS §Perf).
FSDP_ARCHS = {"mixtral-8x22b", "llama4-maverick-400b-a17b"}

# archs whose training state is kept in bf16 (f32 master + moments would
# exceed 16 GB/chip even fully sharded; standard practice for 100B+ MoEs)
BF16_STATE_ARCHS = {"mixtral-8x22b", "llama4-maverick-400b-a17b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    cfg = get_config(name)
    pat = cfg.layer_pattern
    layers = max(len(pat), 2 * len(pat))
    kw = dict(
        num_layers=layers,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else None,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else None,
        lru_width=64 if cfg.lru_width else None,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
    )
    if cfg.moe:
        # capacity 8.0: no token dropping at smoke scale, so the cached
        # decode path is exactly comparable with the full forward
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4,
                                        top_k=cfg.moe.top_k, d_ff=64,
                                        group_size=64, capacity_factor=8.0)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=4, d_conv=4, expand=2, dt_rank=8)
    return dataclasses.replace(cfg, **kw)
