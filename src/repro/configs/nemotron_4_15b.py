"""nemotron-4-15b [dense]: GQA kv=8, squared-ReLU MLP, RoPE.
[arXiv:2402.16819; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp="relu2",
    sub_quadratic=False,
    notes="squared-ReLU MLP (2 matmuls), RoPE, GQA 48q/8kv.",
)
