"""starcoder2-7b [dense]: GQA kv=4, RoPE. [arXiv:2402.19173; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp="gelu",
    sub_quadratic=False,
    notes="36 q heads pad to 48 under TP=16 (zeroed pad heads).",
)
