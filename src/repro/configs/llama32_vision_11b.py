"""llama-3.2-vision-11b [vlm]: cross-attn image layers every 5th layer.
Vision tower is a stub: input_specs supplies (B, 1601, D) patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    mlp="swiglu",
    layer_pattern=("attn", "attn", "attn", "attn", "xattn"),
    num_image_tokens=1601,
    sub_quadratic=False,
    notes="8 (4 self + 1 cross) super-blocks = 40 layers.",
)
