"""mixtral-8x22b [moe]: 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp="swiglu",
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=16384),
    sub_quadratic=True,            # SWA window 4096: O(S*W)
    notes="8 experts < TP=16: tensor-parallel experts (d_ff sharded); "
          "FSDP over data axes for the 140B params.",
)
