"""Pallas TPU kernels for GVEL's compute hot spots.

Each kernel package ships kernel.py (pl.pallas_call + explicit BlockSpec
VMEM tiling), ops.py (jit'd wrapper with an XLA fallback), and ref.py
(pure-jnp oracle used by the allclose test sweeps).

  parse_edges       text block -> packed edges (GVEL Alg. 1 hot loop)
  degree_histogram  contention-free degree counting (rho-partition analogue)
  exclusive_scan    degrees -> CSR offsets (Alg. 2 exclusiveScan)
  neighbor_gather   batched CSR row gather (sampler consumer of the CSR)
"""
from .parse_edges import parse_edges, parse_edges_accumulate, parse_edges_ref
from .degree_histogram import degree_histogram, degree_histogram_ref
from .exclusive_scan import csr_offsets, exclusive_scan, exclusive_scan_ref
from .neighbor_gather import neighbor_gather, neighbor_gather_ref

__all__ = [
    "parse_edges", "parse_edges_accumulate", "parse_edges_ref",
    "degree_histogram", "degree_histogram_ref",
    "exclusive_scan", "csr_offsets", "exclusive_scan_ref",
    "neighbor_gather", "neighbor_gather_ref",
]
