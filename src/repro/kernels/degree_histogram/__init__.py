from .ops import degree_histogram
from .ref import degree_histogram_ref

__all__ = ["degree_histogram", "degree_histogram_ref"]
