"""Jit'd public wrapper for the degree_histogram Pallas kernel."""
from __future__ import annotations

from .kernel import degree_histogram_kernel
from .ref import degree_histogram_ref


def degree_histogram(src, *, num_vertices: int, e_blk: int = 2048,
                     vt: int = 512, use_kernel: bool = True,
                     interpret: bool = True):
    if use_kernel:
        return degree_histogram_kernel(src, num_vertices=num_vertices,
                                       e_blk=e_blk, vt=vt, interpret=interpret)
    return degree_histogram_ref(src, num_vertices=num_vertices)
