"""Pallas TPU kernel: contention-free vertex-degree histogram.

The TPU adaptation of GVEL's rho-partitioned atomic degree counting.
TPUs have no atomics; the native contention-free reduction is
broadcast-compare-and-sum: for a tile of vertices [v0, v0+VT) and a block
of E_BLK edge sources, build the (E_BLK, VT) match matrix and sum over
edges.  Every (edge-block, vertex-tile) grid cell is independent work —
the role GVEL's partitions play — and accumulation over edge blocks uses
the sequential-grid revisiting pattern (`o_ref +=`), which is race-free
on TPU because the grid is executed in order.

Cost is O(E * V / lane-width) compares, so the production pipeline
radix-buckets edges by vertex range first (the staged build) and runs
this kernel per bucket where V_local is a few thousand; within a bucket
it beats scatter because it is pure VPU compare/add with zero memory
conflicts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _hist_body(src_ref, o_ref, *, vt: int):
    i = pl.program_id(0)           # edge-block index (accumulation dim)
    j = pl.program_id(1)           # vertex-tile index
    e_blk = src_ref.shape[-1]
    src = src_ref[0, :]                              # (E_BLK,)
    v0 = j * vt
    lanes = jax.lax.iota(I32, vt) + v0               # (VT,)
    match = (src[:, None] == lanes[None, :])         # (E_BLK, VT) — VPU compare
    partial = jnp.sum(match.astype(I32), axis=0)     # (VT,)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, :] += partial


@functools.partial(jax.jit, static_argnames=("num_vertices", "e_blk", "vt",
                                             "interpret"))
def degree_histogram_kernel(src: jax.Array, *, num_vertices: int,
                            e_blk: int = 2048, vt: int = 512,
                            interpret: bool = True) -> jax.Array:
    """src: (E,) int32 (pad = -1) -> degrees (num_vertices,) int32."""
    e = src.shape[0]
    pe = max(-(-e // e_blk) * e_blk, e_blk)   # at least one block (E may be 0)
    pv = -(-num_vertices // vt) * vt
    if pe != e:
        src = jnp.concatenate([src, jnp.full((pe - e,), -1, I32)])
    src2 = src.reshape(pe // e_blk, e_blk)
    grid = (pe // e_blk, pv // vt)
    out = pl.pallas_call(
        functools.partial(_hist_body, vt=vt),
        grid=grid,
        in_specs=[pl.BlockSpec((1, e_blk), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, vt), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, pv), I32),
        interpret=interpret,
    )(src2)
    return out[0, :num_vertices]
