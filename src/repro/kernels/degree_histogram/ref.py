"""Pure-jnp oracle: scatter-add degree count (repro.core.degrees)."""
from __future__ import annotations

from ...core.degrees import degrees_global


def degree_histogram_ref(src, *, num_vertices: int):
    return degrees_global(src, num_vertices)
