"""Pallas TPU kernel: batched CSR row gather (fixed-width neighbor slices).

The consumer side of GVEL's CSR: the random-walk sampler (repro.data.walks)
needs, for a batch of vertices, a fixed-width window of each vertex's
adjacency row plus its degree.  On TPU this is one DMA-friendly dynamic
slice per vertex: offsets live in SMEM-like scalar storage, the targets
array streams through VMEM via `pl.ds` dynamic slices — the pattern paged
attention uses for KV lookup, applied to graph adjacency.

Each grid step handles one batch tile of vertices with a fori_loop of
dynamic loads; out-of-row lanes are masked to -1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _gather_body(u_ref, off_ref, tgt_ref, out_ref, deg_ref, *, width: int):
    bt = u_ref.shape[-1]

    def one(i, _):
        u = u_ref[0, i]
        lo = off_ref[u]
        hi = off_ref[u + 1]
        deg = hi - lo
        # clamp the slice start so the fixed-width window stays in bounds
        start = jnp.minimum(lo, jnp.maximum(tgt_ref.shape[-1] - width, 0))
        row = pl.load(tgt_ref, (pl.ds(start, width),))
        lane = jax.lax.iota(I32, width)
        shifted = lo - start
        valid = (lane >= shifted) & (lane < shifted + jnp.minimum(deg, width))
        # re-align so lane 0 is the first neighbor
        row = jnp.roll(row, -shifted)
        valid = jnp.roll(valid, -shifted)
        out_ref[i, :] = jnp.where(valid, row, -1)
        deg_ref[0, i] = deg
        return 0

    jax.lax.fori_loop(0, bt, one, 0)


@functools.partial(jax.jit, static_argnames=("width", "bt", "interpret"))
def neighbor_gather_kernel(vertices: jax.Array, offsets: jax.Array,
                           targets: jax.Array, *, width: int = 128,
                           bt: int = 256, interpret: bool = True):
    """vertices (B,), offsets (V+1,), targets (E,) ->
    (neighbors (B, width) padded -1, degrees (B,))."""
    b = vertices.shape[0]
    pb = -(-b // bt) * bt
    if pb != b:
        vertices = jnp.concatenate([vertices, jnp.zeros((pb - b,), I32)])
    v2 = vertices.reshape(pb // bt, bt)
    out, deg = pl.pallas_call(
        functools.partial(_gather_body, width=width),
        grid=(pb // bt,),
        in_specs=[
            pl.BlockSpec((1, bt), lambda i: (i, 0)),
            pl.BlockSpec(offsets.shape, lambda i: (0,)),   # whole offsets
            pl.BlockSpec(targets.shape, lambda i: (0,)),   # whole targets
        ],
        out_specs=(
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((1, bt), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((pb, width), I32),
            jax.ShapeDtypeStruct((pb // bt, bt), I32),
        ),
        interpret=interpret,
    )(v2, offsets, targets)
    return out[:b], deg.reshape(-1)[:b]
