from .ops import neighbor_gather
from .ref import neighbor_gather_ref

__all__ = ["neighbor_gather", "neighbor_gather_ref"]
