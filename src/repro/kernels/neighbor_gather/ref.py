"""Pure-jnp oracle for neighbor_gather: vectorized dynamic-slice gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

I32 = jnp.int32


@functools.partial(jax.jit, static_argnames=("width",))
def neighbor_gather_ref(vertices, offsets, targets, *, width: int = 128):
    if targets.shape[0] < width:     # tiny graphs: keep window in bounds
        targets = jnp.concatenate(
            [targets, jnp.full((width - targets.shape[0],), -1,
                               targets.dtype)])
    e = targets.shape[0]

    def one(u):
        lo = offsets[u]
        hi = offsets[u + 1]
        deg = hi - lo
        start = jnp.minimum(lo, jnp.maximum(e - width, 0))
        row = jax.lax.dynamic_slice(targets, (start,), (width,))
        lane = jnp.arange(width, dtype=I32)
        shifted = lo - start
        valid = (lane >= shifted) & (lane < shifted + jnp.minimum(deg, width))
        row = jnp.roll(row, -shifted)
        valid = jnp.roll(valid, -shifted)
        return jnp.where(valid, row, -1), deg

    return jax.vmap(one)(vertices)
