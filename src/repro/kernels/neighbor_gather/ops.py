"""Jit'd public wrapper for the neighbor_gather Pallas kernel."""
from __future__ import annotations

from .kernel import neighbor_gather_kernel
from .ref import neighbor_gather_ref


def neighbor_gather(vertices, offsets, targets, *, width: int = 128,
                    bt: int = 256, use_kernel: bool = True,
                    interpret: bool = True):
    if targets.shape[0] < width:       # tiny graphs: pad so the fixed-width
        import jax.numpy as jnp        # window slice is always in bounds
        pad = width - targets.shape[0]
        targets = jnp.concatenate(
            [targets, jnp.full((pad,), -1, targets.dtype)])
    if use_kernel:
        return neighbor_gather_kernel(vertices, offsets, targets, width=width,
                                      bt=bt, interpret=interpret)
    return neighbor_gather_ref(vertices, offsets, targets, width=width)
