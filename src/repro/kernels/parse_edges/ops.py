"""Jit'd public wrapper for the parse_edges Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import parse_edges_kernel
from .ref import parse_edges_ref


def parse_edges(bufs, owned_start: int, owned_end: int, *, weighted: bool = False,
                base: int = 1, edge_cap: int | None = None,
                use_kernel: bool = True, interpret: bool = True):
    """Parse (nb, buf_len) text blocks -> (src, dst, w, counts).

    use_kernel=False falls back to the pure-jnp oracle (the XLA path used
    when Mosaic dynamic-scatter support is unavailable).
    """
    nb, buf_len = bufs.shape
    if edge_cap is None:
        edge_cap = buf_len // 4 + 2
    owned = jnp.asarray([owned_start, owned_end], jnp.int32)
    if use_kernel:
        return parse_edges_kernel(bufs, owned, weighted=weighted, base=base,
                                  edge_cap=edge_cap, interpret=interpret)
    return parse_edges_ref(bufs, owned, weighted=weighted, base=base,
                           edge_cap=edge_cap)
