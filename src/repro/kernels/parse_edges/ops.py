"""Jit'd public wrappers for the parse_edges Pallas kernel.

Two entries share the byte-domain kernel (``parse_bytes_kernel``):

* :func:`parse_edges` — packed per-block ``(src, dst, w, counts)``; the
  historical contract used by the allclose test sweeps.
* :func:`parse_edges_accumulate` — the Pallas engine's streaming hot
  path: kernel parse and the batch-wide compaction into the donated
  packed accumulators run as **one jitted program**, exactly mirroring
  ``core.parse.parse_accumulate`` (the compaction is literally shared —
  ``core.parse._compact_accumulate``).  The per-block ``(nb, edge_cap)``
  intermediates and the separate scatter-accumulate program of the old
  two-step pipeline never materialize.

``use_kernel=None`` resolves per backend: the Mosaic kernel on TPU, the
pure-jnp oracle (the identical algebra, compiled by XLA) elsewhere —
interpret-mode Pallas is a debugging device, not a fast path, so CPU
runs should never pay for it implicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import parse as parse_core
from .kernel import parse_bytes_kernel, parse_edges_kernel
from .ref import parse_edges_ref


def _default_use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def parse_edges(bufs, owned_start: int, owned_end: int, *, weighted: bool = False,
                base: int = 1, edge_cap: int | None = None,
                use_kernel: bool = True, interpret: bool = True):
    """Parse (nb, buf_len) text blocks -> (src, dst, w, counts).

    use_kernel=False falls back to the pure-jnp oracle (the XLA path used
    when running off-TPU).
    """
    nb, buf_len = bufs.shape
    if edge_cap is None:
        edge_cap = buf_len // 4 + 2
    owned = jnp.asarray([owned_start, owned_end], jnp.int32)
    if use_kernel:
        return parse_edges_kernel(bufs, owned, weighted=weighted, base=base,
                                  edge_cap=edge_cap, interpret=interpret)
    return parse_edges_ref(bufs, owned, weighted=weighted, base=base,
                           edge_cap=edge_cap)


def _fused_impl(acc_src, acc_dst, acc_w, total, bufs, owned, *,
                weighted: bool, base: int, edge_bound: int, max_digits: int,
                use_kernel: bool, interpret: bool):
    if use_kernel:
        valid, src, dst, w = parse_bytes_kernel(
            bufs, owned, weighted=weighted, base=base, max_digits=max_digits,
            interpret=interpret)
    else:
        fn = functools.partial(parse_core._parse_block_bytes,
                               weighted=weighted, base=base,
                               max_digits=max_digits)
        valid, src, dst, w = jax.vmap(
            lambda b: fn(b, owned[0], owned[1]))(bufs)
    return parse_core._compact_accumulate(
        acc_src, acc_dst, acc_w, total, valid, src, dst, w,
        edge_bound=edge_bound)


@functools.lru_cache(maxsize=None)
def _fused_jit(donate: bool):
    return jax.jit(
        _fused_impl,
        static_argnames=("weighted", "base", "edge_bound", "max_digits",
                         "use_kernel", "interpret"),
        donate_argnums=(0, 1, 2) if donate else ())


def parse_edges_accumulate(acc_src, acc_dst, acc_w, total, bufs,
                           owned_start: int, owned_end: int, *,
                           weighted: bool = False, base: int = 1,
                           edge_bound: int | None = None,
                           max_digits: int = 9,
                           use_kernel: bool | None = None,
                           interpret: bool | None = None,
                           donate: bool | None = None):
    """Fused kernel parse + donated packed accumulation (one program).

    Drop-in peer of ``core.parse.parse_accumulate``: parses ``bufs``
    (nb, buf_len) and writes the batch's edges into the packed
    accumulators at offset ``total``, returning the updated
    ``(acc_src, acc_dst, acc_w, total)``.  Donated inputs are consumed —
    rebind, never reuse, the passed accumulators.
    """
    nb, buf_len = bufs.shape
    if edge_bound is None:
        edge_bound = nb * (buf_len // 4 + 2)
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if donate is None:
        donate = parse_core.donation_supported()
    owned = jnp.asarray([owned_start, owned_end], jnp.int32)
    return _fused_jit(bool(donate))(
        acc_src, acc_dst, acc_w, total, bufs, owned, weighted=weighted,
        base=base, edge_bound=edge_bound, max_digits=max_digits,
        use_kernel=bool(use_kernel), interpret=bool(interpret))
