from .ops import parse_edges, parse_edges_accumulate
from .ref import parse_edges_ref

__all__ = ["parse_edges", "parse_edges_accumulate", "parse_edges_ref"]
