"""Pallas TPU kernel: parse one edgelist text block -> packed edges.

The TPU realization of GVEL Algorithm 1's hot loop.  Each grid step DMAs
one `buf_len`-byte block (GVEL's beta=256 KiB fits VMEM with large
headroom — v5e VMEM is ~16 MiB and the working set here is ~12 bytes of
i32 state per input byte, so beta<=1 MiB tiles are safe) and runs the
mask/scan parse entirely in VMEM:

  byte classes -> token segmentation (cumsum) -> digit place values
  (segment algebra) -> per-line slots -> compaction scatter.

`weighted` is a *Python-level* specialization parameter — the paper found
(§4.1.6) that making the weighted flag a template parameter keeps the hot
loop small enough to stay in the instruction cache; here each value of
the flag produces a distinct, smaller Mosaic program, the same insight.

TPU lowering note: the compaction step uses dynamic scatter within VMEM
(`.at[].set`), which requires Mosaic's dynamic-indexing support; the
kernel is validated in interpret mode against ref.py and designed so all
other ops are VPU-native (compare/select/cumsum along the minor axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _parse_block_body(owned_ref, buf_ref, src_ref, dst_ref, w_ref, cnt_ref,
                      *, weighted: bool, base: int, max_digits: int):
    n = buf_ref.shape[-1]
    edge_cap = src_ref.shape[-1]
    line_cap = n + 1
    tok_cap = n // 2 + 2

    d = buf_ref[0, :].astype(I32)
    idx = jax.lax.iota(I32, n)
    owned_start = owned_ref[0]
    owned_end = owned_ref[1]

    is_digit = (d >= 48) & (d <= 57)
    is_dot = d == 46
    is_minus = d == 45
    is_tok = is_digit | is_dot | is_minus
    is_nl = d == 10
    is_ws = (d == 32) | (d == 9) | (d == 13)
    is_bad = ~(is_tok | is_nl | is_ws)

    prev_tok = jnp.concatenate([jnp.zeros((1,), bool), is_tok[:-1]])
    tok_start = is_tok & ~prev_tok
    tok_ord = jnp.cumsum(tok_start.astype(I32)) - 1
    num_toks = jnp.maximum(tok_ord[-1] + 1, 0)
    line_of = jnp.cumsum(is_nl.astype(I32)) - is_nl.astype(I32)

    def sset(cap, select, index, values, fill, dtype):
        out = jnp.full((cap,), fill, dtype)
        return out.at[jnp.where(select, index, cap)].set(
            values.astype(dtype), mode="drop")

    def sadd(cap, select, index, values, dtype):
        out = jnp.zeros((cap,), dtype)
        return out.at[jnp.where(select, index, cap)].add(
            values.astype(dtype), mode="drop")

    cum_dig = jnp.cumsum(is_digit.astype(I32))
    dig_before = sset(tok_cap, tok_start, tok_ord,
                      cum_dig - is_digit.astype(I32), 0, I32)
    tok_total_dig = sadd(tok_cap, is_tok, tok_ord, is_digit, I32)
    safe_ord = jnp.clip(tok_ord, 0, tok_cap - 1)
    dig_incl = cum_dig - dig_before[safe_ord]
    digits_after = jnp.clip(tok_total_dig[safe_ord] - dig_incl, 0, max_digits)

    digit_val = jnp.where(is_digit, d - 48, 0)
    pow10 = 10 ** jax.lax.iota(I32, max_digits + 1)
    contrib = digit_val * pow10[digits_after]
    tok_int = sadd(tok_cap, is_digit, tok_ord, contrib, I32)

    if weighted:
        tok_dot = sset(tok_cap, is_dot, tok_ord, idx, -1, I32)
        dot_of = tok_dot[safe_ord]
        is_frac = is_digit & (dot_of >= 0) & (idx > dot_of)
        tok_frac = sadd(tok_cap, is_tok, tok_ord, is_frac, I32)
        tok_neg = sadd(tok_cap, is_tok, tok_ord, is_minus, I32) > 0
        pow10f = jnp.float32(10.0) ** jax.lax.iota(jnp.float32, max_digits + 1)
        contrib_f = digit_val.astype(jnp.float32) * pow10f[digits_after]
        tok_allf = sadd(tok_cap, is_digit, tok_ord, contrib_f, jnp.float32)
        tok_float = tok_allf / pow10f[jnp.clip(tok_frac, 0, max_digits)]
        tok_float = jnp.where(tok_neg, -tok_float, tok_float)

    tok_line = sset(tok_cap, tok_start, tok_ord, line_of, line_cap, I32)
    t_ar = jax.lax.iota(I32, tok_cap)
    tok_valid = t_ar < num_toks
    tl = jnp.where(tok_valid, tok_line, line_cap)
    first_tok = jnp.full((line_cap + 1,), tok_cap, I32).at[
        jnp.where(tok_valid, tl, line_cap)].min(t_ar, mode="drop")[:-1]
    ord_in_line = t_ar - first_tok[jnp.clip(tl, 0, line_cap - 1)]

    ntok = sadd(line_cap, tok_valid, tl, jnp.ones_like(t_ar), I32)
    bad_line = sadd(line_cap, is_bad, line_of, jnp.ones_like(idx), I32) > 0
    term = sset(line_cap, is_nl, line_of, idx, -1, I32)

    def line_val(role, vals, fill, dtype):
        sel = tok_valid & (ord_in_line == role)
        return sset(line_cap, sel, tl, vals, fill, dtype)

    src_l = line_val(0, tok_int, -1, I32)
    dst_l = line_val(1, tok_int, -1, I32)
    if weighted:
        w_l = line_val(2, tok_float, 1.0, jnp.float32)
        has_w = line_val(2, jnp.ones_like(t_ar), 0, I32) > 0
        w_l = jnp.where(has_w, w_l, 1.0)

    owned = (term >= owned_start) & (term < owned_end)
    valid = owned & ~bad_line & (ntok >= 2)
    pos = jnp.cumsum(valid.astype(I32)) - 1
    cnt = jnp.maximum(pos[-1] + 1, 0)

    src_ref[0, :] = sset(edge_cap, valid, pos, src_l - base, -1, I32)
    dst_ref[0, :] = sset(edge_cap, valid, pos, dst_l - base, -1, I32)
    if weighted:
        w_ref[0, :] = sset(edge_cap, valid, pos, w_l, 0.0, jnp.float32)
    cnt_ref[0, 0] = cnt


@functools.partial(
    jax.jit,
    static_argnames=("weighted", "base", "edge_cap", "max_digits", "interpret"),
)
def parse_edges_kernel(
    bufs: jax.Array,          # (nb, buf_len) uint8
    owned: jax.Array,         # (2,) int32 — [owned_start, owned_end)
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
    interpret: bool = True,
):
    nb, buf_len = bufs.shape
    body = functools.partial(_parse_block_body, weighted=weighted, base=base,
                             max_digits=max_digits)
    out_shapes = (
        jax.ShapeDtypeStruct((nb, edge_cap), I32),       # src
        jax.ShapeDtypeStruct((nb, edge_cap), I32),       # dst
        jax.ShapeDtypeStruct((nb, edge_cap), jnp.float32),  # w (zeros if unweighted)
        jax.ShapeDtypeStruct((nb, 1), I32),              # count
    )
    grid = (nb,)
    src, dst, w, cnt = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),          # owned range (scalar-ish)
            pl.BlockSpec((1, buf_len), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, edge_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, edge_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, edge_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(owned, bufs)
    return src, dst, (w if weighted else None), cnt[:, 0]
