"""Pallas TPU kernel: edgelist text block -> per-byte parsed edges.

The TPU realization of GVEL Algorithm 1's hot loop.  Each grid step DMAs
one `buf_len`-byte block (GVEL's beta=256 KiB fits VMEM with large
headroom — v5e VMEM is ~16 MiB and the working set here is ~12 bytes of
i32 state per input byte, so beta<=1 MiB tiles are safe) and runs the
mask/scan parse entirely in VMEM:

  byte classes -> token segmentation (cumsum) -> digit place values
  (sorted-segment algebra: cumulative max/min/sum + gathers) -> per-line
  values pinned at terminating newlines.

The kernel emits the **byte domain**: ``valid[i]`` marks owned newlines
terminating well-formed edge lines, with that line's (src, dst, w) at
those bytes — the same contract as ``core.parse._parse_block_bytes``,
whose algebra this body mirrors operation for operation.  Compaction is
deliberately *outside* the kernel: the fused loader path packs a whole
batch with one scatter (``core.parse._compact_accumulate``) straight
into the donated accumulators, and the standalone ``parse_edges`` entry
compacts per block.  Keeping the kernel scatter-free means every op in
the body is VPU-native (compare/select/scan along the minor axis) — no
Mosaic dynamic-scatter support needed.

`weighted` is a *Python-level* specialization parameter — the paper found
(§4.1.6) that making the weighted flag a template parameter keeps the hot
loop small enough to stay in the instruction cache; here each value of
the flag produces a distinct, smaller Mosaic program, the same insight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _parse_bytes_body(owned_ref, buf_ref, valid_ref, src_ref, dst_ref, w_ref,
                      *, weighted: bool, base: int, max_digits: int):
    n = buf_ref.shape[-1]
    d = buf_ref[0, :].astype(I32)
    idx = jax.lax.iota(I32, n)
    owned_start = owned_ref[0]
    owned_end = owned_ref[1]

    is_digit = (d >= 48) & (d <= 57)
    is_dot = d == 46
    is_minus = d == 45
    is_tok = is_digit | is_dot | is_minus
    is_nl = d == 10
    is_ws = (d == 32) | (d == 9) | (d == 13)
    is_bad = ~(is_tok | is_nl | is_ws)

    prev_tok = jnp.concatenate([jnp.zeros((1,), bool), is_tok[:-1]])
    tok_start = is_tok & ~prev_tok
    next_tok = jnp.concatenate([is_tok[1:], jnp.zeros((1,), bool)])
    tok_end = is_tok & ~next_tok

    cum_ts = jnp.cumsum(tok_start.astype(I32))     # token starts <= i
    cum_dig = jnp.cumsum(is_digit.astype(I32))     # digits <= i

    # my token's end/start byte position, per byte (valid at token bytes:
    # tokens never span newlines, so runs are well-nested)
    end_pos = jax.lax.cummin(jnp.where(tok_end, idx, n - 1), reverse=True)
    start_pos = jax.lax.cummax(jnp.where(tok_start, idx, 0))

    # digits strictly after byte i within its token
    digits_after = jnp.clip(cum_dig[end_pos] - cum_dig, 0, max_digits)
    pow10_i = 10 ** jax.lax.iota(I32, max_digits + 1)
    contrib = jnp.where(is_digit, (d - 48) * pow10_i[digits_after], 0)
    csum_c = jnp.cumsum(contrib)       # int32 wraps; per-token diff is exact
    excl_c = csum_c - contrib
    # integer value of the token ending at byte i (valid at token ends)
    tok_val = csum_c - excl_c[start_pos]

    # latest newline strictly before byte i (-1: none)
    pex = jnp.concatenate([
        jnp.full((1,), -1, I32),
        jax.lax.cummax(jnp.where(is_nl, idx, -1))[:-1]])
    # token starts up to my line's opening newline
    cts_at = jnp.where(pex < 0, 0, cum_ts[jnp.maximum(pex, 0)])
    # my token's 0-based ordinal within its line (valid at token ends)
    ord_in_line = cum_ts - 1 - cts_at

    def role_pos(k):
        """Latest byte <= i ending a token with line-ordinal k."""
        return jax.lax.cummax(jnp.where(tok_end & (ord_in_line == k), idx, -1))

    p0, p1 = role_pos(0), role_pos(1)
    bad_pos = jax.lax.cummax(jnp.where(is_bad, idx, -1))

    owned = (idx >= owned_start) & (idx < owned_end)
    # ">= 2 tokens in the line" <=> a role-1 token ends inside it
    valid = is_nl & owned & (p1 > pex) & ~(bad_pos > pex)

    valid_ref[0, :] = valid.astype(I32)
    src_ref[0, :] = tok_val[jnp.maximum(p0, 0)] - base
    dst_ref[0, :] = tok_val[jnp.maximum(p1, 0)] - base

    if weighted:
        p2 = role_pos(2)
        dot_pos = jax.lax.cummax(jnp.where(is_dot, idx, -1))
        minus_pos = jax.lax.cummax(jnp.where(is_minus, idx, -1))
        p2c = jnp.maximum(p2, 0)
        w_start = start_pos[p2c]
        dot_of = dot_pos[p2c]
        frac_len = jnp.where(dot_of >= w_start,
                             cum_dig[p2c] - cum_dig[jnp.maximum(dot_of, 0)], 0)
        pow10_f = jnp.float32(10.0) ** jax.lax.iota(jnp.float32,
                                                    max_digits + 1)
        wf = tok_val[p2c].astype(jnp.float32) \
            / pow10_f[jnp.clip(frac_len, 0, max_digits)]
        wf = jnp.where(minus_pos[p2c] >= w_start, -wf, wf)
        w_ref[0, :] = jnp.where(p2 > pex, wf, 1.0)   # missing weight -> 1
    else:
        w_ref[0, :] = jnp.ones((n,), jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("weighted", "base", "max_digits", "interpret"),
)
def parse_bytes_kernel(
    bufs: jax.Array,          # (nb, buf_len) uint8
    owned: jax.Array,         # (2,) int32 — [owned_start, owned_end)
    *,
    weighted: bool,
    base: int,
    max_digits: int = 9,
    interpret: bool = True,
):
    """Per-byte parse of a batch of blocks: ``(valid, src, dst, w)``,
    each ``(nb, buf_len)`` (``w`` is None when unweighted).  The
    byte-domain contract of ``core.parse._parse_block_bytes``."""
    nb, buf_len = bufs.shape
    body = functools.partial(_parse_bytes_body, weighted=weighted, base=base,
                             max_digits=max_digits)
    out_shapes = (
        jax.ShapeDtypeStruct((nb, buf_len), I32),           # valid mask
        jax.ShapeDtypeStruct((nb, buf_len), I32),           # src
        jax.ShapeDtypeStruct((nb, buf_len), I32),           # dst
        jax.ShapeDtypeStruct((nb, buf_len), jnp.float32),   # w
    )
    spec = pl.BlockSpec((1, buf_len), lambda i: (i, 0))
    valid, src, dst, w = pl.pallas_call(
        body,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),   # owned range (scalar-ish)
            spec,
        ],
        out_specs=(spec, spec, spec, spec),
        out_shape=out_shapes,
        interpret=interpret,
    )(owned, bufs)
    return valid != 0, src, dst, (w if weighted else None)


def _compact_block(valid, src_b, dst_b, w_b, *, edge_cap: int,
                   weighted: bool):
    """One block's byte-domain parse -> fixed-capacity (src, dst, w, cnt);
    the single compaction scatter of ``core.parse.parse_block``."""
    n = valid.shape[0]
    pos = jnp.cumsum(valid.astype(I32)) - 1
    cnt = jnp.maximum(pos[-1] + 1, 0)
    packed = jnp.full((edge_cap,), n, I32).at[
        jnp.where(valid, pos, edge_cap)].set(
            jnp.arange(n, dtype=I32), mode="drop")
    pv = packed < n
    pc = jnp.minimum(packed, n - 1)
    src = jnp.where(pv, src_b[pc], -1)
    dst = jnp.where(pv, dst_b[pc], -1)
    w = jnp.where(pv, w_b[pc], 0.0) if weighted else None
    return src, dst, w, cnt


@functools.partial(
    jax.jit,
    static_argnames=("weighted", "base", "edge_cap", "max_digits",
                     "interpret"),
)
def parse_edges_kernel(
    bufs: jax.Array,          # (nb, buf_len) uint8
    owned: jax.Array,         # (2,) int32 — [owned_start, owned_end)
    *,
    weighted: bool,
    base: int,
    edge_cap: int,
    max_digits: int = 9,
    interpret: bool = True,
):
    """Kernel parse + per-block compaction: (src, dst, w, counts), each
    row a fixed-capacity block parse (the historical packed contract)."""
    valid, src, dst, w = parse_bytes_kernel(
        bufs, owned, weighted=weighted, base=base, max_digits=max_digits,
        interpret=interpret)
    fn = functools.partial(_compact_block, edge_cap=edge_cap,
                           weighted=weighted)
    if weighted:
        src_o, dst_o, w_o, cnt = jax.vmap(fn)(valid, src, dst, w)
    else:
        src_o, dst_o, w_o, cnt = jax.vmap(
            lambda v, s, d: fn(v, s, d, None))(valid, src, dst)
    return src_o, dst_o, w_o, cnt
