"""Pure-jnp oracle for the parse_edges kernel: repro.core.parse.parse_blocks."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.parse import parse_blocks


def parse_edges_ref(bufs, owned, *, weighted: bool, base: int, edge_cap: int,
                    max_digits: int = 9):
    nb = bufs.shape[0]
    os_ = jnp.full((nb,), owned[0], jnp.int32)
    oe = jnp.full((nb,), owned[1], jnp.int32)
    src, dst, w, cnt = parse_blocks(bufs, os_, oe, weighted=weighted,
                                    base=base, edge_cap=edge_cap,
                                    max_digits=max_digits)
    return src, dst, w, cnt
