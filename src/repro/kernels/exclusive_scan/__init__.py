from .ops import csr_offsets, exclusive_scan
from .ref import exclusive_scan_ref

__all__ = ["exclusive_scan", "csr_offsets", "exclusive_scan_ref"]
