"""Jit'd public wrapper for the exclusive_scan Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import exclusive_scan_kernel
from .ref import exclusive_scan_ref


def exclusive_scan(x, *, blk: int = 1024, use_kernel: bool = True,
                   interpret: bool = True):
    if use_kernel:
        return exclusive_scan_kernel(x, blk=blk, interpret=interpret)
    return exclusive_scan_ref(x)


def csr_offsets(degrees, *, blk: int = 1024, use_kernel: bool = True,
                interpret: bool = True):
    """degrees (V,) -> offsets (V+1,) via the scan kernel."""
    excl, total = exclusive_scan(degrees, blk=blk, use_kernel=use_kernel,
                                 interpret=interpret)
    return jnp.concatenate([excl, total[None]])
