"""Pallas TPU kernel: exclusive scan (degrees -> CSR offsets).

GVEL computes CSR offsets with `exclusiveScan` (Alg. 2 lines 7/27).  On
TPU the scan is hierarchical: the sequential grid walks V in blocks; each
step cumsums its block in VMEM and adds the running carry.  The carry
lives in a revisited (1,1) output block — grid steps execute in order on
a TPU core, so read-modify-write across steps is race-free (the same
idiom the histogram kernel uses to accumulate).  This replaces a
multicore two-phase upsweep/downsweep scan and touches the data exactly
once (memory-bound optimal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _scan_body(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0, 0] = jnp.zeros((), I32)

    x = x_ref[0, :]
    c = carry_ref[0, 0]
    incl = jnp.cumsum(x)
    o_ref[0, :] = c + incl - x          # exclusive
    carry_ref[0, 0] = c + incl[-1]


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def exclusive_scan_kernel(x: jax.Array, *, blk: int = 1024,
                          interpret: bool = True):
    """x: (N,) int32 -> (exclusive prefix sums (N,), total ()).

    The total is the scan carry — callers append it to form CSR offsets
    of length V+1 without a second reduction pass.
    """
    n = x.shape[0]
    pn = -(-n // blk) * blk
    if pn != n:
        x = jnp.concatenate([x, jnp.zeros((pn - n,), x.dtype)])
    x2 = x.reshape(pn // blk, blk)
    out, carry = pl.pallas_call(
        _scan_body,
        grid=(pn // blk,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),   # revisited carry cell
        ),
        out_shape=(
            jax.ShapeDtypeStruct((pn // blk, blk), I32),
            jax.ShapeDtypeStruct((1, 1), I32),
        ),
        interpret=interpret,
    )(x2)
    return out.reshape(-1)[:n], carry[0, 0]
