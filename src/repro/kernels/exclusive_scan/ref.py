"""Pure-jnp oracle for the exclusive_scan kernel."""
from __future__ import annotations

import jax.numpy as jnp


def exclusive_scan_ref(x):
    incl = jnp.cumsum(x)
    return incl - x, incl[-1] if x.shape[0] else jnp.zeros((), x.dtype)
