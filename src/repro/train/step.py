"""train_step factory: loss -> grads -> (optional compression) -> AdamW.

Two gradient-accumulation modes:

  * default (GSPMD): value_and_grad per microbatch inside a scan.  Simple,
    but XLA places the data-axis weight-gradient all-reduce INSIDE the
    loop — accum_steps x the collective bytes (measured 6.8 TB/dev/step on
    granite-20b train_4k at accum=16; see EXPERIMENTS §Perf).
  * local_accum (shard_map): the data axes are manual; per-device
    UNREDUCED gradients accumulate across microbatches and a single psum
    (optionally int8-compressed) runs once per step — the collective
    volume becomes independent of accum_steps.  This is the deployment
    mode; the GSPMD mode remains the reference implementation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import compat
from ..distributed.compression import compress_with_feedback, quantize_int8
from ..models.transformer import loss_fn
from .optimizer import OptimizerConfig, adamw_update, clip_by_global_norm
from .state import TrainState


def make_train_step(cfg, oc: OptimizerConfig, *, tp: int = 1,
                    remat_policy: Optional[str] = "full",
                    compression: bool = False,
                    accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    accum_steps > 1 runs gradient accumulation over the leading microbatch
    split (batch dims must divide), trading memory for batch size — the
    standard lever when the per-device batch does not fit.
    """

    def compute_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch, cfg, tp,
                                           remat_policy)

    def accum_grads(params, batch):
        # (B, ...) -> (accum, B/accum, ...): scan slices the leading axis
        # statically, so the batch stays sharded on its (new) second dim —
        # no dynamic-slice on a sharded axis.
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = compute_grads(params, mb)
            return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), zero), micro)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(state: TrainState, batch):
        if accum_steps > 1:
            loss, grads = accum_grads(state.params, batch)
        else:
            loss, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
        error = state.error
        if compression:
            grads, error = compress_with_feedback(grads, error)
        new_p, new_m, new_v, lr = adamw_update(
            state.params, grads, state.mu, state.nu, state.step, oc)
        new_state = TrainState(state.step + 1, new_p, new_m, new_v, error)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_local_accum_train_step(cfg, oc: OptimizerConfig, mesh, *,
                                tp: int = 1,
                                remat_policy: Optional[str] = "full",
                                accum_steps: int = 1,
                                int8_allreduce: bool = False,
                                zero1: bool = False,
                                batch_axes=("data",)):
    """shard_map train step: one gradient reduction per STEP, not per
    microbatch.  Data axes are manual (each device sees its batch shard
    and accumulates raw local grads); the model axis stays auto so GSPMD
    still lays out TP.  With int8_allreduce the single psum carries
    quantized payloads (4x fewer wire bytes; error stays below Adam's
    noise floor at these scales — parity tested in tests/test_train.py).

    zero1=True composes ZeRO-1 with the manual DP axes: gradients are
    reduce-scattered (psum_scatter) instead of all-reduced, Adam runs on
    the local 1/N shard against DP-sharded moments, and only the update
    is all-gathered — moment memory drops N x and wire bytes stay ~an
    all-reduce's.  Use ``make_zero1_local_state`` for the matching
    (flat, sharded) moment layout.
    """
    manual = tuple(a for a in batch_axes if a in mesh.shape)
    if zero1 and len(manual) != 1:
        raise NotImplementedError("zero1 local step: single DP axis for now")

    def body(params, mu, nu, step, batch):
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def one(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb, cfg, tp,
                                                  remat_policy)
            return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(one, (jnp.zeros(()), zero), micro)
        inv = 1.0 / accum_steps
        loss = loss * inv

        if zero1:
            axis = manual[0]
            n = mesh.shape[axis]
            loss = jax.lax.pmean(loss, axis)

            def rs(g):   # flat grad -> this device's 1/n shard (summed)
                flat = g.reshape(-1) * inv
                pad = (-flat.shape[0]) % n
                if pad:
                    flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
                return jax.lax.psum_scatter(
                    flat.reshape(n, -1), axis, scatter_dimension=0,
                    tiled=False).reshape(-1) / n

            gshard = jax.tree.map(rs, grads)
            gnorm = jnp.sqrt(jax.lax.psum(sum(
                jnp.sum(jnp.square(l)) for l in jax.tree.leaves(gshard)),
                axis))
            scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
            gshard = jax.tree.map(lambda l: l * scale, gshard)
            # Adam on the shard against DP-sharded flat moments
            from .optimizer import schedule
            lr = schedule(step, oc)
            t = step.astype(jnp.float32) + 1.0
            bc1 = 1.0 - oc.b1 ** t
            bc2 = 1.0 - oc.b2 ** t

            def upd(p, g, m, v):
                m = m[0]
                v = v[0]
                m2 = oc.b1 * m + (1 - oc.b1) * g
                v2 = oc.b2 * v + (1 - oc.b2) * g * g
                u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + oc.eps)
                pf = p.reshape(-1)
                pad = (-pf.shape[0]) % n
                if pad:
                    pf = jnp.concatenate([pf, jnp.zeros((pad,), pf.dtype)])
                my = jax.lax.axis_index(axis) * u.shape[0]
                pshard = jax.lax.dynamic_slice(pf, (my,), (u.shape[0],)) \
                    .astype(jnp.float32)
                decay = oc.weight_decay * pshard if p.ndim >= 2 else 0.0
                new_shard = pshard - lr * (u + decay)
                full = jax.lax.all_gather(new_shard, axis, tiled=True)
                newp = full[:p.size].reshape(p.shape).astype(p.dtype)
                return newp, m2[None], v2[None]

            out = jax.tree.map(upd, params, gshard, mu, nu)
            new_p = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_m = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_v = jax.tree.map(lambda o: o[2], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            return new_p, new_m, new_v, loss, gnorm, lr

        # the single per-step data reduction
        n = 1.0
        for a in manual:
            n *= mesh.shape[a]
        if int8_allreduce:
            from ..distributed.compression import compressed_allreduce

            def reduce_leaf(g):
                g = g * (inv / n)
                for a in manual:
                    g = compressed_allreduce(g, a, mesh.shape[a])
                return g
            grads = jax.tree.map(reduce_leaf, grads)
        else:
            def reduce_leaf(g):
                g = g * (inv / n)
                for a in manual:
                    g = jax.lax.psum(g, a)
                return g
            grads = jax.tree.map(reduce_leaf, grads)
        for a in manual:
            loss = jax.lax.pmean(loss, a)

        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
        new_p, new_m, new_v, lr = adamw_update(params, grads, mu, nu, step, oc)
        return new_p, new_m, new_v, loss, gnorm, lr

    # params replicated over the manual axes; batch sharded on its dim 0;
    # zero1 moments sharded over the DP axis (their leading dim)
    pspec = P()
    mspec = P(manual[0]) if zero1 else P()
    bspec = P(manual if len(manual) > 1 else manual[0])

    def train_step(state: TrainState, batch):
        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(pspec, mspec, mspec, pspec,
                      jax.tree.map(lambda _: bspec, batch)),
            out_specs=(pspec, mspec, mspec, pspec, pspec, pspec),
            axis_names=set(manual))
        new_p, new_m, new_v, loss, gnorm, lr = fn(
            state.params, state.mu, state.nu, state.step, batch)
        new_state = TrainState(state.step + 1, new_p, new_m, new_v,
                               state.error)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


def make_zero1_local_state(params, n_dp: int, tp: int = 1) -> TrainState:
    """TrainState whose moments are flat (n_dp, ceil(P/n_dp)) shards —
    the layout make_local_accum_train_step(zero1=True) consumes.  The
    inner dim is padded to a tp multiple so it can carry an auto "model"
    sharding on top (moments then shard over dp x tp)."""
    def flat(p):
        size = -(-p.size // (n_dp * tp)) * (n_dp * tp)
        return jnp.zeros((n_dp, size // n_dp), jnp.float32)

    return TrainState(jnp.zeros((), jnp.int32), params,
                      jax.tree.map(flat, params),
                      jax.tree.map(flat, params), None)


def abstract_zero1_local_state(abstract_params, n_dp: int, tp: int = 1):
    import functools
    return jax.eval_shape(functools.partial(
        make_zero1_local_state, n_dp=n_dp, tp=tp), abstract_params)
