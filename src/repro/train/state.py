"""Training state pytree."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    step: Any           # () int32
    params: Any         # f32 master weights
    mu: Any             # Adam first moment (ZeRO-1 sharded)
    nu: Any             # Adam second moment (ZeRO-1 sharded)
    error: Optional[Any] = None   # gradient-compression error feedback

    def tree_flatten(self):
        return (self.step, self.params, self.mu, self.nu, self.error), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_state(params, *, compression: bool = False) -> TrainState:
    zeros = lambda p: jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), p)
    err = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params) \
        if compression else None
    return TrainState(jnp.zeros((), jnp.int32), params, zeros(params),
                      zeros(params), err)


def abstract_state(abstract_params, *, compression: bool = False):
    return jax.eval_shape(
        lambda p: init_state(p, compression=compression), abstract_params)
