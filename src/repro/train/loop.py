"""Training driver loop: prefetch + train_step + FT coordinator.

The loop owns nothing model-specific: it is handed a jitted step, a
step-indexed batch source, and a checkpoint directory, and provides
checkpoint/restart (atomic + async), deterministic data replay,
straggler observation, and preemption-safe shutdown.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import io as ckpt_io
from ..ft.coordinator import Coordinator, FTConfig
from ..train.state import TrainState


def run(
    state: TrainState,
    train_step: Callable,
    batch_source: Callable[[int], dict],
    *,
    num_steps: int,
    ckpt_dir: Optional[str] = None,
    ft: Optional[FTConfig] = None,
    coordinator: Optional[Coordinator] = None,
    log_every: int = 10,
    log: Callable[[str], None] = print,
):
    # a WalkCorpus (repro.data.corpus) is a batch source: its
    # batch_at(step) is the pure step-indexed function this loop's
    # deterministic-replay contract requires
    batch_source = getattr(batch_source, "batch_at", batch_source)
    coord = coordinator or Coordinator(ft or FTConfig())
    start = int(state.step)
    history = []
    pending_ckpt = None

    step = start
    while step < num_steps:
        t0 = time.perf_counter()
        coord.maybe_fail(step)
        batch = batch_source(step)
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])        # blocks; also the step barrier
        dt = time.perf_counter() - t0
        action = coord.observe_step(dt)
        if action == "straggler-rebatch":
            # deterministic source -> same data; re-run the step shape
            log(f"[ft] straggler at step {step}; rebatching")
        history.append({"step": step, "loss": loss, "dt": dt, **{
            k: float(v) for k, v in metrics.items() if k != "loss"}})
        if step % log_every == 0:
            log(f"step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        step += 1
        if ckpt_dir and coord.should_checkpoint(step):
            if pending_ckpt is not None:
                pending_ckpt.join()
            pending_ckpt = ckpt_io.save(state, ckpt_dir, step, async_=True)
        if coord.should_stop():
            log(f"[ft] preempted; checkpointing at step {step} and exiting")
            if ckpt_dir:
                if pending_ckpt is not None:
                    pending_ckpt.join()
                ckpt_io.save(state, ckpt_dir, step)
            break
    if pending_ckpt is not None:
        pending_ckpt.join()
    return state, history


def resume_or_init(abstract_state, init_fn, ckpt_dir: Optional[str],
                   shardings=None):
    """Restart path: restore the latest checkpoint if one exists."""
    if ckpt_dir:
        step = ckpt_io.latest_step(ckpt_dir)
        if step is not None:
            state, _ = ckpt_io.restore(abstract_state, ckpt_dir, step,
                                       shardings=shardings)
            return state, step
    return init_fn(), 0
