"""AdamW with cosine schedule, global-norm clipping and ZeRO-1 layout.

Hand-rolled (no optax in this environment) and shaped so GSPMD turns the
moment updates into sharded ops: moments carry ZeRO-1 shardings (see
distributed.sharding.moment_shardings) and XLA inserts the
reduce-scatter / all-gather pair that ZeRO-1 implies.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step, oc: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = oc.lr * step / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps) / max(oc.decay_steps, 1), 0.0, 1.0)
    cos = oc.min_lr + 0.5 * (oc.lr - oc.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale)
                        .astype(l.dtype), grads), g


def adamw_update(params, grads, mu, nu, step, oc: OptimizerConfig):
    lr = schedule(step, oc)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - oc.b1 ** t
    bc2 = 1.0 - oc.b2 ** t

    def one(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = oc.b1 * m + (1 - oc.b1) * g
        v2 = oc.b2 * v + (1 - oc.b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + oc.eps)
        decay = oc.weight_decay * p if p.ndim >= 2 else 0.0
        return (p - lr * (upd + decay)).astype(p.dtype), m2, v2

    out = jax.tree.map(one, params, grads, mu, nu)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m, new_v, lr
