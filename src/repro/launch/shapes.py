"""Assigned input shapes and abstract input specs per (arch x shape).

  train_4k      seq 4096,    global_batch 256   -> train_step
  prefill_32k   seq 32768,   global_batch 32    -> prefill_step
  decode_32k    seq 32768,   global_batch 128   -> decode_step
  long_500k     seq 524288,  global_batch 1     -> decode_step
                (sub-quadratic archs only; full-attention archs skip)

All specs are ShapeDtypeStructs — weak-type-correct, shardable, zero
device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def cell_enabled(cfg, shape: str) -> bool:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False           # pure full attention: documented skip
    return True


def input_specs(cfg, shape: str):
    """Abstract batch for the given shape (token/frame/image stand-ins)."""
    sc = SHAPES[shape]
    b, s = sc.global_batch, sc.seq
    sd = jax.ShapeDtypeStruct
    if sc.kind in ("train", "prefill"):
        batch = {}
        if cfg.embed_stub:
            batch["frames"] = sd((b, s, cfg.d_model), F32)
        else:
            batch["tokens"] = sd((b, s), I32)
        if sc.kind == "train":
            batch["labels"] = sd((b, s), I32)
        if cfg.num_image_tokens:
            batch["image_embeds"] = sd((b, cfg.num_image_tokens, cfg.d_model), F32)
        return batch
    return {"token": sd((b,), I32), "pos": sd((b,), I32)}


def default_accum(cfg, shape: str, mesh) -> int:
    """Gradient-accumulation heuristic: keep the per-device microbatch's
    layer-boundary residuals under ~2 GB (hillclimbs tune this knob)."""
    sc = SHAPES[shape]
    if sc.kind != "train":
        return 1
    from ..distributed.sharding import _axsize, batch_axes
    ba = batch_axes(mesh, sc.global_batch)
    b_local = sc.global_batch // _axsize(mesh, ba)
    bytes_per_layer = sc.seq * cfg.d_model * 2
    budget = 2 << 30
    live = b_local * bytes_per_layer * max(cfg.num_layers, 1)
    accum = 1
    while live // accum > budget and accum < b_local:
        accum *= 2
    while sc.global_batch % accum or (sc.global_batch // accum) % max(
            _axsize(mesh, ba), 1):
        accum //= 2
    return max(accum, 1)
