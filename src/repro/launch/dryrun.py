import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first backend initialization.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract params/state/caches and their NamedShardings,
  3. jit-lowers the real step (train_step with optimizer / prefill_step /
     decode_step) against ShapeDtypeStruct inputs,
  4. .compile()s it — proving the distribution config is coherent,
  5. records memory_analysis, cost_analysis, and per-collective operand
     bytes parsed from the compiled HLO into a JSON artifact that the
     roofline harness (benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch nemotron-4-15b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out artifacts/
"""
import argparse
import json
import re
import sys
import time
import traceback


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of collective ops in a (per-device) HLO."""
    sizes = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
             "u16": 2}
    out = {}
    pat = re.compile(
        r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"[^\w]")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in sizes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * sizes[dt]
        out[op] = out.get(op, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def build_cell(cfg, shape: str, mesh, *, remat_policy="full",
               accum: int | None = None, fsdp: bool | None = None,
               step_mode: str = "gspmd"):
    """Returns (fn, args, in_shardings) ready to lower."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import BF16_STATE_ARCHS, FSDP_ARCHS
    from ..distributed import sharding as shd
    from ..models import transformer as tfm
    from ..serve.step import make_decode_step, make_prefill_step
    from ..train.optimizer import OptimizerConfig
    from ..train.state import abstract_state
    from ..train.step import make_train_step
    from .shapes import SHAPES, default_accum, input_specs

    sc = SHAPES[shape]
    tp = mesh.shape["model"]
    if fsdp is None:
        fsdp = cfg.name in FSDP_ARCHS
    aparams = tfm.abstract_params(cfg, tp)
    if cfg.name in BF16_STATE_ARCHS:
        aparams = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), aparams)
    pshard = shd.param_shardings(aparams, cfg, mesh, fsdp=fsdp)
    batch = input_specs(cfg, shape)
    bshard = shd.batch_shardings(mesh, batch)
    rep = NamedSharding(mesh, P())

    if sc.kind == "train":
        if accum is None:
            accum = default_accum(cfg, shape, mesh)
        astate = abstract_state(aparams)
        mshard = shd.moment_shardings(aparams, pshard, mesh)
        sshard = type(astate)(rep, pshard, mshard, mshard, None)
        if step_mode in ("local_accum", "local_accum_int8", "local_zero1"):
            from ..train.step import (abstract_zero1_local_state,
                                      make_local_accum_train_step)
            zero1 = step_mode == "local_zero1"
            step = make_local_accum_train_step(
                cfg, OptimizerConfig(), mesh, tp=tp,
                remat_policy=remat_policy, accum_steps=accum,
                int8_allreduce=step_mode.endswith("int8"),
                zero1=zero1,
                batch_axes=("data",) if zero1 else shd.dp_axes(mesh))
            if zero1:
                astate = abstract_zero1_local_state(aparams, mesh.shape["data"],
                                                    tp)
                mz = jax.tree.map(
                    lambda _: NamedSharding(mesh, P("data", "model")),
                    astate.mu)
                sshard = type(astate)(rep, pshard, mz, mz, None)
            else:
                # moments follow param TP sharding (no ZeRO) in plain mode
                sshard = type(astate)(rep, pshard, pshard, pshard, None)
        else:
            step = make_train_step(cfg, OptimizerConfig(), tp=tp,
                                   remat_policy=remat_policy,
                                   accum_steps=accum)
        fn = jax.jit(step, in_shardings=(sshard, bshard),
                     donate_argnums=(0,))
        return fn, (astate, batch), {"accum": accum, "fsdp": fsdp,
                                     "step_mode": step_mode}

    if sc.kind == "prefill":
        step = make_prefill_step(cfg, sc.seq, tp=tp)
        fn = jax.jit(step, in_shardings=(pshard, bshard))
        return fn, (aparams, batch), {"fsdp": fsdp}

    # decode
    acaches = tfm.abstract_caches(cfg, sc.global_batch, sc.seq, tp)
    cshard = shd.cache_shardings(acaches, cfg, mesh)
    step = make_decode_step(cfg, sc.seq, tp=tp)
    fn = jax.jit(step, in_shardings=(pshard, cshard, bshard),
                 donate_argnums=(1,))
    return fn, (aparams, acaches, batch), {"fsdp": fsdp}


def run_cell(arch: str, shape: str, multi_pod: bool, *, remat_policy="full",
             accum=None, fsdp=None, step_mode="gspmd", verbose=True,
             moe_overrides=None):
    import jax

    from ..configs import get_config
    from .mesh import make_production_mesh
    from .shapes import SHAPES, cell_enabled

    cfg = get_config(arch)
    if moe_overrides and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    if not cell_enabled(cfg, shape):
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full attention arch; long_500k documented skip"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args, meta = build_cell(cfg, shape, mesh,
                                    remat_policy=remat_policy, accum=accum,
                                    fsdp=fsdp, step_mode=step_mode)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    from .accounting import cell_cost
    from .hlo import collective_bytes_corrected
    coll_corrected = collective_bytes_corrected(hlo_text)
    sc = SHAPES[shape]
    acct = cell_cost(cfg, mesh.shape["model"], mesh.size, seq=sc.seq,
                     batch=sc.global_batch, kind=sc.kind,
                     accum=meta.get("accum", 1), remat=remat_policy,
                     fsdp=meta["fsdp"])
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "chips": mesh.size,
        "status": "ok",
        "meta": meta,
        "remat": remat_policy,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # raw HLO numbers (NB: while bodies counted once — see accounting.py)
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        # trip-corrected / analytic numbers the roofline uses
        "collective_bytes_corrected": coll_corrected,
        "analytic_flops_total": acct.flops_total,
        "analytic_bytes_per_device": acct.bytes_per_device,
        "model_flops": acct.model_flops,
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
        },
        "tokens": sc.seq * sc.global_batch if sc.kind != "decode"
        else sc.global_batch,
        "kind": sc.kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape}: compile ok "
              f"({rec['compile_s']}s)  flops/dev={rec['flops_per_device']:.3e} "
              f"temp={rec['memory']['temp_gb']:.2f}GB "
              f"coll={coll['total']/1e9:.3f}GB/dev")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="both")
    p.add_argument("--remat", default="full")
    p.add_argument("--accum", type=int, default=None)
    p.add_argument("--fsdp", type=int, default=None)
    p.add_argument("--out", default="artifacts")
    args = p.parse_args(argv)

    from ..configs import ARCHS
    from .shapes import SHAPES

    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    rec = run_cell(arch, shape, mp, remat_policy=args.remat,
                                   accum=args.accum,
                                   fsdp=None if args.fsdp is None
                                   else bool(args.fsdp))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "failed", "error": repr(e)}
                    failures += 1
                results.append(rec)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {failures} failed "
          f"/ {len(results)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
