"""Training driver: end-to-end on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU host, --reduced trains the smoke-scale config; the same
driver at production shapes is what the dry-run lowers.  Data comes from
the GVEL pipeline (--graph path/to/edgelist: random-walk corpus) or the
deterministic synthetic stream.
"""
from __future__ import annotations

import argparse
import functools


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi4-mini-3.8b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--remat", default=None)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--graph", default=None,
                   help="edgelist file -> GVEL random-walk corpus")
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import jax

    from ..configs import get_config, reduced_config
    from ..data.synthetic import synthetic_batch
    from ..ft.coordinator import Coordinator, FTConfig
    from ..models import init_params
    from ..train import loop as train_loop
    from ..train.optimizer import OptimizerConfig
    from ..train.state import abstract_state, init_state
    from ..train.step import make_train_step

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    oc = OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                         decay_steps=args.steps)

    params = init_params(jax.random.key(args.seed), cfg)
    state = init_state(params, compression=args.compress_grads)
    astate = jax.eval_shape(lambda s: s, state)

    if args.ckpt_dir:
        state, start = train_loop.resume_or_init(
            astate, lambda: state, args.ckpt_dir)
        if start:
            print(f"resumed from step {start}")

    if args.graph:
        from ..data.pipeline import graph_walk_source
        source = graph_walk_source(args.graph, cfg, args.batch, args.seq,
                                   engine="numpy")
    else:
        source = functools.partial(synthetic_batch, cfg, args.batch, args.seq)

    step_fn = jax.jit(make_train_step(cfg, oc, remat_policy=args.remat,
                                      compression=args.compress_grads,
                                      accum_steps=args.accum),
                      donate_argnums=(0,))
    coord = Coordinator(FTConfig(ckpt_every=args.ckpt_every,
                                 handle_signals=True))
    state, history = train_loop.run(
        state, step_fn, source, num_steps=args.steps,
        ckpt_dir=args.ckpt_dir, coordinator=coord)
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"done: loss {first:.4f} -> {last:.4f} over {len(history)} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
