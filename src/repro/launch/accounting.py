"""Analytic FLOP/byte accounting for the roofline terms.

Why analytic: XLA's ``cost_analysis()`` counts a while-loop body ONCE,
not times its trip count (verified on this toolchain: an 8-step scanned
matmul reports ~1x body flops).  Our steps are scans over layers,
microbatches, and attention/ssm chunks, so raw HLO flops/bytes undercount
by the trip product.  We therefore account flops and HBM traffic from
first principles — the same model-FLOPs bookkeeping production MFU
reporting uses — and keep the raw HLO numbers in the artifacts for
transparency.  Collective bytes ARE taken from the HLO, corrected by
parsed while-loop trip counts (see repro.launch.hlo).

Conventions:
  * 2 flops per MAC; backward = 2x forward; remat('full'/'nothing')
    recomputes forward once -> 4x forward total for matmuls.
  * causal attention scores+values: 4*S^2*H*hd per sequence halved for
    causality; sliding window replaces one S by min(S, W).
  * padded Q heads and MoE capacity slack are counted as real work
    (they burn real MXU cycles) — the useful-ratio exposes them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class CellCost:
    flops_total: float          # all chips, one step
    bytes_per_device: float     # HBM traffic per chip, one step
    model_flops: float          # 6*N*D / 2*N_active*D (spec definition)


def _layer_matmul_params(cfg, tp: int) -> Dict[str, float]:
    """Matmul params per layer kind, with TP head padding counted."""
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.head_dim or 0
    hp = cfg.padded_heads(tp)
    kv = cfg.num_kv_heads
    out = {}
    attn = d * hp * hd + 2 * d * kv * hd + hp * hd * d
    mlp = (3 if cfg.mlp in ("swiglu", "geglu") else 2) * d * f
    if cfg.moe:
        m = cfg.moe
        # dense-dispatch MoE: every expert runs its capacity slice
        cap_work = m.top_k * m.capacity_factor     # tokens of expert work/tok
        out["attn"] = attn + d * m.num_experts + cap_work * 3 * d * m.d_ff
    else:
        out["attn"] = attn + mlp
    out["xattn"] = hp * hd * d * 2 + 2 * d * kv * hd + mlp
    if cfg.ssm:
        di, st, dr = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.dt_rank
        out["mamba"] = (2 * d * di + di * (dr + 2 * st) + dr * di + di * d
                        + di * st)   # scan ~ di*st MACs/token
    if cfg.lru_width:
        w = cfg.lru_width
        out["rglru"] = 2 * d * w + 2 * w * w + w * d + mlp
    return out


def _attn_flops_per_seq(cfg, tp: int, s: int, kind: str) -> float:
    """Score+value flops for ONE sequence in ONE attention layer (fwd)."""
    if not cfg.num_heads:
        return 0.0
    hp = cfg.padded_heads(tp)
    hd = cfg.head_dim
    if kind == "decode":
        s_kv = min(s, cfg.window or s)
        return 4.0 * s_kv * hp * hd               # one query token
    s_kv = min(s, cfg.window or s)
    if cfg.window and cfg.window < s:
        return 4.0 * s * s_kv * hp * hd           # banded
    return 4.0 * s * s * hp * hd * 0.5            # causal half


def step_flops(cfg, tp: int, *, seq: int, batch: int, kind: str,
               remat: str = "full") -> float:
    """Total flops across all chips for one step."""
    pat = cfg.pattern_layers
    per_kind = _layer_matmul_params(cfg, tp)
    tokens = batch * (1 if kind == "decode" else seq)

    matmul = sum(per_kind.get(k, per_kind.get("attn", 0.0)) for k in pat)
    fwd = 2.0 * matmul * tokens
    fwd += 2.0 * cfg.d_model * cfg.vocab_size * (
        batch if kind in ("decode", "prefill") else tokens)   # logits
    n_attn = sum(1 for k in pat if k == "attn")
    n_x = sum(1 for k in pat if k == "xattn")
    fwd += n_attn * batch * _attn_flops_per_seq(cfg, tp, seq, kind)
    if n_x:
        q = 1 if kind == "decode" else seq
        fwd += n_x * batch * 4.0 * q * cfg.num_image_tokens \
            * cfg.padded_heads(tp) * cfg.head_dim

    if kind == "train":
        factor = 3.0 if remat in (None, "everything") else 4.0
        return fwd * factor
    return fwd


def step_bytes_per_device(cfg, tp: int, mesh_size: int, *, seq: int,
                          batch: int, kind: str, accum: int = 1,
                          fsdp: bool = False, state_bytes: int = 4) -> float:
    """Estimated HBM traffic per chip for one step.

    train:  params read per microbatch (fwd + bwd + remat recompute)
            + optimizer update (read p,g,mu,nu; write p,mu,nu)
            + layer-boundary residuals written+read (+logits)
    decode: params once + cache read/modify/write
    prefill: params once + residual/caches written
    """
    p_total = cfg.param_count()
    p_shards = mesh_size if fsdp else tp
    p_dev = p_total * 2.0 / p_shards                 # bf16 compute copies
    d = cfg.d_model
    dp = max(mesh_size // tp, 1)
    b_dev = max(batch // dp, 1)

    if kind == "train":
        b_micro = max(b_dev // accum, 1)
        resid = cfg.num_layers * b_micro * seq * d * 2.0 / tp
        logits = b_micro * seq * cfg.vocab_size * 2.0 / tp
        traffic = accum * (3.0 * p_dev + 2.0 * resid + 2.0 * logits)
        traffic += 7.0 * p_total * state_bytes / p_shards   # adam update
        return traffic

    if kind == "prefill":
        kv = max(cfg.num_kv_heads, 1) * (cfg.head_dim or 0)
        cache = cfg.num_layers * b_dev * min(seq, cfg.window or seq) \
            * kv * 2.0 / tp
        resid = cfg.num_layers * b_dev * seq * d * 2.0 / tp
        return p_dev + cache + 2.0 * resid

    # decode: every live weight + the whole cache crosses HBM once
    kv = max(cfg.num_kv_heads, 1) * (cfg.head_dim or 0)
    cache = cfg.num_layers * b_dev * min(seq, cfg.window or seq) * kv * 4.0 / tp
    if cfg.ssm:
        cache += cfg.num_layers * b_dev * cfg.d_inner \
            * (cfg.ssm.d_state + cfg.ssm.d_conv) * 4.0 / tp
    active_dev = cfg.active_param_count() * 2.0 / p_shards
    return active_dev + cache


def model_flops(cfg, *, seq: int, batch: int, kind: str) -> float:
    """Spec definition: 6*N*D train / 2*N_active*D inference."""
    if kind == "train":
        return 6.0 * cfg.active_param_count() * batch * seq
    if kind == "prefill":
        return 2.0 * cfg.active_param_count() * batch * seq
    return 2.0 * cfg.active_param_count() * batch


def cell_cost(cfg, tp: int, mesh_size: int, *, seq: int, batch: int,
              kind: str, accum: int = 1, remat: str = "full",
              fsdp: bool = False) -> CellCost:
    return CellCost(
        flops_total=step_flops(cfg, tp, seq=seq, batch=batch, kind=kind,
                               remat=remat),
        bytes_per_device=step_bytes_per_device(
            cfg, tp, mesh_size, seq=seq, batch=batch, kind=kind,
            accum=accum, fsdp=fsdp),
        model_flops=model_flops(cfg, seq=seq, batch=batch, kind=kind),
    )
