"""Production mesh construction.

Single pod: (16, 16)      axes (data, model)   = 256 chips
Multi pod:  (2, 16, 16)   axes (pod, data, model) = 512 chips

A function, not a module constant: importing this module never touches
jax device state (device counts are locked at first backend init).
"""
from __future__ import annotations

import jax

from ..core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = n // model
    return compat.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip, one direction)
