"""HLO-text analysis: collective bytes with while-loop trip correction.

XLA reports each computation once, but scanned programs execute while
bodies `trip` times.  jax lowers scans to whiles whose induction bound is
a constant — either compared directly in the condition computation or
threaded through the init tuple.  We recover it from both places, build
the computation call graph (ENTRY -> while bodies / called computations),
multiply each computation's collective bytes by the product of enclosing
trip counts, and sum.  This makes the collective roofline term reflect
actual execution counts for the schedules we emit (layer scans,
accumulation scans, chunked attention/ssm scans).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CONSTDEF = re.compile(r"%([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_ANYCONST = re.compile(r"constant\((\d+)\)")
_OPREF = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """-> ({name: lines}, entry_name)."""
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and (
                    s.startswith("%") or s.startswith("ENTRY")):
                name = s.split("(", 1)[0].replace("ENTRY", "").strip()
                name = name.lstrip("%").strip()
                comps[name] = []
                cur = name
                if s.startswith("ENTRY"):
                    entry = name
            continue
        if s == "}":
            cur = None
        else:
            comps[cur].append(s)
    return comps, entry


def _trip_for_while(line: str, caller_lines: List[str],
                    comps: Dict[str, List[str]]) -> Tuple[int, str]:
    """(trip count, body computation name) for one while instruction."""
    cond = re.search(r"condition=%?([\w\.\-]+)", line)
    body = re.search(r"body=%?([\w\.\-]+)", line)
    body_name = body.group(1) if body else ""
    candidates = []
    # (1) constant directly in the condition computation
    if cond and cond.group(1) in comps:
        for ln in comps[cond.group(1)]:
            candidates += [int(m.group(1)) for m in _ANYCONST.finditer(ln)]
    # (2) constants threaded through the init tuple
    m = re.search(r"while\(%?([\w\.\-]+)\)", line)
    if m:
        init = m.group(1)
        consts = dict()
        for ln in caller_lines:
            cm = _CONSTDEF.search(ln)
            if cm:
                consts[cm.group(1)] = int(cm.group(2))
        for ln in caller_lines:
            if ln.split("=", 1)[0].strip().lstrip("%").split(" ")[0] == init:
                for om in _OPREF.finditer(ln.split("tuple(", 1)[-1]):
                    if om.group(1) in consts:
                        candidates.append(consts[om.group(1)])
                break
    return (max(candidates) if candidates else 1), body_name


def collective_bytes_corrected(hlo: str) -> Dict[str, float]:
    comps, entry = split_computations(hlo)
    if not entry:
        return {"total": 0.0}

    raw: Dict[str, Dict[str, int]] = {}
    for cname, lines in comps.items():
        per: Dict[str, int] = {}
        for ln in lines:
            for op in COLLECTIVES:
                if f" {op}(" in ln or f" {op}-start(" in ln:
                    # result shape(s) sit between '=' and the op mnemonic
                    rhs = ln.split("=", 1)[1] if "=" in ln else ln
                    per[op] = per.get(op, 0) + _shape_bytes(
                        rhs[:rhs.find(op)])
                    break
        raw[cname] = per

    mult: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    visited = set()
    while stack:
        cname = stack.pop()
        if cname in visited or cname not in comps:
            continue
        visited.add(cname)
        m = mult.get(cname, 1.0)
        for ln in comps[cname]:
            if " while(" in ln or ln.startswith("while("):
                trips, body = _trip_for_while(ln, comps[cname], comps)
                if body in comps:
                    nm = m * trips
                    if nm > mult.get(body, 0.0):
                        mult[body] = nm
                        visited.discard(body)
                    stack.append(body)
                cond = re.search(r"condition=%?([\w\.\-]+)", ln)
                if cond and cond.group(1) in comps:
                    mult.setdefault(cond.group(1), m)
                continue
            for cm in re.finditer(r"(?:calls=|to_apply=|condition=|body=)"
                                  r"%?([\w\.\-]+)", ln):
                key = cm.group(1)
                if key in comps and key not in visited:
                    if m > mult.get(key, 0.0):
                        mult[key] = m
                    stack.append(key)
            bm = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if bm:
                for b in bm.group(1).split(","):
                    key = b.strip().lstrip("%")
                    if key in comps:
                        if m > mult.get(key, 0.0):
                            mult[key] = m
                        stack.append(key)

    out: Dict[str, float] = {}
    for cname, per in raw.items():
        m = mult.get(cname, 1.0 if any(per.values()) else 0.0)
        for op, b in per.items():
            out[op] = out.get(op, 0.0) + b * m
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out
