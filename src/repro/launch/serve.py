"""Serving driver: batched decode with the slot-based engine.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --reduced --requests 16 --max-new 32
"""
from __future__ import annotations

import argparse


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi4-mini-3.8b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import time

    import jax
    import numpy as np

    from ..configs import get_config, reduced_config
    from ..models import init_params
    from ..serve.engine import Request, ServeEngine

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.embed_stub:
        print("audio arch: decode consumes code ids (frontend stub)")
    params = init_params(jax.random.key(args.seed), cfg)
    eng = ServeEngine(cfg, params, batch=args.batch, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(i, prompt, args.max_new))

    t0 = time.perf_counter()
    ticks = eng.run()
    dt = time.perf_counter() - t0
    done = args.requests
    toks = args.requests * args.max_new
    print(f"served {done} requests / {toks} tokens in {ticks} ticks, "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
