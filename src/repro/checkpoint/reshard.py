"""Elastic resharding: restore a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (full logical arrays), so scaling from N to
M devices is: build the new mesh, derive shardings for it, restore.  This
module packages that and validates shard layouts — the path a 1000-node
job takes when it loses a pod and restarts at reduced width.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from ..distributed import sharding as shd
from . import io


def reshard_restore(abstract_state, directory: str, cfg, mesh: Mesh, *,
                    fsdp: bool, step: Optional[int] = None):
    """Restore train state with shardings derived for ``mesh``."""
    pspecs = shd.param_shardings(abstract_state.params, cfg, mesh, fsdp=fsdp)
    mspecs = shd.moment_shardings(abstract_state.params, pspecs, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..train.state import TrainState
    sh = TrainState(
        step=NamedSharding(mesh, P()),
        params=pspecs, mu=mspecs, nu=mspecs,
        error=None if abstract_state.error is None else mspecs)
    state, at_step = io.restore(abstract_state, directory, step, shardings=sh)
    return state, at_step
