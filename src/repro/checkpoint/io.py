"""Sharded checkpointing: async, atomic, mesh-agnostic.

Layout:  <dir>/step_<n>/
           manifest.json        tree structure, shapes, dtypes, step
           <flat.key.path>.npy  one file per leaf

Leaves are gathered to host (process-local addressable shards in a
multi-host deployment would each write their own slice files; the
manifest format carries a `shards` field for that — single-process here
writes full arrays).  Saves go to a tmp dir + atomic rename, so a
preemption mid-save never corrupts the latest checkpoint.  Restores are
mesh-agnostic: leaves are device_put with whatever shardings the *new*
mesh dictates (elastic resharding is therefore free — see reshard.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "."


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    pairs = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    for path, leaf in pairs:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = leaf
    return flat


def save(tree, directory: str, step: int, *, async_: bool = False):
    """Write a checkpoint; returns a join() handle when async_."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()
            if v is not None}
    treedef = jax.tree_util.tree_structure(tree)

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "treedef": str(treedef),
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in flat.items()},
                    "shards": 1}
        for k, v in flat.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(abstract_tree, directory: str, step: Optional[int] = None,
            shardings=None):
    """Load into the structure of ``abstract_tree``; None leaves stay None.

    ``shardings`` (same structure) device_puts each leaf with its target
    sharding — pass the *new* mesh's shardings to reshard elastically.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    flat_abs = _flatten(abstract_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for k, leaf in flat_abs.items():
        if leaf is None:
            loaded[k] = None
            continue
        arr = np.load(os.path.join(d, k + ".npy"))
        sh = flat_sh.get(k)
        loaded[k] = jax.device_put(arr, sh) if sh is not None else arr
    # rebuild in original order
    paths, treedef = jax.tree_util.tree_flatten_with_path(
        abstract_tree, is_leaf=lambda x: x is None)
    keys = [_SEP.join(str(getattr(kk, "key", getattr(kk, "idx", kk)))
                      for kk in path) for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys]), step
