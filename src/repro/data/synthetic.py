"""Deterministic synthetic LM batches (step-indexed for restart replay)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(cfg, batch: int, seq: int, step: int):
    """Pure function of (config, step): restart at step n replays exactly."""
    key = jax.random.fold_in(jax.random.key(1234), step)
    out = {}
    if cfg.embed_stub:
        k1, k2 = jax.random.split(key)
        out["frames"] = jax.random.normal(k1, (batch, seq, cfg.d_model),
                                          jnp.float32)
        out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)
        return out
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab_size)
    out["tokens"] = toks[:, :-1]
    out["labels"] = toks[:, 1:]
    if cfg.num_image_tokens:
        out["image_embeds"] = jax.random.normal(
            k2, (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return out
