"""Streaming walk-corpus: a snapshot-backed GraphSource -> step-indexed
LM batch pipeline.

This is the bridge the ROADMAP's end-to-end scenario needs: the fast
loader (:func:`repro.core.source.open_graph`, or a hot
:class:`~repro.core.cache.SourceCache` handle) on one side, the
training/serving substrate on the other.

    corpus = WalkCorpus(open_graph("web.gvel"), CorpusConfig(batch=8))
    with corpus.batches(start_step=0) as stream:
        for step, batch in stream:
            ...

Contract (tests/test_corpus.py, docs/serving.md):

* **Step-indexed and pure**: ``batch_at(step)`` is a pure function of
  ``(CSR, cfg, step)`` — same snapshot + same config => bitwise-equal
  batch, forever.  ``batches(start_step=n)`` therefore resumes a
  killed stream mid-corpus with a bitwise-identical continuation; no
  replay, no drift.  The cursor (``save_cursor``/``load_cursor``) is
  just the next step index, written atomically so a preemption
  mid-save never corrupts it.
* **Prefetch-threaded, double-buffered**: ``batches()`` builds walk
  batch ``n+1`` (and stages it host->device) in a background thread
  while the consumer runs step ``n`` — the serving-side mirror of the
  loader's prefetch/arena discipline, reusing
  :class:`repro.data.pipeline.Prefetcher`.
* **Degradable**: per-walk keying in :mod:`repro.data.walks` means a
  batch-size cut keeps the surviving walks bitwise identical
  (``batch_at(step, batch=b)`` rows are a prefix of the full batch) —
  the straggler-degrade path in :mod:`repro.serve.runtime` leans on
  this.

The CSR is resolved once through the source's memo (``source.csr()``)
and pinned on the corpus as device arrays, so after the first batch no
host->device transfer of the graph ever repeats.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .pipeline import Prefetcher
from .walks import I32, random_walks


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    """Walk-corpus geometry and keying.  Every field participates in
    the determinism contract: same config + same snapshot => same
    batch stream."""

    batch: int = 8                    # walks (rows) per batch
    seq: int = 32                     # tokens per row (walk length - 1)
    vocab_size: int = 256             # token ids = vertex ids mod vocab
    seed: int = 99                    # corpus-level PRNG root
    lookahead: int = 2                # prefetch queue depth
    method: Optional[str] = None      # CSR build method (source default)
    rho: int = 4


class WalkCorpus:
    """A deterministic, prefetch-threaded walk-batch stream over one
    :class:`~repro.core.source.GraphSource`."""

    def __init__(self, source, cfg: CorpusConfig = CorpusConfig()):
        self.source = source
        self.cfg = cfg
        self._offsets = None          # device-pinned CSR, built lazily
        self._targets = None
        self._num_vertices = 0

    # -- graph resolution ----------------------------------------------------

    def _csr_arrays(self):
        """The source's CSR as device int32 arrays, pinned on the
        corpus (one transfer per corpus, not per batch)."""
        if self._offsets is None:
            csr = self.source.csr(method=self.cfg.method, rho=self.cfg.rho)
            self._offsets = jnp.asarray(np.asarray(csr.offsets), I32)
            self._targets = jnp.asarray(np.asarray(csr.targets), I32)
            self._num_vertices = int(csr.num_vertices)
        return self._offsets, self._targets, self._num_vertices

    # -- batches -------------------------------------------------------------

    def batch_at(self, step: int, *, batch: Optional[int] = None) -> dict:
        """The walk-LM batch for ``step`` — pure and memoless.  A
        smaller ``batch`` override returns the bitwise prefix of the
        full batch's rows (per-walk keying; see ``data/walks.py``)."""
        offsets, targets, v = self._csr_arrays()
        cfg = self.cfg
        b = cfg.batch if batch is None else int(batch)
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        walks = random_walks(offsets, targets, key, num_walks=b,
                             length=cfg.seq + 1, num_vertices=v)
        toks = (walks % cfg.vocab_size).astype(I32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, start_step: int = 0, *, sharding=None) -> "BatchStream":
        """Iterate ``(step, batch)`` from ``start_step`` with a
        lookahead thread building (and, with ``sharding``, staging
        host->device) the next batch while the caller consumes the
        current one.  Close the stream (or use ``with``) to stop the
        thread."""
        return BatchStream(self, start_step, sharding=sharding)


class BatchStream:
    """Iterator over ``(step, batch)`` backed by a prefetch thread.
    ``next_step`` is the resume cursor: checkpoint it after consuming a
    batch and ``batches(start_step=next_step)`` continues the stream
    bitwise-identically."""

    def __init__(self, corpus: WalkCorpus, start_step: int, *, sharding=None):
        corpus._csr_arrays()          # resolve the CSR before threading
        self.next_step = int(start_step)
        self._pf = Prefetcher(corpus.batch_at, start_step=self.next_step,
                              lookahead=corpus.cfg.lookahead,
                              sharding=sharding)

    def __iter__(self):
        return self

    def __next__(self):
        step = self.next_step
        batch = self._pf.get(expect_step=step)
        self.next_step = step + 1
        return step, batch

    def close(self):
        self._pf.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# -- resume cursor -----------------------------------------------------------

def save_cursor(path: str, step: int) -> None:
    """Durably persist the next step index (tmp + fsync + rename +
    directory fsync, same discipline as checkpoint/io.py: a preemption
    mid-write leaves the previous cursor intact).  The directory fsync
    is what makes the *rename* itself survive a host crash — without
    it the journal may replay the directory to the pre-rename state
    and lose the cursor the resume contract depends on."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"step": int(step)}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def load_cursor(path: str) -> Optional[int]:
    """The persisted next step index, or ``None`` when no cursor
    exists yet (cold start)."""
    try:
        with open(path) as f:
            return int(json.load(f)["step"])
    except FileNotFoundError:
        return None
