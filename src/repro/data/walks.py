"""Random-walk corpus over a GVEL-loaded CSR -> LM token sequences.

The end-to-end integration of the paper's technique with the training
substrate: text edgelist --GVEL--> CSR --vectorized walker--> token
batches.  Each walk step is two gathers (offsets, then a uniformly
sampled neighbor); dead ends (out-degree 0) self-loop, so a walk never
steps outside its current vertex's adjacency.  Vertex ids map to tokens
modulo the model vocab.

Determinism contract (tests/test_walks.py):

* Every walk is keyed **per walk id**, not per batch shape: walk ``i``
  derives its stream from ``fold_in(key, walk_offset + i)``.  The same
  ``key`` therefore yields bitwise-identical walks across repeated
  calls *and* across batch splits —
  ``random_walks(key, num_walks=8)`` equals the concatenation of
  ``num_walks=4, walk_offset=0`` and ``num_walks=4, walk_offset=4``.
  (This is what lets the serving runtime degrade batch size under
  straggler pressure without perturbing the surviving walks.)
* Pure function of ``(csr, key/step)`` — deterministic restart; the
  walk corpus (:mod:`repro.data.corpus`) builds its step-indexed
  resume contract on this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32

# fold_in tag for the start-vertex draw; step draws use tags [0, length),
# so any walk length below 2**31 - 1 cannot collide with it
_START_TAG = 0x7FFFFFFF


def walk_keys(key, ids):
    """Per-walk base keys: ``fold_in(key, id)`` for each walk id."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.asarray(ids, I32))


@functools.partial(jax.jit, static_argnames=("length",))
def walk_from(offsets, targets, keys, starts, *, length: int):
    """Walks of ``length`` vertices from explicit ``starts``.

    ``keys`` are per-walk base keys (:func:`walk_keys`); ``starts`` is a
    matching ``(n,)`` int32 vector.  Returns ``(n, length)`` int32
    sequences whose first column is ``starts``.  Each step samples a
    neighbor uniformly from the current vertex's adjacency; a dead end
    (out-degree 0) self-loops.
    """
    starts = jnp.asarray(starts, I32)

    def step(cur, s):
        lo = offsets[cur]
        deg = offsets[cur + 1] - lo
        ks = jax.vmap(lambda k: jax.random.fold_in(k, s))(keys)
        r = jax.vmap(
            lambda k, d: jax.random.randint(k, (), 0, jnp.maximum(d, 1), I32)
        )(ks, deg)
        if targets.shape[0]:
            nxt = targets[jnp.clip(lo + r, 0, targets.shape[0] - 1)]
            nxt = jnp.where(deg > 0, nxt, cur)
        else:                       # edgeless graph: every vertex self-loops
            nxt = cur
        return nxt, cur

    _, seq = jax.lax.scan(step, starts, jnp.arange(length, dtype=I32))
    return seq.T                                   # (n, length)


@functools.partial(jax.jit, static_argnames=("num_walks", "length"))
def random_walks(offsets, targets, key, *, num_walks: int, length: int,
                 num_vertices, walk_offset=0):
    """-> (num_walks, length) int32 vertex sequences with random starts.

    Walk ``i`` is a pure function of ``fold_in(key, walk_offset + i)``
    and the CSR — see the batch-split invariance note in the module
    docstring.  ``num_vertices`` and ``walk_offset`` trace (a serving
    runtime cycling graphs and request ids never recompiles; only new
    batch geometry does).
    """
    ids = jnp.asarray(walk_offset, I32) + jnp.arange(num_walks, dtype=I32)
    keys = walk_keys(key, ids)
    starts = jax.vmap(
        lambda k: jax.random.randint(
            jax.random.fold_in(k, _START_TAG), (), 0, num_vertices, I32)
    )(keys)
    return walk_from(offsets, targets, keys, starts, length=length)


def walk_batch(csr, cfg, batch: int, seq: int, step: int, *, seed: int = 99,
               walk_offset: int = 0):
    """Training batch from walks: tokens = vertex ids mod vocab."""
    offsets = jnp.asarray(np.asarray(csr.offsets), I32)
    targets = jnp.asarray(np.asarray(csr.targets), I32)
    key = jax.random.fold_in(jax.random.key(seed), step)
    walks = random_walks(offsets, targets, key, num_walks=batch,
                         length=seq + 1, num_vertices=csr.num_vertices,
                         walk_offset=walk_offset)
    toks = (walks % cfg.vocab_size).astype(I32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
