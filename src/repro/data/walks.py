"""Random-walk corpus over a GVEL-loaded CSR -> LM token sequences.

The end-to-end integration of the paper's technique with the training
substrate: text edgelist --GVEL--> CSR --vectorized walker--> token
batches.  Each walk step is two gathers (offsets, then a uniformly
sampled neighbor); dead ends teleport.  Vertex ids map to tokens modulo
the model vocab.  Pure function of (csr, step) — deterministic restart.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@functools.partial(jax.jit, static_argnames=("num_walks", "length", "num_vertices"))
def random_walks(offsets, targets, key, *, num_walks: int, length: int,
                 num_vertices: int):
    """-> (num_walks, length) int32 vertex sequences."""
    k0, key = jax.random.split(key)
    cur = jax.random.randint(k0, (num_walks,), 0, num_vertices, I32)

    def step(carry, k):
        cur = carry
        lo = offsets[cur]
        deg = offsets[cur + 1] - lo
        kk, kt = jax.random.split(k)
        r = jax.random.randint(kk, (num_walks,), 0, jnp.maximum(deg, 1), I32)
        nxt = targets[jnp.clip(lo + r, 0, targets.shape[0] - 1)]
        tele = jax.random.randint(kt, (num_walks,), 0, num_vertices, I32)
        nxt = jnp.where(deg > 0, nxt, tele)
        return nxt, cur

    keys = jax.random.split(key, length)
    _, seq = jax.lax.scan(step, cur, keys)
    return seq.T                                   # (num_walks, length)


def walk_batch(csr, cfg, batch: int, seq: int, step: int):
    """Training batch from walks: tokens = vertex ids mod vocab."""
    offsets = jnp.asarray(np.asarray(csr.offsets), I32)
    targets = jnp.asarray(np.asarray(csr.targets), I32)
    key = jax.random.fold_in(jax.random.key(99), step)
    walks = random_walks(offsets, targets, key, num_walks=batch,
                         length=seq + 1, num_vertices=csr.num_vertices)
    toks = (walks % cfg.vocab_size).astype(I32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
