"""Async double-buffered batch pipeline.

The host-side analogue of GVEL's madvise read-ahead: while the device
runs step n, a background thread builds (and device_puts) batch n+1, so
input never serializes with compute.  Step-indexed sources keep restart
deterministic.

``graph_walk_source`` is the bridge from the loading front door
(:func:`repro.core.source.open_graph`) into this pipeline: graph file
-> ``GraphSource`` -> CSR through a named engine -> step-indexed
walk-batch source for :class:`Prefetcher`.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax


def graph_walk_source(path: str, cfg, batch: int, seq: int, *,
                      engine: str = "device", seed: int = 99,
                      **load_kw) -> Callable[[int], dict]:
    """Load a graph through ``open_graph(path)`` and return a
    deterministic step-indexed source of random-walk LM batches
    (a :class:`repro.data.corpus.WalkCorpus` bound to the handle).

    The returned callable feeds :class:`Prefetcher` directly, completing
    the streamed path: file -> packed device edges -> CSR -> walk batches,
    with the loader and the batch pipeline double-buffering at both ends.
    """
    from ..core.source import open_graph
    from .corpus import CorpusConfig, WalkCorpus

    method = load_kw.pop("method", "staged")
    rho = load_kw.pop("rho", 4)
    src = open_graph(path, engine=engine, **load_kw)
    corpus = WalkCorpus(src, CorpusConfig(
        batch=batch, seq=seq, vocab_size=cfg.vocab_size, seed=seed,
        method=method, rho=rho))
    return corpus.batch_at


class _Failure:
    """Sentinel carrying a worker exception through the batch queue —
    how a dead lookahead thread reaches its consumer instead of
    leaving it blocked on an empty queue forever."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher:
    """Wraps source(step)->batch with a lookahead thread.

    Failure semantics: an exception in the worker (a corrupt graph, an
    injected fault) is queued behind any batches already built and
    re-raised from :meth:`get` — never swallowed.  ``get`` also bounds
    its wait by the watchdog budget (``timeout`` here, else
    ``faults.WATCHDOG_S``), raising :class:`~repro.core.faults.
    StageTimeout` when the source is stuck rather than hanging the
    training/serving loop.
    """

    def __init__(self, source: Callable[[int], dict], start_step: int = 0,
                 lookahead: int = 2, sharding=None,
                 timeout: Optional[float] = None):
        self.source = source
        self.sharding = sharding
        self._timeout = timeout
        self._q: queue.Queue = queue.Queue(maxsize=lookahead)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._next
        try:
            while not self._stop.is_set():
                batch = self.source(step)
                if self.sharding is not None:
                    batch = jax.device_put(batch, self.sharding)
                try:
                    self._q.put((step, batch), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue
        except BaseException as exc:   # propagate through the queue
            while not self._stop.is_set():
                try:
                    self._q.put((step, _Failure(exc)), timeout=0.2)
                    return
                except queue.Full:
                    continue

    def get(self, expect_step: Optional[int] = None):
        from ..core import faults

        budget = faults.WATCHDOG_S if self._timeout is None else self._timeout
        try:
            step, batch = self._q.get(timeout=budget)
        except queue.Empty:
            raise faults.StageTimeout(
                f"batch pipeline: no batch produced within {budget:.1f}s "
                f"(REPRO_WATCHDOG_S); the source is stuck") from None
        if isinstance(batch, _Failure):
            self._stop.set()
            raise batch.exc
        if expect_step is not None and step != expect_step:
            raise RuntimeError(f"pipeline desync: got {step}, want {expect_step}")
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
