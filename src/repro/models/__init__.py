"""Model zoo: the 10 assigned architectures as one composable stack."""
from .config import ModelConfig, MoEConfig, SSMConfig
from .transformer import (abstract_caches, abstract_params, forward_decode,
                          forward_prefill, forward_train, init_caches,
                          init_params, loss_fn)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig",
    "init_params", "abstract_params", "forward_train", "loss_fn",
    "init_caches", "abstract_caches", "forward_prefill", "forward_decode",
]
