"""Mamba-1 (S6) selective-state-space layer.

Training/prefill runs the recurrence as a *chunked* associative scan:
an outer lax.scan over sequence chunks carries the (B, d_inner, d_state)
state while an inner associative_scan parallelizes within the chunk —
live memory is O(chunk * d_inner * d_state) instead of O(S * ...), which
is what lets the 500k-token shapes compile.  Decode is the O(1) single
step.  d_inner is sharded over "model" (all state tensors inherit it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import BF16, F32


def init_mamba_params(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    di = cfg.d_inner
    ks = jax.random.split(key, 7)
    si = 1.0 / jnp.sqrt(d)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), F32) * si,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, di), F32) * 0.1,
        "conv_b": jnp.zeros((di,), F32),
        "x_proj": jax.random.normal(ks[2], (di, s.dt_rank + 2 * s.d_state), F32)
        / jnp.sqrt(di),
        "dt_proj": jax.random.normal(ks[3], (s.dt_rank, di), F32)
        / jnp.sqrt(s.dt_rank),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, F32))),  # softplus^-1
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=F32), (di, s.d_state)) + 0.0),
        "D": jnp.ones((di,), F32),
        "out_proj": jax.random.normal(ks[4], (di, d), F32) / jnp.sqrt(di),
    }


def _ssm_inputs(p, u, cfg):
    """u: (B, L, di) post-conv activations -> (dA, dBu, C) chunk tensors."""
    s = cfg.ssm
    bc = jnp.einsum("bld,dk->blk", u, p["x_proj"].astype(BF16)).astype(F32)
    dt, Bm, Cm = jnp.split(bc, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt.astype(BF16), p["dt_proj"].astype(BF16))
        .astype(F32) + p["dt_bias"])                       # (B,L,di)
    A = -jnp.exp(p["A_log"])                               # (di, N)
    dA = jnp.exp(dt[..., None] * A)                        # (B,L,di,N)
    dBu = dt[..., None] * Bm[:, :, None, :] * u.astype(F32)[..., None]
    return dA, dBu, Cm


def _scan_chunk(state, dA, dBu, Cm):
    """state: (B,di,N).  Returns (new_state, y (B,L,di))."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    cA, cB = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    h = cA * state[:, None] + cB                           # (B,L,di,N)
    y = jnp.einsum("bldn,bln->bld", h, Cm)
    return h[:, -1], y


def mamba_apply(p, x, cfg, *, chunk: int = 256, state=None, return_state=False):
    """x: (B,S,D).  Full-sequence form (training / prefill)."""
    b, s_len, d = x.shape
    di = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(BF16))
    u, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv, width d_conv
    dc = cfg.ssm.d_conv
    upad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(upad[:, i:i + s_len] * p["conv_w"][i].astype(BF16)
               for i in range(dc)) + p["conv_b"].astype(BF16)
    u = jax.nn.silu(conv.astype(F32)).astype(BF16)

    if state is None:
        state = jnp.zeros((b, di, cfg.ssm.d_state), F32)

    nch = max(1, s_len // chunk)
    ch = s_len // nch
    uc = u.reshape(b, nch, ch, di).transpose(1, 0, 2, 3)

    def outer(st, uc_t):
        dA, dBu, Cm = _ssm_inputs(p, uc_t, cfg)
        st2, y = _scan_chunk(st, dA, dBu, Cm)
        return st2, y

    state, ys = jax.lax.scan(outer, state, uc)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s_len, di)
    y = y + u.astype(F32) * p["D"]
    y = y.astype(BF16) * jax.nn.silu(z.astype(F32)).astype(BF16)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(BF16))
    if return_state:
        return out, state
    return out


def init_mamba_cache(cfg, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, cfg.d_inner), BF16),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm.d_state), F32),
    }


def mamba_decode(p, x, cache, cfg):
    """x: (B,1,D) one token; O(1) state update."""
    b = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(BF16))
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,1,di)
    dc = cfg.ssm.d_conv
    win = jnp.concatenate([cache["conv"], u], axis=1)      # (B,dc,di)
    conv = sum(win[:, i] * p["conv_w"][i].astype(BF16)
               for i in range(dc)) + p["conv_b"].astype(BF16)
    u1 = jax.nn.silu(conv.astype(F32)).astype(BF16)[:, None]  # (B,1,di)

    dA, dBu, Cm = _ssm_inputs(p, u1, cfg)
    h = dA[:, 0] * cache["ssm"] + dBu[:, 0]                # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]     # (B,1,di)
    y = y + u1.astype(F32) * p["D"]
    y = y.astype(BF16) * jax.nn.silu(z.astype(F32)).astype(BF16)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(BF16))
    return out, {"conv": win[:, 1:], "ssm": h}
