"""Model stack: init / train / prefill / decode over scanned segments."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import blocks
from .layers import BF16, F32, embed_lookup, rms_norm

REMAT_POLICIES = {
    "full": None,                                    # save nothing extra
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def init_params(key, cfg, tp: int = 1) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (v, d), F32) * (d ** -0.5),
        "final_norm": jnp.zeros((d,), F32),
    }
    for si, (pattern, n) in enumerate(blocks.plan_segments(cfg)):
        params[f"seg{si}"] = blocks.init_segment(ks[si + 1], pattern, n, cfg, tp)
    return params


def abstract_params(cfg, tp: int = 1):
    return jax.eval_shape(
        lambda k: init_params(k, cfg, tp), jax.random.key(0))


def _input_embeds(params, batch, cfg):
    if cfg.embed_stub and "frames" in batch:
        return batch["frames"].astype(BF16)
    return embed_lookup(params["embed"], batch["tokens"])


def _remat(fn, policy: Optional[str]):
    if policy is None:
        return fn
    pol = REMAT_POLICIES[policy]
    return jax.checkpoint(fn, policy=pol) if pol is not None else jax.checkpoint(fn)


def forward_train(params, batch, cfg, tp: int = 1,
                  remat_policy: Optional[str] = "full"):
    """batch: tokens/frames (+labels, +image_embeds) -> (logits, aux_loss)."""
    x = _input_embeds(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(BF16)
    aux = jnp.zeros((), F32)

    for si, (pattern, n) in enumerate(blocks.plan_segments(cfg)):
        def block(carry, p, _pattern=pattern):
            xx, ax = carry
            for i, kind in enumerate(_pattern):
                xx, a = blocks.apply_layer_train(kind, p[f"sub{i}"], xx,
                                                 positions, cfg, tp, img)
                ax = ax + a
            return (xx, ax), None

        (x, aux), _ = jax.lax.scan(_remat(block, remat_policy), (x, aux),
                                   params[f"seg{si}"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(BF16))
    return logits, aux


def loss_fn(params, batch, cfg, tp: int = 1,
            remat_policy: Optional[str] = "full"):
    """Cross-entropy, safe under vocab-sharded logits (reductions over V
    stay small collectives; the one-hot gather fuses)."""
    logits, aux = forward_train(params, batch, cfg, tp, remat_policy)
    logits = logits.astype(F32)
    labels = batch["labels"]
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=F32)
    tgt = jnp.sum(logits * onehot, axis=-1)
    nll = jnp.mean(lse - tgt)
    if cfg.moe is not None:
        nll = nll + cfg.moe.aux_loss_weight * aux
    return nll


# ---- serving ------------------------------------------------------------------

def init_caches(cfg, batch: int, max_seq: int, tp: int = 1):
    spec = attn.cache_spec(cfg, max_seq)
    caches = {}
    for si, (pattern, n) in enumerate(blocks.plan_segments(cfg)):
        def one(_, _pattern=pattern):
            return {f"sub{i}": blocks.init_layer_cache(kind, cfg, spec, batch, tp)
                    for i, kind in enumerate(_pattern)}
        caches[f"seg{si}"] = jax.vmap(one)(jnp.arange(n))
    return caches


def abstract_caches(cfg, batch: int, max_seq: int, tp: int = 1):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_seq, tp))


def forward_prefill(params, batch, cfg, max_seq: int, tp: int = 1,
                    remat_policy: Optional[str] = None):
    """Prompt -> (last-token logits, caches)."""
    x = _input_embeds(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(BF16)
    spec = attn.cache_spec(cfg, max_seq)
    caches = {}

    for si, (pattern, n) in enumerate(blocks.plan_segments(cfg)):
        def block(xx, p, _pattern=pattern):
            cs = {}
            for i, kind in enumerate(_pattern):
                xx, c = blocks.apply_layer_prefill(kind, p[f"sub{i}"], xx,
                                                   positions, cfg, tp, spec, img)
                cs[f"sub{i}"] = c
            return xx, cs

        x, caches[f"seg{si}"] = jax.lax.scan(_remat(block, remat_policy), x,
                                             params[f"seg{si}"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(BF16))
    return logits, caches


def forward_decode(params, batch, caches, cfg, max_seq: int, tp: int = 1):
    """One-token step: batch = {token (B,), pos (B,)} -> (logits, caches)."""
    tok = batch["token"]
    pos = batch["pos"]
    x = embed_lookup(params["embed"], tok[:, None])
    spec = attn.cache_spec(cfg, max_seq)
    new_caches = {}

    for si, (pattern, n) in enumerate(blocks.plan_segments(cfg)):
        def block(xx, pc, _pattern=pattern):
            p, cache = pc
            cs = {}
            for i, kind in enumerate(_pattern):
                xx, c = blocks.apply_layer_decode(kind, p[f"sub{i}"], xx, pos,
                                                  cache[f"sub{i}"], spec, cfg, tp)
                cs[f"sub{i}"] = c
            return xx, cs

        x, new_caches[f"seg{si}"] = jax.lax.scan(
            block, x, (params[f"seg{si}"], caches[f"seg{si}"]))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"].astype(BF16))
    return logits, new_caches
