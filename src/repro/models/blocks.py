"""Per-layer assembly and the three execution modes (train/prefill/decode).

A *segment* is a repeated pattern of layer kinds — ("attn",) for
homogeneous stacks, ("rglru","rglru","attn") for RecurrentGemma,
("attn",)*4+("xattn",) for the vision model — scanned with stacked
params so the HLO stays one-block-sized regardless of depth.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import mlp as mlpm
from . import moe as moem
from . import rglru as rg
from .layers import BF16, F32, rms_norm


def plan_segments(cfg) -> list[Tuple[Tuple[str, ...], int]]:
    p = cfg.layer_pattern
    n_full = cfg.num_layers // len(p)
    segs = [(p, n_full)]
    rem = cfg.num_layers - n_full * len(p)
    if rem:
        segs.append((p[:rem], 1))
    return segs


# ---- init --------------------------------------------------------------------

def init_layer(key, kind: str, cfg, tp: int) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.zeros((d,), F32)}
    if kind == "attn":
        p["attn"] = attn.init_attn_params(ks[0], cfg, tp)
        p["norm2"] = jnp.zeros((d,), F32)
        if cfg.moe is not None:
            p["moe"] = moem.init_moe_params(ks[1], cfg)
        else:
            p["mlp"] = mlpm.init_mlp_params(ks[1], d, cfg.d_ff, cfg.mlp)
    elif kind == "xattn":
        p["xattn"] = attn.init_xattn_params(ks[0], cfg, tp)
        p["norm2"] = jnp.zeros((d,), F32)
        p["mlp"] = mlpm.init_mlp_params(ks[1], d, cfg.d_ff, cfg.mlp)
    elif kind == "mamba":
        p["mamba"] = mb.init_mamba_params(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = rg.init_rglru_params(ks[0], cfg)
        p["norm2"] = jnp.zeros((d,), F32)
        p["mlp"] = mlpm.init_mlp_params(ks[1], d, cfg.d_ff,
                                        "geglu" if cfg.mlp == "geglu" else cfg.mlp)
    else:
        raise ValueError(kind)
    return p


def init_segment(key, pattern, n: int, cfg, tp: int):
    def one(k):
        kk = jax.random.split(k, len(pattern))
        return {f"sub{i}": init_layer(kk[i], kind, cfg, tp)
                for i, kind in enumerate(pattern)}
    return jax.vmap(one)(jax.random.split(key, n))


# ---- train forward -----------------------------------------------------------

def apply_layer_train(kind: str, p, x, positions, cfg, tp, image_embeds=None):
    aux = jnp.zeros((), F32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        x = x + attn.attention_train(p["attn"], h, positions, cfg, tp)
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, aux = moem.moe_apply(p["moe"], h2, cfg)
        else:
            y = mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
        x = x + y
    elif kind == "xattn":
        x = x + attn.cross_attention(p["xattn"], h, image_embeds, cfg, tp)
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
    elif kind == "mamba":
        x = x + mb.mamba_apply(p["mamba"], h, cfg)
    elif kind == "rglru":
        x = x + rg.rglru_apply(p["rglru"], h, cfg)
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2,
                               "geglu" if cfg.mlp == "geglu" else cfg.mlp)
    return x, aux


# ---- prefill (returns caches) -------------------------------------------------

def apply_layer_prefill(kind: str, p, x, positions, cfg, tp, spec,
                        image_embeds=None):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        q, k, v = attn._qkv(p["attn"], h, positions, cfg)
        s = x.shape[1]
        if s <= 2048:
            out = attn.full_attention(q, k, v, window=cfg.window)
        else:
            out = attn.chunked_attention(q, k, v, window=cfg.window)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(BF16))
        cache = _fill_cache(k, v, positions, spec)
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moem.moe_apply(p["moe"], h2, cfg)
        else:
            y = mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
        x = x + y
    elif kind == "xattn":
        kk = jnp.einsum("bnd,dhk->bnhk", image_embeds,
                        p["xattn"]["wk"].astype(BF16))
        vv = jnp.einsum("bnd,dhk->bnhk", image_embeds,
                        p["xattn"]["wv"].astype(BF16))
        x = x + attn.cross_attention(p["xattn"], h, image_embeds, cfg, tp)
        cache = {"k": kk, "v": vv}
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
    elif kind == "mamba":
        dc = cfg.ssm.d_conv
        xz = jnp.einsum("bsd,de->bse", h, p["mamba"]["in_proj"].astype(BF16))
        u_raw, _ = jnp.split(xz, 2, axis=-1)
        y, state = mb.mamba_apply(p["mamba"], h, cfg, return_state=True)
        x = x + y
        cache = {"conv": u_raw[:, -(dc - 1):].astype(BF16), "ssm": state}
    elif kind == "rglru":
        xg = jnp.einsum("bsd,de->bse", h, p["rglru"]["in_proj"].astype(BF16))
        u_raw, _ = jnp.split(xg, 2, axis=-1)
        y, state = rg.rglru_apply(p["rglru"], h, cfg, return_state=True)
        x = x + y
        cache = {"conv": u_raw[:, -3:].astype(BF16), "h": state}
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2,
                               "geglu" if cfg.mlp == "geglu" else cfg.mlp)
    return x, cache


def _fill_cache(k, v, positions, spec: attn.CacheSpec):
    b, s = k.shape[0], k.shape[1]
    keep = min(s, spec.length)
    ck = jnp.zeros((b, spec.length) + k.shape[2:], BF16)
    cv = jnp.zeros_like(ck)
    if spec.ring:
        slots = positions[:, -keep:] % spec.length
        bi = jnp.arange(b)[:, None]
        ck = ck.at[bi, slots].set(k[:, -keep:])
        cv = cv.at[bi, slots].set(v[:, -keep:])
    else:
        ck = jax.lax.dynamic_update_slice(ck, k[:, :keep], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[:, :keep], (0, 0, 0, 0))
    return {"k": ck, "v": cv}


def init_layer_cache(kind: str, cfg, spec, batch: int, tp: int):
    if kind == "attn":
        return attn.init_cache(cfg, spec, batch)
    if kind == "xattn":
        kh, hd = cfg.num_kv_heads, cfg.head_dim
        n = cfg.num_image_tokens
        return {"k": jnp.zeros((batch, n, kh, hd), BF16),
                "v": jnp.zeros((batch, n, kh, hd), BF16)}
    if kind == "mamba":
        return mb.init_mamba_cache(cfg, batch)
    if kind == "rglru":
        return rg.init_rglru_cache(cfg, batch)
    raise ValueError(kind)


# ---- decode -------------------------------------------------------------------

def apply_layer_decode(kind: str, p, x, pos, cache, spec, cfg, tp):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        y, cache = attn.attention_decode(p["attn"], h, pos, cache, spec, cfg, tp)
        x = x + y
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y2, _ = moem.moe_apply(p["moe"], h2, cfg)
        else:
            y2 = mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
        x = x + y2
    elif kind == "xattn":
        # static image kv from cache
        q = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"].astype(BF16))
        out = attn.full_attention(q, cache["k"], cache["v"], causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["xattn"]["wo"].astype(BF16))
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2, cfg.mlp)
    elif kind == "mamba":
        y, cache = mb.mamba_decode(p["mamba"], h, cache, cfg)
        x = x + y
    elif kind == "rglru":
        y, cache = rg.rglru_decode(p["rglru"], h, cache, cfg)
        x = x + y
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_apply(p["mlp"], h2,
                               "geglu" if cfg.mlp == "geglu" else cfg.mlp)
    return x, cache
