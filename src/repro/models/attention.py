"""GQA attention: full / chunked / sliding-window / cross, with KV caches.

Design notes for the mesh:
  * Q heads are padded to a multiple of TP and sharded over "model";
    KV heads are sharded only when divisible, otherwise replicated
    (their projections are tiny) while the KV *cache* is sharded over
    the batch/data axis.
  * Long sequences use a q-chunked attention loop (lax.scan) so live
    memory is O(chunk * S) instead of O(S^2); sliding-window archs keep
    only `window` KV entries in the decode cache (a ring buffer), which
    is what makes long_500k decode O(1) per token.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import BF16, F32, apply_rope, causal_mask

NEG_INF = -1e9


def init_attn_params(key, cfg, tp: int, *, cross: bool = False):
    d, hd, k_h = cfg.d_model, cfg.head_dim, cfg.num_kv_heads
    h = cfg.padded_heads(tp)
    ks = jax.random.split(key, 4)
    scale_q = 1.0 / jnp.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), F32) * scale_q,
        "wk": jax.random.normal(ks[1], (d, k_h, hd), F32) * scale_q,
        "wv": jax.random.normal(ks[2], (d, k_h, hd), F32) * scale_q,
        "wo": jax.random.normal(ks[3], (h, hd, d), F32) / jnp.sqrt(h * hd),
    }
    # zero the padded q heads so they are inert (and stay so under decay)
    if h != cfg.num_heads:
        mask = (jnp.arange(h) < cfg.num_heads).astype(F32)[None, :, None]
        p["wq"] = p["wq"] * mask
        p["wo"] = p["wo"] * mask[0][:, :, None]
    return p


def _qkv(p, x, positions, cfg, *, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(BF16))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(BF16))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(BF16))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd), k: (B,Sk,K,hd) -> (B, K, G, Sq, Sk)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / jnp.sqrt(hd).astype(BF16)


def _gqa_out(scores, v, h):
    b, kh, g, sq, sk = scores.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", scores, v)
    return out.reshape(b, sq, h, v.shape[-1])


def full_attention(q, k, v, *, q_offset=0, window=None, causal=True):
    """Reference attention; used when S is small enough to materialize."""
    scores = _gqa_scores(q, k).astype(F32)
    if causal:
        m = causal_mask(q.shape[1], k.shape[1], q_offset, window)
        scores = scores + m[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(BF16)
    return _gqa_out(probs, v, q.shape[2])


def chunked_attention(q, k, v, *, chunk: int = 512, window=None):
    """Causal attention scanned over q chunks: live memory O(chunk*S).

    Numerically identical to full softmax (each chunk sees its full
    key prefix).  Used for prefill/train when S*S would not fit.
    """
    b, s, h, hd = q.shape
    nq = s // chunk

    def body(_, qc_i):
        qc, i = qc_i
        off = i * chunk
        scores = _gqa_scores(qc, k).astype(F32)
        m = causal_mask(chunk, k.shape[1], off, window)
        probs = jax.nn.softmax(scores + m[None, None, None], axis=-1).astype(BF16)
        return None, _gqa_out(probs, v, h)

    qs = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    _, outs = jax.lax.scan(body, None,
                           (qs, jnp.arange(nq, dtype=jnp.int32)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attention_train(p, x, positions, cfg, tp: int, *, chunk: int = 1024,
                    rope: bool = True):
    q, k, v = _qkv(p, x, positions, cfg, rope=rope)
    s = x.shape[1]
    if s <= 2048:
        out = full_attention(q, k, v, window=cfg.window)
    else:
        out = chunked_attention(q, k, v, chunk=chunk, window=cfg.window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(BF16))


# ---- KV cache (decode) ------------------------------------------------------

@dataclasses.dataclass
class CacheSpec:
    length: int          # cache capacity: min(window, max_seq)
    ring: bool           # True for sliding-window ring buffers


def cache_spec(cfg, max_seq: int) -> CacheSpec:
    if cfg.window is not None and cfg.window < max_seq:
        return CacheSpec(cfg.window, True)
    return CacheSpec(max_seq, False)


def init_cache(cfg, spec: CacheSpec, batch: int):
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, spec.length, kh, hd)
    return {"k": jnp.zeros(shape, BF16), "v": jnp.zeros(shape, BF16)}


def attention_decode(p, x, pos, cache, spec: CacheSpec, cfg, tp: int,
                     *, rope: bool = True):
    """One-token decode step.  pos: (B,) absolute positions.

    Ring caches write at pos % window; position-aware masking keeps
    softmax correct for both layouts.
    """
    b = x.shape[0]
    positions = pos[:, None]
    q, k_new, v_new = _qkv(p, x, positions, cfg, rope=rope)

    slot = (pos % spec.length) if spec.ring else pos
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])

    # key absolute positions for masking
    lane = jnp.arange(spec.length)[None, :]
    if spec.ring:
        # entry at slot s holds the latest position p with p % L == s, p <= pos
        cur = pos[:, None]
        kpos = cur - ((cur - lane) % spec.length)
    else:
        kpos = jnp.broadcast_to(lane, (b, spec.length))
    valid = (kpos <= pos[:, None]) & (kpos > pos[:, None] - (cfg.window or 10**9))

    scores = _gqa_scores(q, k).astype(F32)              # (B,K,G,1,L)
    mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(scores + mask, axis=-1).astype(BF16)
    out = _gqa_out(probs, v, q.shape[2])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(BF16))
    return y, {"k": k, "v": v}


# ---- cross attention (VLM) ---------------------------------------------------

def init_xattn_params(key, cfg, tp: int):
    return init_attn_params(key, cfg, tp)


def cross_attention(p, x, kv_embeds, cfg, tp: int):
    """x: (B,S,D) queries; kv_embeds: (B,N,D) image tokens (no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(BF16))
    k = jnp.einsum("bnd,dhk->bnhk", kv_embeds, p["wk"].astype(BF16))
    v = jnp.einsum("bnd,dhk->bnhk", kv_embeds, p["wv"].astype(BF16))
    out = full_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(BF16))
