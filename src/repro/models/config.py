"""Model configuration for the assigned architecture pool.

One frozen dataclass describes every family (dense / moe / ssm / hybrid /
vlm / audio); per-arch files in repro.configs instantiate it with the
assignment's exact numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    group_size: int = 2048       # GSPMD dispatch group (tokens)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    mlp: str = "swiglu"          # swiglu | relu2 | gelu | geglu
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer-kind pattern, cycled to num_layers ("attn" | "rglru" | "mamba"
    # | "xattn"); homogeneous patterns scan over layers, mixed patterns
    # scan over super-blocks of len(pattern) layers.
    layer_pattern: Tuple[str, ...] = ("attn",)
    lru_width: Optional[int] = None       # rg-lru recurrence width
    num_image_tokens: int = 0             # vlm cross-attn kv length
    embed_stub: bool = False              # audio: inputs are frame embeddings
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    sub_quadratic: bool = False           # eligible for long_500k
    scan_layers: bool = True
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm is not None and self.ssm.dt_rank is None:
            object.__setattr__(
                self, "ssm",
                dataclasses.replace(self.ssm, dt_rank=-(-self.d_model // 16)))

    # ---- derived ------------------------------------------------------------
    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        """The concrete kind of each of the num_layers layers."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def padded_heads(self, tp: int) -> int:
        """Q heads padded up to a multiple of tp (Megatron-style TP padding;
        the roofline's useful-flops ratio accounts the waste honestly)."""
        return -(-self.num_heads // tp) * tp if self.num_heads else 0

    def kv_shardable(self, tp: int) -> bool:
        return self.num_kv_heads > 0 and self.num_kv_heads % tp == 0

    def heads_shardable(self, tp: int) -> bool:
        return self.num_heads > 0 and self.num_heads % tp == 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    def param_count(self) -> int:
        """Analytic N for MODEL_FLOPS = 6*N*D (total params)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim or 0
        n = v * d  # embed (tied head)
        if not self.tie_embeddings:
            n += v * d
        per = {}
        per["attn"] = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d + 2 * d
        if self.mlp in ("swiglu", "geglu"):
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        per["attn"] += per_mlp
        per["xattn"] = per["attn"] + d * self.num_heads * hd \
            + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.moe:
            e, fe = self.moe.num_experts, self.moe.d_ff
            per["attn"] = per["attn"] - per_mlp + d * e + e * 3 * d * fe
        if self.ssm:
            di, st, dr = self.d_inner, self.ssm.d_state, self.ssm.dt_rank
            per["mamba"] = (d * 2 * di + self.ssm.d_conv * di
                            + di * (dr + 2 * st) + dr * di + di * st + di
                            + di * d + d)
        if self.lru_width:
            w = self.lru_width
            per["rglru"] = d * 2 * w + 2 * 4 * w + 3 * w + w * d + 3 * d * f + 2 * d
        return n + sum(per.get(k, per.get("attn", 0))
                       for k in self.pattern_layers)

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        e, k, fe = self.moe.num_experts, self.moe.top_k, self.moe.d_ff
        full = self.param_count()
        unused_experts = L * (e - k) * 3 * d * fe
        return full - unused_experts
