"""RG-LRU recurrent block (RecurrentGemma temporal-mixing layer).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))

with input/recurrence gates r_t, i_t from linear maps of x.  The block is
conv1d(4) -> RG-LRU, wrapped by linear in/out projections (the "recurrent
block" of the paper).  Same chunked associative-scan execution as mamba:
O(chunk) live memory, O(1) decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import BF16, F32

_C = 8.0


def init_rglru_params(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    si = 1.0 / jnp.sqrt(d)
    sw = 1.0 / jnp.sqrt(w)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * w), F32) * si,   # x, gate
        "conv_w": jax.random.normal(ks[1], (4, w), F32) * 0.1,
        "conv_b": jnp.zeros((w,), F32),
        "wr": jax.random.normal(ks[2], (w, w), F32) * sw,
        "wi": jax.random.normal(ks[3], (w, w), F32) * sw,
        "lam": jnp.full((w,), 2.0, F32),   # softplus(2) ~ 2.1 -> slow decay
        "out_proj": jax.random.normal(ks[4], (w, d), F32) * sw,
    }


def _gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", u, p["wr"].astype(BF16))
                       .astype(F32))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", u, p["wi"].astype(BF16))
                       .astype(F32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,L,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(F32))
    return a, gated


def rglru_apply(p, x, cfg, *, chunk: int = 256, state=None, return_state=False):
    b, s_len, d = x.shape
    w = cfg.lru_width or d
    xg = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(BF16))
    u, g = jnp.split(xg, 2, axis=-1)

    upad = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
    conv = sum(upad[:, i:i + s_len] * p["conv_w"][i].astype(BF16)
               for i in range(4)) + p["conv_b"].astype(BF16)
    u = conv

    if state is None:
        state = jnp.zeros((b, w), F32)

    nch = max(1, s_len // chunk)
    ch = s_len // nch
    uc = u.reshape(b, nch, ch, w).transpose(1, 0, 2, 3)

    def outer(st, ut):
        a, gated = _gates(p, ut)

        def combine(x1, x2):
            a1, b1 = x1
            a2, b2 = x2
            return a1 * a2, b1 * a2 + b2

        cA, cB = jax.lax.associative_scan(combine, (a, gated), axis=1)
        h = cA * st[:, None] + cB
        return h[:, -1], h

    state, hs = jax.lax.scan(outer, state, uc)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s_len, w)
    y = h.astype(BF16) * jax.nn.gelu(g.astype(F32)).astype(BF16)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(BF16))
    if return_state:
        return out, state
    return out


def init_rglru_cache(cfg, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, 3, w), BF16),
            "h": jnp.zeros((batch, w), F32)}


def rglru_decode(p, x, cache, cfg):
    b = x.shape[0]
    w = cfg.lru_width or cfg.d_model
    xg = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(BF16))
    u, g = jnp.split(xg, 2, axis=-1)                      # (B,1,W)
    win = jnp.concatenate([cache["conv"], u], axis=1)     # (B,4,W)
    conv = sum(win[:, i] * p["conv_w"][i].astype(BF16)
               for i in range(4)) + p["conv_b"].astype(BF16)
    u1 = conv[:, None]
    a, gated = _gates(p, u1)
    h = a[:, 0] * cache["h"] + gated[:, 0]
    y = h[:, None].astype(BF16) * jax.nn.gelu(g.astype(F32)).astype(BF16)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(BF16))
    return out, {"conv": win[:, 1:], "h": h}
