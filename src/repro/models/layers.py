"""Shared neural building blocks: norms, RoPE, embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BF16 = jnp.bfloat16
F32 = jnp.float32


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 accumulation, cast back to input dtype."""
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(F32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    """(V, D) f32 table -> (B, S, D) bf16 activations."""
    return embedding[tokens].astype(BF16)


def dense_init(key, shape, in_axis: int = 0, dtype=F32) -> jax.Array:
    fan_in = np.prod([shape[i] for i in (in_axis,) if True]) if isinstance(in_axis, int) else 1
    fan_in = shape[in_axis]
    scale = 1.0 / np.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def causal_mask(sq: int, sk: int, q_offset, window=None) -> jax.Array:
    """(sq, sk) additive mask; q_offset = absolute position of q[0]."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e9).astype(F32)
