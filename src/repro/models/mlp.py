"""Feed-forward variants: SwiGLU / GeGLU / squared-ReLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import BF16, F32


def init_mlp_params(key, d_model: int, d_ff: int, kind: str):
    ks = jax.random.split(key, 3)
    si = 1.0 / jnp.sqrt(d_model)
    so = 1.0 / jnp.sqrt(d_ff)
    p = {"w_in": jax.random.normal(ks[0], (d_model, d_ff), F32) * si,
         "w_out": jax.random.normal(ks[1], (d_ff, d_model), F32) * so}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[2], (d_model, d_ff), F32) * si
    return p


def mlp_apply(p, x, kind: str):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(BF16))
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(BF16))
        h = jax.nn.silu(g.astype(F32)).astype(BF16) * h
    elif kind == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(BF16))
        h = jax.nn.gelu(g.astype(F32)).astype(BF16) * h
    elif kind == "relu2":       # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h.astype(F32))).astype(BF16)
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(F32)).astype(BF16)
    else:
        raise ValueError(kind)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(BF16))
