"""Mixture-of-Experts layer (GSPMD group-wise dispatch, Switch/GLaM style).

Tokens are reshaped into groups of `group_size`; within each group the
router's top-k choices are turned into capacity-bounded positions via a
cumulative-sum (the same "claim a slot by prefix rank" trick the GVEL CSR
builder uses — position-in-expert replaces an atomic fetch-add).  The
dispatch/combine tensors are (G, S_g, E, C) einsums, which GSPMD shards
cleanly: groups over the batch/data axes, experts over "model" when
E % TP == 0 (true expert parallelism — llama4's 128 experts), otherwise
the expert hidden dim is TP-sharded (mixtral's 8 experts on a 16-way
axis become tensor-parallel experts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import BF16, F32


def init_moe_params(key, cfg):
    d, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    si = 1.0 / jnp.sqrt(d)
    so = 1.0 / jnp.sqrt(m.d_ff)
    return {
        "router": jax.random.normal(ks[0], (d, m.num_experts), F32) * si,
        "w_in": jax.random.normal(ks[1], (m.num_experts, d, m.d_ff), F32) * si,
        "w_gate": jax.random.normal(ks[2], (m.num_experts, d, m.d_ff), F32) * si,
        "w_out": jax.random.normal(ks[3], (m.num_experts, m.d_ff, d), F32) * so,
    }


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (B, S, D), plus load-balancing aux loss."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    gs = min(m.group_size, tokens)
    g = -(-tokens // gs)
    pad = g * gs - tokens
    xf = x.reshape(tokens, d)
    if pad:      # ragged batches (prefill/serve): pad, drop on the way out
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)])
    xg = xf.reshape(g, gs, d)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(BF16)).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,S,E)

    cap = int(gs * m.top_k / m.num_experts * m.capacity_factor)
    cap = max(cap, m.top_k)

    # top-k selection, one expert at a time (k is 1 or 2 here)
    gates = []
    masks = []
    remaining = probs
    for _ in range(m.top_k):
        idx = jnp.argmax(remaining, axis=-1)                   # (G,S)
        onehot = jax.nn.one_hot(idx, m.num_experts, dtype=F32)  # (G,S,E)
        gates.append(jnp.sum(probs * onehot, axis=-1))         # (G,S)
        masks.append(onehot)
        remaining = remaining * (1.0 - onehot)

    # aux load-balance loss (Switch): mean over experts of f_e * p_e * E
    me = jnp.mean(probs, axis=1)                               # (G,E)
    fe = jnp.mean(masks[0], axis=1)                            # (G,E)
    aux = jnp.mean(jnp.sum(me * fe, axis=-1)) * m.num_experts

    # capacity positions: prefix rank within expert across the group,
    # k-th choices queue behind all first choices
    combined = jnp.zeros((g, gs, m.num_experts, cap), F32)
    prior = jnp.zeros((g, m.num_experts), F32)
    for mask, gate in zip(masks, gates):
        pos = jnp.cumsum(mask, axis=1) - mask + prior[:, None, :]   # (G,S,E)
        prior = prior + jnp.sum(mask, axis=1)
        keep = (pos < cap) * mask                              # dropped beyond C
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=F32)
        combined = combined + gate[:, :, None, None] * keep[..., None] * pos_oh

    dispatch = (combined > 0).astype(BF16)                     # (G,S,E,C)
    xin = jnp.einsum("gsd,gsec->gecd", xg, dispatch)           # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xin, p["w_in"].astype(BF16))
    gt = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"].astype(BF16))
    h = jax.nn.silu(gt.astype(F32)).astype(BF16) * h
    out = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(BF16))
    y = jnp.einsum("gecd,gsec->gsd", out, combined.astype(BF16))
    y = y.reshape(g * gs, d)
    if pad:
        y = y[:tokens]
    return y.reshape(b, s, d), aux
