"""WalkCorpus: step-indexed determinism, kill/restart bitwise resume,
prefetch stream ordering, cursor atomicity, degrade prefix contract
(docs/serving.md)."""
import os

import numpy as np
import pytest

from repro.core import make_graph_file
from repro.core.source import open_graph
from repro.data.corpus import (CorpusConfig, WalkCorpus, load_cursor,
                               save_cursor)

CC = CorpusConfig(batch=4, seq=8, vocab_size=64, seed=5)


@pytest.fixture(scope="module")
def snap(tmp_path_factory):
    d = tmp_path_factory.mktemp("corpus")
    el = str(d / "g.el")
    v, e = make_graph_file(el, "rmat", scale=7, edge_factor=6, seed=2)
    gv = str(d / "g.gvel")
    open_graph(el, engine="numpy", num_vertices=v).save(gv)
    return gv


def _tokens(batch):
    return np.asarray(batch["tokens"])


def test_batch_at_pure(snap):
    c = WalkCorpus(open_graph(snap), CC)
    b1, b2 = c.batch_at(3), c.batch_at(3)
    assert np.array_equal(_tokens(b1), _tokens(b2))
    assert _tokens(b1).shape == (CC.batch, CC.seq)
    # labels are next-token shifted
    assert np.array_equal(np.asarray(b1["labels"])[:, :-1],
                          _tokens(b1)[:, 1:])
    # a second corpus over a second handle of the same snapshot agrees
    c2 = WalkCorpus(open_graph(snap), CC)
    assert np.array_equal(_tokens(c2.batch_at(3)), _tokens(b1))


def test_stream_yields_indexed_batches(snap):
    c = WalkCorpus(open_graph(snap), CC)
    with c.batches(0) as stream:
        for want in range(5):
            step, batch = next(stream)
            assert step == want
            assert np.array_equal(_tokens(batch), _tokens(c.batch_at(step)))
        assert stream.next_step == 5


def test_kill_restart_resumes_bitwise(snap):
    """The churn contract, in-process: consume k batches, checkpoint the
    cursor, drop the stream (the 'kill'), rebuild corpus + stream from
    the cursor — the continuation is bitwise identical to an
    uninterrupted run."""
    ref = []
    with WalkCorpus(open_graph(snap), CC).batches(0) as stream:
        for _ in range(8):
            ref.append(_tokens(next(stream)[1]))

    cursor = snap + ".cursor"
    with WalkCorpus(open_graph(snap), CC).batches(0) as stream:
        for _ in range(3):
            step, batch = next(stream)
            assert np.array_equal(_tokens(batch), ref[step])
            save_cursor(cursor, stream.next_step)
    # "restart": fresh handle, fresh corpus, resume at the cursor
    resume = load_cursor(cursor)
    assert resume == 3
    with WalkCorpus(open_graph(snap), CC).batches(resume) as stream:
        for want in range(3, 8):
            step, batch = next(stream)
            assert step == want
            assert np.array_equal(_tokens(batch), ref[step])


def test_degraded_batch_is_prefix(snap):
    c = WalkCorpus(open_graph(snap), CC)
    full = _tokens(c.batch_at(6))
    half = _tokens(c.batch_at(6, batch=2))
    assert np.array_equal(half, full[:2])


def test_cursor_roundtrip_and_missing(tmp_path):
    p = str(tmp_path / "cursor.json")
    assert load_cursor(p) is None
    save_cursor(p, 41)
    assert load_cursor(p) == 41
    save_cursor(p, 42)                      # atomic overwrite
    assert load_cursor(p) == 42
    assert not [f for f in os.listdir(tmp_path) if ".tmp." in f]


def test_graph_walk_source_routes_through_corpus(snap):
    from repro.data.pipeline import graph_walk_source

    class Cfg:
        vocab_size = CC.vocab_size

    src = graph_walk_source(snap, Cfg, CC.batch, CC.seq, engine="snapshot",
                            seed=CC.seed)
    want = WalkCorpus(open_graph(snap), CC).batch_at(2)
    assert np.array_equal(np.asarray(src(2)["tokens"]), _tokens(want))


def test_train_loop_accepts_corpus_as_batch_source(snap):
    """train.loop duck-types a WalkCorpus straight in as batch_source."""
    from repro.train import loop as train_loop

    corpus = WalkCorpus(open_graph(snap), CC)
    seen = []

    class _State:
        step = 0

    def fake_step(state, batch):
        seen.append(np.asarray(batch["tokens"]))
        return state, {"loss": np.float32(0.0), "grad_norm": np.float32(0.0)}

    train_loop.run(_State(), fake_step, corpus, num_steps=3,
                   log=lambda s: None)
    assert len(seen) == 3
    for i, toks in enumerate(seen):
        assert np.array_equal(toks, _tokens(corpus.batch_at(i)))
