"""Fault-injection + self-healing IO contract (docs/robustness.md):
seeded plans, bounded transient retries with bitwise-equal recovery,
stuck-reader watchdogs (StageTimeout, never a hang), corruption
quarantine with swap-on-disk recovery, prefetch-thread failure
propagation, cursor durability, and zero-edge/empty graphs through the
full serving path under injected faults."""
import errno
import os
import struct
import time

import numpy as np
import pytest

from repro.core import faults, open_graph, write_edgelist
from repro.core.cache import SourceCache
from repro.core.faults import (CorruptGraphError, FaultPlan, FaultSpec,
                               ShardLoadError, StageTimeout, fault_plan,
                               plan_from_env, set_fault_plan)
from repro.core import snapshot as snapmod
from repro.core.snapshot import SnapshotError
from repro.data.corpus import load_cursor, save_cursor
from repro.data.pipeline import Prefetcher


@pytest.fixture(autouse=True)
def _clean_faults():
    """No plan or counters may leak across tests (the module global is
    process-wide by design)."""
    set_fault_plan(None)
    faults.reset_counters()
    yield
    set_fault_plan(None)
    faults.reset_counters()


def _graph_file(tmp_path, name="g.el", *, v=50, e=300, seed=0):
    rng = np.random.default_rng(seed)
    path = str(tmp_path / name)
    write_edgelist(path, rng.integers(0, v, e), rng.integers(0, v, e),
                   None, base=1)
    return path, v


def _save_compressed(el, v, gv, *, frame_beta=96):
    """Write a zlib-framed .gvel with small frames (multi-frame
    sections, so one corrupt frame is a section-local event)."""
    from repro.core import load_edgelist, save_snapshot
    from repro.core.csr import convert_to_csr
    elist = load_edgelist(el, engine="numpy", num_vertices=v, base=1)
    save_snapshot(gv, edgelist=elist, csr=convert_to_csr(elist, engine="numpy"),
                  compress="zlib", frame_beta=frame_beta)
    return gv


def _snapshot(tmp_path, name="g.gvel", *, v=50, e=300, seed=0):
    el, v = _graph_file(tmp_path, name + ".el", v=v, e=e, seed=seed)
    return _save_compressed(el, v, str(tmp_path / name)), v


def _corrupt_section(path, section_name, *, byte=13):
    """Flip one byte inside ``section_name``'s compressed payload (past
    the first frame header) — a CRC/decode failure on next touch."""
    with open(path, "rb") as f:
        hdr = f.read(snapmod.HEADER_LEN)
    _, version, _, _, _, nsec, _ = struct.unpack(snapmod.HEADER_FMT, hdr)
    assert version == snapmod.VERSION_COMPRESSED
    sid_want = {v: k for k, v in snapmod.SECTION_NAMES.items()}[section_name]
    with open(path, "rb") as f:
        f.seek(snapmod.HEADER_LEN)
        table = f.read(nsec * snapmod.SECTION_LEN_V2)
    for i in range(nsec):
        sid, _, off, nbytes, _, _, _ = struct.unpack_from(
            snapmod.SECTION_FMT_V2, table, i * snapmod.SECTION_LEN_V2)
        if sid == sid_want:
            pos = off + 12 + min(byte, max(0, nbytes - 13))  # FRAME_HDR_LEN
            with open(path, "r+b") as f:
                f.seek(pos)
                b = f.read(1)
                f.seek(pos)
                f.write(bytes([b[0] ^ 0x40]))
            return
    raise AssertionError(f"section {section_name} not found in {path}")


# ---- plans, parsing, deterministic corruption --------------------------------


def test_plan_from_env_grammar():
    plan = plan_from_env("seed=3; block:oserror@2*2 ;frame:bitflip@1~web")
    assert plan.seed == 3
    assert plan.faults == (
        FaultSpec("block", "oserror", index=2, times=2),
        FaultSpec("frame", "bitflip", index=1, times=1, path="web"))
    assert plan_from_env("") is None
    # the @index is optional before *times and ~path
    plan = plan_from_env("open:oserror*3;mmap:latency~web")
    assert plan.faults == (
        FaultSpec("open", "oserror", times=3),
        FaultSpec("mmap", "latency", path="web"))
    with pytest.raises(ValueError, match="site"):
        plan_from_env("disk:oserror@0")
    with pytest.raises(ValueError, match="kind"):
        plan_from_env("block:explode@0")
    with pytest.raises(ValueError, match="bad entry"):
        plan_from_env("justtext")


def test_match_consumes_budget_and_filters_path():
    plan = FaultPlan([FaultSpec("open", "oserror", times=2, path="web")])
    assert plan.match("open", 0, "other.gvel") == []
    assert len(plan.match("open", 0, "a/web.gvel")) == 1
    assert len(plan.match("open", 0, "a/web.gvel")) == 1
    assert plan.match("open", 0, "a/web.gvel") == []      # budget spent
    assert plan.injected() == {"open:oserror": 2}
    assert plan.total_injected() == 2


def test_unlimited_budget_and_corruption_determinism():
    plan = FaultPlan([FaultSpec("frame", "bitflip", times=-1)], seed=7)
    data = bytes(range(256))
    a = plan.corrupt(data, plan.faults[0], salt=3)
    b = plan.corrupt(data, plan.faults[0], salt=3)
    assert a == b and a != data
    assert len([x for x, y in zip(a, data) if x != y]) == 1
    assert plan.corrupt(data, plan.faults[0], salt=4) != a
    trunc = FaultSpec("frame", "truncate")
    assert 0 < len(plan.corrupt(data, trunc)) < len(data)
    for _ in range(5):
        assert plan.match("frame", 0)                     # never exhausts


def test_fault_plan_context_restores_previous():
    outer = FaultPlan([])
    set_fault_plan(outer)
    inner = FaultPlan([])
    with fault_plan(inner):
        assert faults.active_plan() is inner
        with fault_plan(None):                            # no-op nesting
            assert faults.active_plan() is inner
    assert faults.active_plan() is outer


# ---- retry machinery ---------------------------------------------------------


def test_call_with_retries_transient_then_success():
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise OSError(errno.EIO, "flaky")
        return "ok"

    assert faults.call_with_retries(fn, attempts=3, backoff_s=0.001) == "ok"
    assert len(calls) == 3
    assert faults.counters()["io_retries"] == 2


def test_call_with_retries_nontransient_fails_fast():
    calls = []

    def fn():
        calls.append(1)
        raise FileNotFoundError(errno.ENOENT, "gone", "x")

    with pytest.raises(FileNotFoundError):
        faults.call_with_retries(fn, attempts=5, backoff_s=0.001)
    assert len(calls) == 1                                # no retry
    assert faults.counters()["io_retries"] == 0


def test_call_with_retries_budget_exhausted():
    with pytest.raises(OSError, match="flaky"):
        faults.call_with_retries(
            lambda: (_ for _ in ()).throw(OSError(errno.EAGAIN, "flaky")),
            attempts=2, backoff_s=0.001)
    assert faults.counters()["io_retries"] == 1


def test_is_transient_classification():
    assert faults.is_transient(OSError(errno.EIO, "x"))
    assert faults.is_transient(OSError(errno.ESTALE, "x"))
    assert not faults.is_transient(FileNotFoundError(errno.ENOENT, "x"))
    assert not faults.is_transient(PermissionError(errno.EACCES, "x"))
    assert not faults.is_transient(ValueError("x"))


# ---- streaming load: retry parity + watchdog ---------------------------------


def test_streaming_load_retries_transient_block_faults_bitwise(tmp_path):
    path, v = _graph_file(tmp_path)
    clean = open_graph(path, engine="device", num_vertices=v).csr()
    plan = FaultPlan([FaultSpec("block", "oserror", index=0, times=2),
                      FaultSpec("block", "latency", index=0, delay_s=0.01)])
    faulty = open_graph(path, engine="device", num_vertices=v,
                        faults=plan).csr()
    assert plan.injected() == {"block:oserror": 2, "block:latency": 1}
    assert faults.counters()["io_retries"] >= 2
    assert np.array_equal(np.asarray(clean.offsets), np.asarray(faulty.offsets))
    assert np.array_equal(np.asarray(clean.targets), np.asarray(faulty.targets))


def test_streaming_load_exhausted_retries_raise(tmp_path):
    path, v = _graph_file(tmp_path)
    plan = FaultPlan([FaultSpec("block", "oserror", index=0, times=-1)])
    with pytest.raises(OSError, match="injected transient"):
        open_graph(path, engine="device", num_vertices=v, faults=plan).csr()


def test_stuck_block_source_raises_stage_timeout(tmp_path, monkeypatch):
    path, v = _graph_file(tmp_path)
    monkeypatch.setattr(faults, "WATCHDOG_S", 0.3)
    plan = FaultPlan([FaultSpec("block", "stall", index=0, delay_s=2.0)])
    t0 = time.perf_counter()
    with pytest.raises(StageTimeout, match=r"byte span \[0, "):
        open_graph(path, engine="device", num_vertices=v, faults=plan).csr()
    assert time.perf_counter() - t0 < 1.5          # within budget, no hang
    assert faults.counters()["stage_timeouts"] == 1


# ---- SourceCache: open retries, quarantine, swap recovery --------------------


def test_cache_open_retries_transient(tmp_path):
    gv, _ = _snapshot(tmp_path)
    cache = SourceCache(capacity=2)
    with fault_plan(FaultPlan([FaultSpec("open", "oserror", times=2)])):
        info = cache.query(gv, "info")
    assert info.num_vertices == 50
    st = cache.stats()["faults"]
    assert st["open_retries"] == 2
    assert st["io_retries"] >= 2


def test_corrupt_section_quarantines_and_swap_recovers(tmp_path):
    gv, v = _snapshot(tmp_path, "live.gvel")
    other, _ = _snapshot(tmp_path, "other.gvel", seed=4)
    cache = SourceCache(capacity=4)
    good_deg = cache.query(gv, "degree", vertex=3)
    cache.invalidate()                         # force reopen of the bad bytes

    _corrupt_section(gv, "csr_indices")
    with pytest.raises(CorruptGraphError) as ei:
        cache.query(gv, "csr")
    assert ei.value.path == gv and ei.value.section == "csr_indices"
    # subsequent touches of the section fail fast from quarantine
    with pytest.raises(CorruptGraphError, match="quarantined"):
        cache.query(gv, "neighbors", vertex=3)
    # ...but header-only ops, the untouched offsets section, and other
    # graphs in the same cache keep serving
    assert cache.query(gv, "info").num_vertices == v
    assert cache.query(gv, "degree", vertex=3) == good_deg
    assert cache.query(other, "csr").num_vertices == 50
    st = cache.stats()["faults"]
    assert st["quarantines"] == 1 and st["corrupt_errors"] >= 2
    assert st["quarantined"] == [
        {"path": gv, "section": "csr_indices", "count": 2}]

    # swap a good snapshot onto the path: the quarantine lifts
    el, _ = _graph_file(tmp_path, "fresh.el", seed=0)
    _save_compressed(el, v, gv)
    os.utime(gv, ns=(time.time_ns(), time.time_ns()))
    csr = cache.query(gv, "csr")
    assert csr.num_vertices == v
    st = cache.stats()["faults"]
    assert st["recovered"] == 1
    assert st["quarantined"] == []


def test_report_corrupt_unknown_section_blocks_everything_but_info(tmp_path):
    gv, _ = _snapshot(tmp_path)
    cache = SourceCache()
    err = cache.report_corrupt(gv, ValueError("mystery damage"), op="csr")
    assert isinstance(err, CorruptGraphError) and err.section == "unknown"
    with pytest.raises(CorruptGraphError):
        cache.query(gv, "degree", vertex=0)
    assert cache.query(gv, "info").num_edges == 300      # () sections


def test_snapshot_error_carries_section(tmp_path):
    gv, _ = _snapshot(tmp_path)
    _corrupt_section(gv, "csr_indices")
    src = open_graph(gv)
    with pytest.raises(SnapshotError) as ei:
        src.csr()
    assert ei.value.section == "csr_indices"


# ---- uniform truncation/corruption messages ----------------------------------


def test_codec_errors_name_frame_and_byte_offset(tmp_path):
    from repro.core.codecs import (compress_frames, get_codec,
                                   iter_decompressed_frames)
    codec = get_codec("zlib")
    raw = bytes(np.random.default_rng(0).integers(0, 256, 4096, np.uint8))
    stream = compress_frames(raw, codec, frame_beta=512)
    # mid-frame truncation: the error names the frame AND byte offset
    with pytest.raises(ValueError, match=r"frame \d+ at byte \d+"):
        list(iter_decompressed_frames(stream[:-5], codec, context="cut"))
    # a dangling partial header names the frame and byte position too
    with pytest.raises(ValueError,
                       match=r"truncated frame header for frame \d+ at byte"):
        list(iter_decompressed_frames(stream + b"\x01\x02\x03", codec,
                                      context="hdr"))
    # corrupt payload: checksum/decode error names frame + byte offset
    bad = bytearray(stream)
    bad[20] ^= 0xFF
    with pytest.raises(ValueError, match=r"frame \d+ .*byte \d+"):
        list(iter_decompressed_frames(bytes(bad), codec, context="bad"))


# ---- prefetch pipelines never strand their consumer --------------------------


def test_prefetcher_propagates_worker_exception():
    def source(step):
        if step == 2:
            raise RuntimeError("worker died at step 2")
        return {"step": step}

    pf = Prefetcher(source, lookahead=2)
    assert pf.get(expect_step=0)["step"] == 0
    assert pf.get(expect_step=1)["step"] == 1
    with pytest.raises(RuntimeError, match="worker died at step 2"):
        pf.get(expect_step=2)
    pf.close()


def test_prefetcher_stuck_source_times_out():
    def source(step):
        time.sleep(5.0)
        return {}

    pf = Prefetcher(source, timeout=0.3)
    t0 = time.perf_counter()
    with pytest.raises(StageTimeout, match="stuck"):
        pf.get()
    assert time.perf_counter() - t0 < 2.0
    pf.close()


def test_corpus_stream_propagates_batch_failure(tmp_path, monkeypatch):
    from repro.data.corpus import CorpusConfig, WalkCorpus
    gv, v = _snapshot(tmp_path)
    corpus = WalkCorpus(open_graph(gv), CorpusConfig(batch=2, seq=4))
    real = corpus.batch_at

    def flaky(step, **kw):
        if step >= 1:
            raise OSError(errno.EIO, "corpus storage yanked")
        return real(step, **kw)

    monkeypatch.setattr(corpus, "batch_at", flaky)
    with corpus.batches() as stream:
        step, batch = next(stream)
        assert step == 0 and batch["tokens"].shape == (2, 4)
        with pytest.raises(OSError, match="storage yanked"):
            next(stream)


# ---- cursor durability -------------------------------------------------------


def test_save_cursor_fsyncs_file_and_directory(tmp_path, monkeypatch):
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (synced.append(fd),
                                                 real_fsync(fd))[1])
    cur = str(tmp_path / "cursor.json")
    save_cursor(cur, 41)
    assert load_cursor(cur) == 41
    assert len(synced) == 2                    # tmp file + its directory
    assert not [p for p in os.listdir(tmp_path) if p.startswith("cursor.json.tmp")]


def test_save_cursor_crash_midway_keeps_previous(tmp_path, monkeypatch):
    cur = str(tmp_path / "cursor.json")
    save_cursor(cur, 7)

    def boom(src, dst):
        raise OSError(errno.EIO, "crash before rename")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        save_cursor(cur, 8)
    monkeypatch.undo()
    assert load_cursor(cur) == 7               # old cursor intact


# ---- zero-edge / empty graphs through the serving path -----------------------


@pytest.mark.parametrize("v", [0, 5])
def test_degenerate_graphs_serve_under_faults(tmp_path, v):
    el = str(tmp_path / f"z{v}.el")
    write_edgelist(el, np.array([], np.int64), np.array([], np.int64),
                   None, base=1)
    gv = _save_compressed(el, v, str(tmp_path / f"z{v}.gvel"), frame_beta=64)
    cache = SourceCache()
    plan = FaultPlan([FaultSpec("open", "oserror", times=1),
                      FaultSpec("mmap", "latency", times=1, delay_s=0.01)])
    with fault_plan(plan):
        info = cache.query(gv, "info")
        assert (info.num_vertices, info.num_edges) == (v, 0)
        csr = cache.query(gv, "csr")
        assert csr.num_vertices == v and len(csr.targets) == 0
        assert np.array_equal(np.asarray(csr.offsets), np.zeros(v + 1, np.int64))
        if v:
            assert list(cache.query(gv, "neighbors", vertex=v - 1)) == []
            assert cache.query(gv, "degree", vertex=0) == 0
    assert plan.injected().get("open:oserror") == 1
    assert cache.stats()["faults"]["open_retries"] == 1


def test_zero_edge_streaming_matches_numpy(tmp_path):
    el = str(tmp_path / "z.el")
    write_edgelist(el, np.array([], np.int64), np.array([], np.int64),
                   None, base=1)
    a = open_graph(el, engine="numpy", num_vertices=6).csr()
    b = open_graph(el, engine="device", num_vertices=6).csr()
    assert np.array_equal(np.asarray(a.offsets), np.asarray(b.offsets))
    assert len(np.asarray(b.targets)) == 0


# ---- structured errors -------------------------------------------------------


def test_shard_load_error_carries_log():
    err = ShardLoadError("shard 2 failed", shard=2,
                         fault_log=["attempt 1: OSError: x"])
    assert err.shard == 2 and err.fault_log == ["attempt 1: OSError: x"]
    assert isinstance(err, RuntimeError)


def test_stats_faults_block_shape(tmp_path):
    gv, _ = _snapshot(tmp_path)
    cache = SourceCache()
    cache.query(gv, "info")
    st = cache.stats()["faults"]
    for key in ("open_retries", "open_faults", "corrupt_errors",
                "quarantines", "recovered", "wait_timeouts",
                "io_retries", "stage_timeouts", "shard_retries",
                "quarantined", "injected"):
        assert key in st
    assert st["injected"] == {}                # no plan active


# ---- sharded load: shard-level re-execution (4 forced host devices) ----------


def test_sharded_shard_reexecution_bitwise(devices4, tmp_path):
    """Tentpole (2): a shard whose in-span retries are exhausted is
    re-executed over its byte span (fresh source + accumulators) and
    the result is bitwise equal to the fault-free load; a shard that
    never recovers fails with ShardLoadError carrying the fault log."""
    code = f"""
import numpy as np
from repro.core import faults, open_graph
from repro.core.compat import make_mesh

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(5)
n, v = 4000, 300
src = rng.integers(1, v + 1, n); dst = rng.integers(1, v + 1, n)
path = r"{tmp_path}/g.el"
open(path, "w").write("\\n".join(f"{{s}} {{d}}" for s, d in zip(src, dst)) + "\\n")

clean = open_graph(path, engine="device", beta=2048).csr_sharded(mesh)

# 3 consecutive stage failures on block 0: in-span retries (3 attempts)
# exhaust, the shard re-executes once, and the 4th stage call is clean
plan = faults.FaultPlan([faults.FaultSpec("block", "oserror", index=0, times=3)])
faults.set_fault_plan(plan)
faulty = open_graph(path, engine="device", beta=2048).csr_sharded(mesh)
faults.set_fault_plan(None)
assert plan.injected() == {{"block:oserror": 3}}, plan.injected()
c = faults.counters()
assert c["shard_retries"] == 1, c
assert c["io_retries"] >= 2, c
assert np.array_equal(np.asarray(clean.offsets), np.asarray(faulty.offsets))
assert np.array_equal(np.asarray(clean.targets), np.asarray(faulty.targets))

# a permanently-failing shard: budget exhausts into ShardLoadError
faults.set_fault_plan(faults.FaultPlan(
    [faults.FaultSpec("block", "oserror", index=0, times=-1)]))
try:
    open_graph(path, engine="device", beta=2048).csr_sharded(mesh)
    raise SystemExit("expected ShardLoadError")
except faults.ShardLoadError as exc:
    assert exc.shard == 0, exc.shard
    assert len(exc.fault_log) == faults.SHARD_RETRIES + 1, exc.fault_log
    assert "byte span [0," in str(exc), str(exc)
finally:
    faults.set_fault_plan(None)
print("SHARD-RETRY-OK")
"""
    assert "SHARD-RETRY-OK" in devices4(code)
