"""Walk determinism contract: bitwise repeatability, batch-split
invariance, adjacency confinement vs a numpy oracle, isolated-vertex
self-loops (the corpus/serving resume + degrade contracts build on
these — docs/serving.md)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import convert_to_csr, make_graph_file, read_edgelist_numpy
from repro.data.walks import (random_walks, walk_batch, walk_from,
                              walk_keys)


class _Cfg:
    vocab_size = 64


@pytest.fixture(scope="module")
def csr(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("w") / "g.el")
    v, e = make_graph_file(path, "rmat", scale=8, edge_factor=8, seed=11)
    el = read_edgelist_numpy(path, num_vertices=v)
    return convert_to_csr(el, method="staged")


def _arrays(csr):
    return (jnp.asarray(np.asarray(csr.offsets), jnp.int32),
            jnp.asarray(np.asarray(csr.targets), jnp.int32))


def _assert_confined(walks, offsets, targets):
    """Numpy oracle: every step lands inside the current vertex's
    adjacency; a dead end (out-degree 0) self-loops."""
    offs, tgts = np.asarray(offsets), np.asarray(targets)
    for row in np.asarray(walks):
        for a, b in zip(row[:-1], row[1:]):
            nbrs = tgts[offs[a]:offs[a + 1]]
            if len(nbrs):
                assert b in nbrs, (a, b, nbrs)
            else:
                assert b == a, f"dead end {a} stepped to {b}, not self-loop"


def test_same_key_same_csr_bitwise_identical(csr):
    off, tgt = _arrays(csr)
    k = jax.random.key(7)
    w1 = random_walks(off, tgt, k, num_walks=8, length=12,
                      num_vertices=csr.num_vertices)
    w2 = random_walks(off, tgt, k, num_walks=8, length=12,
                      num_vertices=csr.num_vertices)
    assert np.array_equal(np.asarray(w1), np.asarray(w2))


def test_batch_split_invariance(csr):
    """num_walks=8 equals the concatenation of two num_walks=4 calls at
    walk offsets 0 and 4 — per-walk keying, bitwise."""
    off, tgt = _arrays(csr)
    k = jax.random.key(3)
    kw = dict(length=10, num_vertices=csr.num_vertices)
    full = np.asarray(random_walks(off, tgt, k, num_walks=8, **kw))
    lo = np.asarray(random_walks(off, tgt, k, num_walks=4, walk_offset=0, **kw))
    hi = np.asarray(random_walks(off, tgt, k, num_walks=4, walk_offset=4, **kw))
    assert np.array_equal(full, np.concatenate([lo, hi]))
    # ...and any prefix batch is the prefix of the full batch
    pre = np.asarray(random_walks(off, tgt, k, num_walks=3, **kw))
    assert np.array_equal(full[:3], pre)


def test_walks_confined_random_csrs():
    """Property over random CSRs (isolated vertices included, by
    construction): walks never leave adjacency, dead ends self-loop."""
    rng = np.random.default_rng(0)
    for trial in range(8):
        v = 32
        ne = int(rng.integers(0, 120))
        src = rng.integers(0, v // 2, ne)       # top half stays isolated
        dst = rng.integers(0, v, ne)
        counts = np.bincount(src, minlength=v)
        offsets = np.zeros(v + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        targets = dst[np.argsort(src, kind="stable")]
        off = jnp.asarray(offsets, jnp.int32)
        tgt = jnp.asarray(targets, jnp.int32)
        walks = random_walks(off, tgt, jax.random.key(trial), num_walks=8,
                             length=8, num_vertices=v)
        _assert_confined(walks, offsets, targets)


def test_isolated_vertex_self_loops_not_crash():
    # vertex 2 of 4 has no out-edges; a walk pinned there never moves
    offsets = jnp.asarray([0, 1, 2, 2, 3], jnp.int32)
    targets = jnp.asarray([1, 0, 0], jnp.int32)
    w = walk_from(offsets, targets, walk_keys(jax.random.key(0), [0]),
                  [2], length=6)
    assert np.array_equal(np.asarray(w)[0], np.full(6, 2))


def test_edgeless_graph_self_loops():
    offsets = jnp.zeros(6, jnp.int32)
    targets = jnp.zeros((0,), jnp.int32)
    w = np.asarray(random_walks(offsets, targets, jax.random.key(1),
                                num_walks=4, length=5, num_vertices=5))
    assert np.array_equal(w, np.repeat(w[:, :1], 5, axis=1))


def test_walk_from_pins_start(csr):
    off, tgt = _arrays(csr)
    w = walk_from(off, tgt, walk_keys(jax.random.key(2), [9]), [5], length=7)
    assert int(np.asarray(w)[0, 0]) == 5
    _assert_confined(w, csr.offsets, csr.targets)


def test_walk_batch_seeded_and_split_stable(csr):
    b1 = walk_batch(csr, _Cfg, 4, 16, step=3)
    b2 = walk_batch(csr, _Cfg, 4, 16, step=3)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # a different seed is a different corpus
    b3 = walk_batch(csr, _Cfg, 4, 16, step=3, seed=1)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # batch split invariance carries through walk_batch
    lo = walk_batch(csr, _Cfg, 2, 16, step=3)
    assert np.array_equal(np.asarray(b1["tokens"])[:2],
                          np.asarray(lo["tokens"]))
