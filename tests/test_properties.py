"""Hypothesis property tests for the GVEL loading invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import build
from repro.core.parse import parse_block
from repro.core.parse_np import parse_chunk_np

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

edges_st = st.lists(
    st.tuples(st.integers(0, 9999), st.integers(0, 9999)),
    min_size=0, max_size=120)


def _render(edges, base=1, sep_choices=(" ", "\t", "  ")):
    rng = np.random.default_rng(len(edges))
    lines = []
    for u, v in edges:
        sep = sep_choices[rng.integers(0, len(sep_choices))]
        lines.append(f"{u + base}{sep}{v + base}")
    return ("\n".join(lines) + ("\n" if lines else "")).encode()


@given(edges_st)
def test_roundtrip_text_to_edges_numpy(edges):
    text = _render(edges)
    s, d, _, c = parse_chunk_np(np.frombuffer(text, np.uint8), weighted=False)
    assert c == len(edges)
    assert list(zip(s.tolist(), d.tolist())) == edges


@given(edges_st)
def test_roundtrip_text_to_edges_jax(edges):
    text = _render(edges)
    buf = np.frombuffer(text, np.uint8)
    pad = (-len(buf)) % 64 or 64
    buf = np.concatenate([buf, np.full(pad, 10, np.uint8)])
    s, d, _, c = parse_block(jnp.asarray(buf), jnp.int32(0),
                             jnp.int32(len(buf)), weighted=False, base=1,
                             edge_cap=max(len(edges) + 2, 4))
    assert int(c) == len(edges)
    got = list(zip(np.asarray(s[:len(edges)]).tolist(),
                   np.asarray(d[:len(edges)]).tolist()))
    assert got == edges


@given(st.lists(st.floats(min_value=-999, max_value=999,
                          allow_nan=False).map(lambda x: round(x, 3)),
                min_size=1, max_size=50))
def test_roundtrip_weights(ws):
    text = "".join(f"1 2 {w}\n" for w in ws).encode()
    s, d, w, c = parse_chunk_np(np.frombuffer(text, np.uint8), weighted=True)
    assert c == len(ws)
    np.testing.assert_allclose(w, ws, rtol=1e-9, atol=1e-9)


@given(edges_st.filter(lambda e: len(e) > 0),
       st.integers(min_value=1, max_value=9))
def test_csr_invariants(edges, rho):
    v = 64
    src = np.asarray([u % v for u, _ in edges], np.int32)
    dst = np.asarray([w % v for _, w in edges], np.int32)
    off, tgt, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None,
                                   v, rho=rho)
    off = np.asarray(off)
    # invariant 1: offsets monotone, start 0, end |E|
    assert off[0] == 0 and off[-1] == len(edges)
    assert (np.diff(off) >= 0).all()
    # invariant 2: degree sums match bincount
    assert np.array_equal(np.diff(off), np.bincount(src, minlength=v))
    # invariant 3: per-row multiset equality vs oracle
    ref = build.csr_np(src, dst, None, v)
    roff = np.asarray(ref.offsets)
    for u in range(v):
        assert np.array_equal(np.sort(np.asarray(tgt[off[u]:off[u + 1]])),
                              np.sort(np.asarray(ref.targets[roff[u]:roff[u + 1]])))


@given(edges_st, st.integers(min_value=1, max_value=6))
def test_staged_partition_count_invariance(edges, rho):
    """The CSR must not depend on the partition count (GVEL Fig. 4 knob)."""
    v = 32
    src = np.asarray([u % v for u, _ in edges] or [0], np.int32)
    dst = np.asarray([w % v for _, w in edges] or [0], np.int32)
    o1, t1, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                                 rho=1)
    o2, t2, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                                 rho=rho)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    o1 = np.asarray(o1)
    for u in range(v):
        assert np.array_equal(np.sort(np.asarray(t1[o1[u]:o1[u + 1]])),
                              np.sort(np.asarray(t2[o1[u]:o1[u + 1]])))
