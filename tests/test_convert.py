"""scripts/convert.py CLI: the text/mtx x compression x weighted
argument matrix (outputs verified against the ``csr_np`` oracle),
plus the error paths — unreadable input, unknown engine, bad codec
spec, and overwrite refusal."""
import importlib.util
import os

import numpy as np
import pytest

from repro.core import open_graph
from repro.core.build import csr_np
from repro.core.generate import write_edgelist
from repro.core.mtx import write_mtx

_CONVERT = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "convert.py")
_spec = importlib.util.spec_from_file_location("convert_cli", _CONVERT)
convert_cli = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(convert_cli)


def _inputs(tmp_path, informat, weighted, seed=0, v=40, e=200):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = ((rng.random(e) * 9).round(3).astype(np.float32) if weighted
         else None)
    if informat == "text":
        path = str(tmp_path / "g.el")
        write_edgelist(path, src, dst, w, base=1)
    else:
        path = str(tmp_path / "g.mtx")
        write_mtx(path, src, dst, w, num_vertices=v)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


# ---- argument matrix ---------------------------------------------------------

@pytest.mark.parametrize("informat", ["text", "mtx"])
@pytest.mark.parametrize("compress", [None, "zlib"])
@pytest.mark.parametrize("weighted", [False, True])
def test_convert_matrix(tmp_path, informat, compress, weighted):
    path, v, e, oracle = _inputs(tmp_path, informat, weighted,
                                 seed=2 * weighted + (compress is not None))
    out = str(tmp_path / "g.gvel")
    args = [path, out]
    if informat == "text":
        args += ["--num-vertices", str(v)]
        if weighted:
            args.append("--weighted")
    if compress:
        args += ["--compress", compress]
    assert convert_cli.main(args) == 0

    res = open_graph(out)
    info = res.info()
    assert info.format == "gvel"
    assert info.version == (2 if compress else 1)
    assert info.codec == compress
    assert info.num_vertices == v and info.num_edges == e
    assert info.weighted == weighted
    assert info.has_edgelist and info.has_csr
    csr = res.csr()
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    off = np.asarray(oracle.offsets)
    for u in range(v):
        mine = np.sort(np.asarray(csr.targets[off[u]:off[u + 1]]))
        ref = np.sort(np.asarray(oracle.targets[off[u]:off[u + 1]]))
        assert np.array_equal(mine, ref), u


def test_convert_mtx_warns_about_ignored_text_flags(tmp_path, capsys):
    path, v, e, _ = _inputs(tmp_path, "mtx", weighted=False)
    out = str(tmp_path / "g.gvel")
    assert convert_cli.main([path, out, "--weighted", "--base", "0"]) == 0
    err = capsys.readouterr().err
    assert "--weighted" in err and "--base" in err and "ignored" in err


def test_convert_no_csr_and_level_spec(tmp_path):
    path, v, e, _ = _inputs(tmp_path, "text", weighted=False)
    out = str(tmp_path / "g.gvel")
    assert convert_cli.main([path, out, "--num-vertices", str(v),
                             "--no-csr", "--compress", "zlib:9"]) == 0
    info = open_graph(out).info()
    assert info.has_edgelist and not info.has_csr
    assert info.codec == "zlib" and info.version == 2


# ---- error paths -------------------------------------------------------------

def test_convert_unreadable_input(tmp_path, capsys):
    rc = convert_cli.main([str(tmp_path / "missing.el"),
                           str(tmp_path / "out.gvel")])
    assert rc == 1
    assert "error:" in capsys.readouterr().err
    assert not os.path.exists(str(tmp_path / "out.gvel"))


def test_convert_refuses_overwrite_without_force(tmp_path, capsys):
    path, v, e, _ = _inputs(tmp_path, "text", weighted=False)
    out = str(tmp_path / "g.gvel")
    assert convert_cli.main([path, out, "--num-vertices", str(v)]) == 0
    before = open(out, "rb").read()
    rc = convert_cli.main([path, out, "--num-vertices", str(v)])
    assert rc == 2
    assert "refusing to overwrite" in capsys.readouterr().err
    assert open(out, "rb").read() == before          # untouched
    assert convert_cli.main([path, out, "--num-vertices", str(v),
                             "--force", "--compress", "zlib"]) == 0
    assert open_graph(out).info().version == 2       # really replaced


def test_convert_unknown_engine_lists_available(tmp_path, capsys):
    path, v, e, _ = _inputs(tmp_path, "text", weighted=False)
    rc = convert_cli.main([path, str(tmp_path / "o.gvel"),
                           "--engine", "no-such-engine"])
    assert rc == 1
    err = capsys.readouterr().err
    assert "unknown loader engine" in err and "numpy" in err


def test_convert_bad_codec_spec(tmp_path, capsys):
    path, v, e, _ = _inputs(tmp_path, "text", weighted=False)
    rc = convert_cli.main([path, str(tmp_path / "o.gvel"),
                           "--compress", "zlib:notanint"])
    assert rc == 1
    assert "codec level" in capsys.readouterr().err
