"""Distributed GVEL loader + sharding rules under 8 host devices."""
import numpy as np
import pytest


def test_sharded_csr_matches_oracle(devices8, tmp_path):
    code = f"""
import numpy as np, jax
from repro.core.compat import make_mesh
from repro.core import (make_graph_file, host_shard_and_load,
                        read_edgelist_numpy, convert_to_csr)

path = r"{tmp_path}/g.el"
v, e = make_graph_file(path, "rmat", scale=9, edge_factor=8, seed=5)
mesh = make_mesh((8,), ("data",))
csr = host_shard_and_load(mesh, "data", path, num_vertices=v)
off = np.asarray(csr.offsets); tgt = np.asarray(csr.targets)
rows = off.shape[1] - 1
oc = convert_to_csr(read_edgelist_numpy(path, num_vertices=v), engine="numpy")
oo, ot = np.asarray(oc.offsets), np.asarray(oc.targets)
tot = 0
for k in range(8):
    for r in range(rows):
        u = k * rows + r
        if u >= v:
            break
        mine = np.sort(tgt[k, off[k, r]:off[k, r + 1]])
        ref = np.sort(ot[oo[u]:oo[u + 1]])
        assert np.array_equal(mine, ref), (k, r)
        tot += len(ref)
assert tot == e
print("SHARDED-CSR-OK", tot)
"""
    assert "SHARDED-CSR-OK" in devices8(code)


def test_weighted_sharded_csr(devices8, tmp_path):
    code = f"""
import numpy as np, jax
from repro.core.compat import make_mesh
from repro.core.generate import write_edgelist
from repro.core import host_shard_and_load
rng = np.random.default_rng(1)
v, e = 64, 500
src = rng.integers(0, v, e); dst = rng.integers(0, v, e)
w = (rng.random(e) * 10).round(3).astype(np.float32)
path = r"{tmp_path}/w.el"
write_edgelist(path, src, dst, w)
mesh = make_mesh((8,), ("data",))
csr = host_shard_and_load(mesh, "data", path, num_vertices=v, weighted=True)
off = np.asarray(csr.offsets); tgt = np.asarray(csr.targets)
ww = np.asarray(csr.weights)
pairs = {{(int(a), int(b), round(float(c), 3)) for a, b, c in zip(src, dst, w)}}
rows = off.shape[1] - 1
seen = 0
for k in range(8):
    for r in range(rows):
        u = k * rows + r
        if u >= v: break
        for j in range(off[k, r], off[k, r + 1]):
            assert (u, int(tgt[k, j]), round(float(ww[k, j]), 3)) in pairs
            seen += 1
assert seen == e
print("WEIGHTED-OK", seen)
"""
    assert "WEIGHTED-OK" in devices8(code)


def test_param_shardings_cover_zoo(devices8):
    """Every arch's param tree gets valid NamedShardings on a (4,2) mesh
    and a jitted forward lowers with them."""
    code = """
import jax, numpy as np
from repro.core.compat import make_mesh
from repro.configs import ARCHS, reduced_config
from repro.distributed import sharding as shd
from repro.models import abstract_params

mesh = make_mesh((4, 2), ("data", "model"))
for name in ARCHS:
    cfg = reduced_config(name)
    ap = abstract_params(cfg, tp=2)
    sh = shd.param_shardings(ap, cfg, mesh, fsdp=True)
    n = len(jax.tree.leaves(sh))
    assert n == len(jax.tree.leaves(ap))
print("PSPECS-OK")
"""
    assert "PSPECS-OK" in devices8(code)


def test_compressed_allreduce_roundtrip(devices8):
    """Wire-efficient int8 all-reduce (all_to_all + all_gather) vs f32."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import make_mesh, shard_map
from repro.distributed.compression import compressed_allreduce, compressed_psum

mesh = make_mesh((8,), ("data",))
x = jnp.arange(8 * 33, dtype=jnp.float32).reshape(8, 33) / 7.0  # odd: pad path

def body(xs):
    return compressed_allreduce(xs[0], "data", 8)[None]

y = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data")))(x)
ref = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 33))
err = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
assert err < 0.03, err       # two int8 quantizations

def body2(xs):
    return compressed_psum(xs, "data")
y2 = jax.jit(shard_map(body2, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data")))(x)
err2 = np.abs(np.asarray(y2) - ref).max() / np.abs(ref).max()
assert err2 < 0.01, err2
print("CPSUM-OK", float(err), float(err2))
"""
    assert "CPSUM-OK" in devices8(code)


def test_local_accum_step_parity(devices8):
    """shard_map local-grad accumulation == GSPMD reference step."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.configs import reduced_config
from repro.models import init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.state import init_state
from repro.train.step import make_train_step, make_local_accum_train_step

cfg = reduced_config("phi4-mini-3.8b")
oc = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=50)
mesh = make_mesh((4, 2), ("data", "model"))
params = init_params(jax.random.key(0), cfg)
toks = jax.random.randint(jax.random.key(7), (8, 33), 0, cfg.vocab_size)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

s_ref, _ = jax.jit(make_train_step(cfg, oc, accum_steps=2))(
    init_state(params), batch)
with mesh:
    s_new, m = jax.jit(make_local_accum_train_step(
        cfg, oc, mesh, accum_steps=2))(init_state(params), batch)
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_new.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-3, atol=3e-5)
# int8 wire-compressed variant trains (loss drops over 5 steps)
with mesh:
    sq = init_state(params)
    stq = jax.jit(make_local_accum_train_step(cfg, oc, mesh, accum_steps=2,
                                              int8_allreduce=True))
    losses = []
    for _ in range(5):
        sq, mq = stq(sq, batch)
        losses.append(float(mq["loss"]))
assert losses[-1] < losses[0]
print("LOCAL-ACCUM-OK")
"""
    assert "LOCAL-ACCUM-OK" in devices8(code)


def test_zero1_local_step_parity(devices8):
    """ZeRO-sharded manual-DP step == GSPMD reference (params after 1 step)."""
    code = """
import numpy as np, jax
from repro.core.compat import make_mesh
from repro.configs import reduced_config
from repro.models import init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.state import init_state
from repro.train.step import (make_train_step, make_local_accum_train_step,
                              make_zero1_local_state)

cfg = reduced_config("phi4-mini-3.8b")
oc = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=50)
mesh = make_mesh((4, 2), ("data", "model"))
params = init_params(jax.random.key(0), cfg)
toks = jax.random.randint(jax.random.key(7), (8, 33), 0, cfg.vocab_size)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
s_ref, _ = jax.jit(make_train_step(cfg, oc, accum_steps=2))(
    init_state(params), batch)
with mesh:
    sz = make_zero1_local_state(params, 4)
    stz = jax.jit(make_local_accum_train_step(cfg, oc, mesh, accum_steps=2,
                                              zero1=True))
    sz, _ = stz(sz, batch)
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(sz.params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-3, atol=5e-5)
print("ZERO1-OK")
"""
    assert "ZERO1-OK" in devices8(code)
