"""Unified loader layer: registry resolution + engine parity vs the
``csr_np`` host oracle on generated graphs."""
import os

import numpy as np
import pytest

from repro.core import (available_engines, get_engine, load_csr,
                        load_edgelist, register_engine)
from repro.core.build import csr_np
from repro.core.generate import write_edgelist
from repro.core.loader import _REGISTRY

ENGINES = ["device", "numpy", "threads", "pallas"]
# pallas runs the kernel in interpret mode — keep its inputs tiny
SMALL_KW = {"device": dict(beta=4096, batch_blocks=2),
            "pallas": dict(beta=2048, batch_blocks=2)}


# ---- registry ----------------------------------------------------------------

def test_builtin_engines_registered():
    assert set(ENGINES) <= set(available_engines())


def test_get_engine_unknown_lists_available():
    with pytest.raises(ValueError, match="numpy"):
        get_engine("no-such-engine")


def test_register_engine_last_wins_and_dispatches(tmp_path):
    class Fake:
        name = "fake-test-engine"

        def read_edgelist(self, path, **kw):
            from repro.core.types import EdgeList
            return EdgeList(np.array([7], np.int32), np.array([8], np.int32),
                            None, np.int64(1), 9)

    try:
        register_engine(Fake())
        el = load_edgelist("/nonexistent", engine="fake-test-engine")
        assert int(el.num_edges) == 1 and el.num_vertices == 9
    finally:
        _REGISTRY.pop("fake-test-engine", None)


# ---- engine parity vs host oracle -------------------------------------------

def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("weighted,base", [(False, 1), (False, 0),
                                           (True, 1), (True, 0)])
def test_load_csr_matches_oracle(tmp_path, engine, weighted, base):
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base,
                                seed=base + 2 * weighted)
    csr = load_csr(path, engine=engine, weighted=weighted, base=base,
                   num_vertices=v, **SMALL_KW.get(engine, {}))
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    off = np.asarray(oracle.offsets)
    for u in range(v):
        mine = np.sort(np.asarray(csr.targets[off[u]:off[u + 1]]))
        ref = np.sort(np.asarray(oracle.targets[off[u]:off[u + 1]]))
        assert np.array_equal(mine, ref), (engine, u)
    if weighted:
        # weights travel with their (src, dst) edge under any stable order
        for u in range(v):
            mine = sorted(zip(np.asarray(csr.targets[off[u]:off[u + 1]]).tolist(),
                              np.round(np.asarray(
                                  csr.weights[off[u]:off[u + 1]]), 3).tolist()))
            ref = sorted(zip(np.asarray(oracle.targets[off[u]:off[u + 1]]).tolist(),
                             np.round(np.asarray(
                                 oracle.weights[off[u]:off[u + 1]]), 3).tolist()))
            assert mine == ref, (engine, u)


@pytest.mark.parametrize("engine", ENGINES)
def test_load_edgelist_infers_num_vertices(tmp_path, engine):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=11)
    el = load_edgelist(path, engine=engine, **SMALL_KW.get(engine, {}))
    n = int(el.num_edges)
    assert n == e
    assert el.num_vertices == int(max(np.asarray(el.src[:n]).max(),
                                      np.asarray(el.dst[:n]).max())) + 1


@pytest.mark.parametrize("engine", ["device", "numpy", "threads"])
def test_empty_file(tmp_path, engine):
    path = str(tmp_path / "empty.el")
    open(path, "w").close()
    el = load_edgelist(path, engine=engine)
    assert int(el.num_edges) == 0
    csr = load_csr(path, engine=engine)
    assert csr.num_rows == 0
    assert np.asarray(csr.offsets).tolist() == [0]


def test_load_edgelist_offset_skips_header(tmp_path):
    path = str(tmp_path / "hdr.el")
    header = "9999 9999 9999\n"
    with open(path, "w") as f:
        f.write(header)
        f.write("1 2\n3 4\n")
    el = load_edgelist(path, engine="numpy", offset=len(header))
    n = int(el.num_edges)
    assert n == 2
    assert np.asarray(el.src[:n]).tolist() == [0, 2]


def test_symmetric_through_front_door(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=4)
    for engine in ["device", "numpy"]:
        el = load_edgelist(path, engine=engine, symmetric=True,
                           num_vertices=v, **SMALL_KW.get(engine, {}))
        assert int(el.num_edges) == 2 * e


@pytest.mark.slow
def test_streaming_device_csr_large_graph(tmp_path):
    """Acceptance: fused device load_csr == csr_np oracle on >= 1M edges,
    no host EdgeList in between (the fused path in loader.load_csr)."""
    from repro.core import make_graph_file, read_edgelist_numpy

    path = str(tmp_path / "big.el")
    v, e = make_graph_file(path, "rmat", scale=16, edge_factor=16, seed=1)
    assert e >= 1_000_000
    csr = load_csr(path, engine="device", num_vertices=v, method="staged")
    el = read_edgelist_numpy(path, num_vertices=v)
    n = int(el.num_edges)
    oracle = csr_np(np.asarray(el.src[:n]), np.asarray(el.dst[:n]), None, v)
    assert np.array_equal(np.asarray(csr.offsets, np.int64), oracle.offsets)
    off = oracle.offsets
    rng = np.random.default_rng(0)
    for u in rng.integers(0, v, 200):
        assert np.array_equal(
            np.sort(np.asarray(csr.targets[off[u]:off[u + 1]])),
            np.sort(oracle.targets[off[u]:off[u + 1]])), u
