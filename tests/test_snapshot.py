"""Binary ``.gvel`` snapshots: round-trip parity vs the ``csr_np`` host
oracle, malformed-file rejection, and loader-registry integration."""
import os
import struct

import numpy as np
import pytest

from repro.core import (available_engines, load_csr, load_edgelist,
                        read_snapshot, save_snapshot)
from repro.core.build import csr_np
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist
from repro.core.snapshot import (HEADER_FMT, MAGIC, SnapshotError, VERSION,
                                 is_snapshot)


def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


def _snapshot(tmp_path, text_path, *, weighted, base, v, with_csr=True):
    """text -> (EdgeList, host CSR) -> .gvel, the convert.py pipeline."""
    el = load_edgelist(text_path, engine="numpy", weighted=weighted,
                       base=base, num_vertices=v)
    csr = convert_to_csr(el, engine="numpy") if with_csr else None
    gv = str(tmp_path / (os.path.basename(text_path) + ".gvel"))
    save_snapshot(gv, edgelist=el, csr=csr)
    return gv, el


def _assert_rows_match(csr, oracle, v, *, weighted):
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    off = np.asarray(oracle.offsets)
    for u in range(v):
        mine = np.sort(np.asarray(csr.targets[off[u]:off[u + 1]]))
        ref = np.sort(np.asarray(oracle.targets[off[u]:off[u + 1]]))
        assert np.array_equal(mine, ref), u
    if weighted:
        for u in range(v):
            mine = sorted(zip(
                np.asarray(csr.targets[off[u]:off[u + 1]]).tolist(),
                np.round(np.asarray(csr.weights[off[u]:off[u + 1]]), 3).tolist()))
            ref = sorted(zip(
                np.asarray(oracle.targets[off[u]:off[u + 1]]).tolist(),
                np.round(np.asarray(oracle.weights[off[u]:off[u + 1]]), 3).tolist()))
            assert mine == ref, u


# ---- registry ----------------------------------------------------------------

def test_snapshot_engine_registered():
    assert "snapshot" in available_engines()


# ---- round trip --------------------------------------------------------------

@pytest.mark.parametrize("weighted,base", [(False, 1), (False, 0),
                                           (True, 1), (True, 0)])
def test_roundtrip_prebuilt_csr_parity(tmp_path, weighted, base):
    """text -> .gvel (CSR embedded) -> load_csr == csr_np oracle, exactly:
    the stored CSR *is* the host-oracle build, served back via mmap."""
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base,
                                seed=base + 2 * weighted)
    gv, _ = _snapshot(tmp_path, path, weighted=weighted, base=base, v=v)
    csr = load_csr(gv, engine="snapshot", weighted=weighted)
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    assert np.array_equal(np.asarray(csr.targets), np.asarray(oracle.targets))
    if weighted:
        assert np.allclose(np.asarray(csr.weights), np.asarray(oracle.weights))
    else:
        assert csr.weights is None


@pytest.mark.parametrize("weighted,base", [(False, 1), (True, 0)])
def test_roundtrip_edgelist_only_builds_csr(tmp_path, weighted, base):
    """Edgelist-only snapshot: load_csr falls back to the fused device
    build over the mmap'd sections; rows match the oracle."""
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base, seed=7)
    gv, _ = _snapshot(tmp_path, path, weighted=weighted, base=base, v=v,
                      with_csr=False)
    csr = load_csr(gv, engine="snapshot", weighted=weighted)
    _assert_rows_match(csr, oracle, v, weighted=weighted)


def test_roundtrip_edgelist_views(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=True, base=1, seed=3)
    gv, el = _snapshot(tmp_path, path, weighted=True, base=1, v=v)
    el2 = load_edgelist(gv, engine="snapshot", weighted=True)
    n = int(el2.num_edges)
    assert n == e and el2.num_vertices == v
    assert np.array_equal(np.asarray(el2.src[:n]), np.asarray(el.src))
    assert np.array_equal(np.asarray(el2.dst[:n]), np.asarray(el.dst))
    assert np.allclose(np.asarray(el2.weights[:n]), np.asarray(el.weights))


def test_front_door_autodetects_gvel(tmp_path):
    """load_csr/load_edgelist sniff the magic: a .gvel passed with the
    default (text) engine routes to the snapshot engine."""
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=9)
    gv, _ = _snapshot(tmp_path, path, weighted=False, base=1, v=v)
    csr = load_csr(gv)                        # default engine="device"
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    el = load_edgelist(gv)                    # default engine="numpy"
    assert int(el.num_edges) == e


def test_isolated_trailing_vertices_preserved(tmp_path):
    """|V| comes from the header, not a max-id scan: vertices past the
    last referenced id survive the round trip."""
    path = str(tmp_path / "iso.el")
    write_edgelist(path, [0, 1], [1, 0], base=1)
    el = load_edgelist(path, engine="numpy", num_vertices=10)
    gv = str(tmp_path / "iso.gvel")
    save_snapshot(gv, edgelist=el)
    csr = load_csr(gv, engine="snapshot")
    assert csr.num_vertices == 10 and csr.num_rows == 10


def test_empty_graph_roundtrip(tmp_path):
    empty = str(tmp_path / "empty.el")
    open(empty, "w").close()
    el = load_edgelist(empty, engine="numpy")
    gv = str(tmp_path / "empty.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"))
    csr = load_csr(gv, engine="snapshot")
    assert csr.num_rows == 0
    assert np.asarray(csr.offsets).tolist() == [0]


def test_csr_only_snapshot(tmp_path):
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=5)
    gv = str(tmp_path / "csr_only.gvel")
    save_snapshot(gv, csr=oracle)
    csr = load_csr(gv, engine="snapshot")
    assert np.array_equal(np.asarray(csr.targets), np.asarray(oracle.targets))
    with pytest.raises(SnapshotError, match="CSR-only"):
        load_edgelist(gv, engine="snapshot")


# ---- validation / rejection --------------------------------------------------

def _valid_snapshot(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=1)
    gv, _ = _snapshot(tmp_path, path, weighted=False, base=1, v=v)
    return gv


def test_is_snapshot_sniff(tmp_path):
    gv = _valid_snapshot(tmp_path)
    assert is_snapshot(gv)
    assert not is_snapshot(str(tmp_path / "g_False_1.el"))
    assert not is_snapshot(str(tmp_path / "missing.gvel"))


def test_bad_magic_rejected(tmp_path):
    gv = _valid_snapshot(tmp_path)
    with open(gv, "r+b") as f:
        f.write(b"NOTGVEL!")
    with pytest.raises(SnapshotError, match="magic"):
        read_snapshot(gv)
    # and a text engine never sees the binary: the front door raises too
    with pytest.raises(SnapshotError, match="magic"):
        load_csr(gv, engine="snapshot")


def test_version_mismatch_rejected(tmp_path):
    # version 2 is now supported (compressed sections); 99 is not
    gv = _valid_snapshot(tmp_path)
    with open(gv, "r+b") as f:
        f.seek(len(MAGIC))
        f.write(struct.pack("<I", 99))
    with pytest.raises(SnapshotError, match="version"):
        read_snapshot(gv)


def test_truncated_file_rejected(tmp_path):
    gv = _valid_snapshot(tmp_path)
    size = os.path.getsize(gv)
    with open(gv, "r+b") as f:
        f.truncate(size // 2)               # cuts into the section data
    with pytest.raises(SnapshotError, match="truncated"):
        read_snapshot(gv)
    with open(gv, "r+b") as f:
        f.truncate(16)                      # cuts into the header itself
    with pytest.raises(SnapshotError, match="truncated"):
        read_snapshot(gv)


def test_weighted_request_on_unweighted_rejected(tmp_path):
    gv = _valid_snapshot(tmp_path)
    with pytest.raises(SnapshotError, match="unweighted"):
        load_csr(gv, engine="snapshot", weighted=True)


def test_save_rejects_mismatched_el_csr(tmp_path):
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=2)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    half = int(el.num_edges) // 2
    short = csr_np(np.asarray(el.src[:half]), np.asarray(el.dst[:half]),
                   None, v)
    with pytest.raises(ValueError, match="edges"):
        save_snapshot(str(tmp_path / "bad.gvel"), edgelist=el, csr=short)
    with pytest.raises(ValueError, match="needs"):
        save_snapshot(str(tmp_path / "none.gvel"))


def test_header_declares_counts(tmp_path):
    gv = _valid_snapshot(tmp_path)
    snap = read_snapshot(gv)
    assert snap.version == VERSION
    assert snap.num_edges == 400 and snap.num_vertices == 60
    assert snap.has_edgelist and snap.has_csr and not snap.weighted
    # sections are page-aligned views into the mmap, not copies
    assert not snap.src.flags.writeable
    assert snap.src.dtype == np.int32 and snap.csr_offsets.dtype == np.int64
