"""Data pipeline: walks over GVEL CSR, prefetcher, determinism."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.core import convert_to_csr, make_graph_file, read_edgelist_numpy
from repro.data.pipeline import Prefetcher
from repro.data.walks import random_walks, walk_batch

CFG = reduced_config("phi4-mini-3.8b")


@pytest.fixture(scope="module")
def csr(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("g") / "g.el")
    v, e = make_graph_file(path, "rmat", scale=8, edge_factor=8, seed=11)
    el = read_edgelist_numpy(path, num_vertices=v)
    return convert_to_csr(el, method="staged")


def test_walks_follow_edges(csr):
    import jax
    off = jnp.asarray(np.asarray(csr.offsets), jnp.int32)
    tgt = jnp.asarray(csr.targets)
    walks = random_walks(off, tgt, jax.random.key(0), num_walks=16,
                         length=12, num_vertices=csr.num_vertices)
    w = np.asarray(walks)
    offs = np.asarray(csr.offsets)
    tgts = np.asarray(csr.targets)
    edges_ok = self_loops = 0
    for row in w:
        for a, b in zip(row[:-1], row[1:]):
            nbrs = tgts[offs[a]:offs[a + 1]]
            if b in nbrs:
                edges_ok += 1
            else:                          # self-loop only at dead ends
                assert len(nbrs) == 0 and b == a
                self_loops += 1
    assert edges_ok > 0


def test_walk_batch_shape_and_determinism(csr):
    b1 = walk_batch(csr, CFG, 4, 16, step=3)
    b2 = walk_batch(csr, CFG, 4, 16, step=3)
    b3 = walk_batch(csr, CFG, 4, 16, step=4)
    assert b1["tokens"].shape == (4, 16)
    assert (np.asarray(b1["tokens"]) < CFG.vocab_size).all()
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    assert np.array_equal(np.asarray(b1["labels"][:, :-1]),
                          np.asarray(b1["tokens"][:, 1:]))


def test_prefetcher_orders_steps():
    seen = []

    def source(step):
        seen.append(step)
        return {"x": np.full((2,), step)}

    pf = Prefetcher(source, start_step=0, lookahead=2)
    try:
        for i in range(5):
            b = pf.get(expect_step=i)
            assert int(np.asarray(b["x"])[0]) == i
    finally:
        pf.close()


def test_prefetcher_desync_raises():
    pf = Prefetcher(lambda s: {"x": np.zeros(1)}, start_step=3)
    try:
        with pytest.raises(RuntimeError):
            pf.get(expect_step=99)
    finally:
        pf.close()
