"""Bounded decoded-frame cache on the selective-read path.

A serving handle (``GraphSource`` pinned hot by ``SourceCache``) decodes
compressed ``.gvel`` sections frame by frame for point reads and memoizes
the decoded frames.  The memo must be a *bounded* LRU
(``snapshot.FRAME_CACHE_BYTES``): a point-read hammer across a large
section must stay under the byte cap (evicting cold frames) while every
answer stays correct, and the hot-graph cache must surface the pinned
bytes / evictions in its ``stats()``.
"""
import numpy as np

from repro.core import load_edgelist, open_graph, save_snapshot, snapshot
from repro.core.build import csr_np
from repro.core.cache import SourceCache
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist

FRAME_BETA = 96


def _snapshot(tmp_path, name, *, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    el_path = str(tmp_path / f"{name}.el")
    write_edgelist(el_path, src, dst, None, base=1)
    el = load_edgelist(el_path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / f"{name}.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress="zlib", frame_beta=FRAME_BETA)
    return gv, v, csr_np(src, dst, None, v)


def _hammer(source, v, oracle, rounds=3):
    off = np.asarray(oracle.offsets)
    tgt = np.asarray(oracle.targets)
    for _ in range(rounds):
        for u in range(v):
            got = source.neighbors(u)
            assert np.array_equal(got, tgt[off[u]:off[u + 1]]), u


def test_point_read_hammer_stays_under_cap(tmp_path, monkeypatch):
    cap = 4 * FRAME_BETA                 # room for ~4 decoded frames/section
    monkeypatch.setattr(snapshot, "FRAME_CACHE_BYTES", cap)
    gv, v, oracle = _snapshot(tmp_path, "hammer", e=1500)
    src = open_graph(gv)
    _hammer(src, v, oracle)
    stats = src.frame_cache_stats()
    # csr_indices alone spans ~60 frames; unbounded memoization would
    # hold them all.  Bound is per section; offsets + indices touched.
    assert stats["bytes"] <= 2 * cap
    assert stats["evictions"] > 0        # the hammer cycled the cache
    assert stats["hits"] > 0             # but locality still paid
    assert stats["frames"] * FRAME_BETA <= 2 * cap + 2 * FRAME_BETA


def test_unbounded_before_cap_is_reachable(tmp_path, monkeypatch):
    """With a roomy cap the whole touched span stays memoized (no
    evictions) — the bound only bites when memory pressure is real."""
    monkeypatch.setattr(snapshot, "FRAME_CACHE_BYTES", 32 << 20)
    gv, v, oracle = _snapshot(tmp_path, "roomy", e=1500)
    src = open_graph(gv)
    _hammer(src, v, oracle, rounds=2)
    stats = src.frame_cache_stats()
    assert stats["evictions"] == 0
    assert stats["bytes"] > 0


def test_full_decode_drops_frame_memos(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "full")
    src = open_graph(gv)
    src.neighbors(3)                     # seeds some frame memos
    snap = src._selective_snap()
    assert snap.frame_cache_stats()["bytes"] > 0
    csr = snap.csr()                     # full decode supersedes the memos
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    assert snap.frame_cache_stats()["bytes"] == 0
    assert src.frame_cache_stats()["frames"] == 0


def test_source_cache_surfaces_frame_stats(tmp_path, monkeypatch):
    cap = 4 * FRAME_BETA
    monkeypatch.setattr(snapshot, "FRAME_CACHE_BYTES", cap)
    gv, v, oracle = _snapshot(tmp_path, "served", e=1500)
    c = SourceCache(capacity=4)
    for u in range(v):
        c.query(gv, "neighbors", vertex=u)
    fc = c.stats()["frame_cache"]
    assert fc["bytes"] > 0 and fc["bytes"] <= 2 * cap
    assert fc["evictions"] > 0
    # non-snapshot sources contribute nothing (and don't break stats)
    el = str(tmp_path / "plain.el")
    write_edgelist(el, np.asarray([1, 2], np.int32),
                   np.asarray([2, 3], np.int32), None, base=1)
    c.query(el, "degree", vertex=0)
    assert c.stats()["frame_cache"]["bytes"] <= 2 * cap
