import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, os.path.abspath(SRC))

# Smoke tests and benches must see exactly 1 device (the dry-run sets its
# own 512-device flag in a separate process).
os.environ.pop("XLA_FLAGS", None)


def run_devices_subprocess(code: str, num_devices: int = 8,
                           timeout: int = 560) -> str:
    """Run a python snippet under --xla_force_host_platform_device_count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices8():
    return run_devices_subprocess


@pytest.fixture(scope="session")
def devices4():
    def run(code: str, timeout: int = 560) -> str:
        return run_devices_subprocess(code, num_devices=4, timeout=timeout)
    return run
