"""Compressed inputs through every loading path: engine x codec CSR
parity vs the ``csr_np`` oracle (deterministic matrix + hypothesis
property suite), compressed ``.gvel`` v2 round-trips, v1 back-compat,
and the corruption matrix routed through the loader front door."""
import gzip
import os
import struct

import numpy as np
import pytest

from repro.core import (codecs, load_csr, load_edgelist, read_snapshot,
                        save_snapshot, write_framed)
from repro.core.build import csr_np
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist
from repro.core.snapshot import SnapshotError, VERSION, VERSION_COMPRESSED

HOST_ENGINES = ["numpy", "threads"]
DEVICE_ENGINES = ["device", "pallas"]
# same staging shapes as test_loader.py, so jitted programs are reused
# across tests; framed files force beta to their frame size, so the
# frame_beta below must match the engine's beta
SMALL_KW = {"device": dict(beta=4096, batch_blocks=2),
            "pallas": dict(beta=2048, batch_blocks=2)}
FRAME_BETA = {"device": 4096, "pallas": 2048}

FORMATS = ["raw", "gzip", "framed-zlib", "framed-zstd"]


def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


def _compressed(path, fmt, frame_beta=4096):
    """Materialize ``path`` in the given format; returns the new path."""
    if fmt == "raw":
        return path
    raw = open(path, "rb").read()
    if fmt == "gzip":
        out = path + ".gz"
        with open(out, "wb") as f:
            f.write(gzip.compress(raw))
        return out
    codec = fmt.split("-")[1]
    if codec == "zstd":
        pytest.importorskip("zstandard")
    out = path + f".{codec}.elz"
    write_framed(out, raw, codec=codec, frame_beta=frame_beta)
    return out


def _assert_rows_match(csr, oracle, v, *, weighted):
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    off = np.asarray(oracle.offsets)
    for u in range(v):
        mine = np.sort(np.asarray(csr.targets[off[u]:off[u + 1]]))
        ref = np.sort(np.asarray(oracle.targets[off[u]:off[u + 1]]))
        assert np.array_equal(mine, ref), u
    if weighted:
        for u in range(v):
            mine = sorted(zip(
                np.asarray(csr.targets[off[u]:off[u + 1]]).tolist(),
                np.round(np.asarray(csr.weights[off[u]:off[u + 1]]), 3).tolist()))
            ref = sorted(zip(
                np.asarray(oracle.targets[off[u]:off[u + 1]]).tolist(),
                np.round(np.asarray(oracle.weights[off[u]:off[u + 1]]), 3).tolist()))
            assert mine == ref, u


# ---- engine x codec parity matrix -------------------------------------------

@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("engine", HOST_ENGINES)
@pytest.mark.parametrize("weighted,base", [(False, 1), (False, 0),
                                           (True, 1), (True, 0)])
def test_host_engines_compressed_parity(tmp_path, engine, fmt, weighted, base):
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base,
                                seed=base + 2 * weighted)
    cpath = _compressed(path, fmt)
    csr = load_csr(cpath, engine=engine, weighted=weighted, base=base,
                   num_vertices=v)
    _assert_rows_match(csr, oracle, v, weighted=weighted)
    el = load_edgelist(cpath, engine=engine, weighted=weighted, base=base)
    assert int(el.num_edges) == e


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("engine", DEVICE_ENGINES)
@pytest.mark.parametrize("weighted,base", [(False, 1), (True, 0)])
def test_streaming_engines_compressed_parity(tmp_path, engine, fmt, weighted,
                                             base):
    """The fused device path over compressed inputs: decompression runs
    in the prefetch thread, frames map 1:1 onto staging blocks."""
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base,
                                seed=base + 2 * weighted)
    cpath = _compressed(path, fmt, frame_beta=FRAME_BETA[engine])
    csr = load_csr(cpath, engine=engine, weighted=weighted, base=base,
                   num_vertices=v, **SMALL_KW[engine])
    _assert_rows_match(csr, oracle, v, weighted=weighted)


@pytest.mark.parametrize("fmt", ["gzip", "framed-zlib"])
@pytest.mark.parametrize("engine", ["numpy", "device"])
def test_empty_compressed_file(tmp_path, engine, fmt):
    path = str(tmp_path / "empty.el")
    open(path, "w").close()
    cpath = _compressed(path, fmt)
    el = load_edgelist(cpath, engine=engine)
    assert int(el.num_edges) == 0
    csr = load_csr(cpath, engine=engine)
    assert np.asarray(csr.offsets).tolist() == [0]


def test_offset_applies_after_decompression(tmp_path):
    """MTX-style body offsets are in uncompressed coordinates."""
    header = "9999 9999 9999\n"
    path = str(tmp_path / "hdr.el")
    with open(path, "w") as f:
        f.write(header + "1 2\n3 4\n")
    for fmt in ("gzip", "framed-zlib"):
        cpath = _compressed(path, fmt, frame_beta=4096)
        for engine, kw in (("numpy", {}), ("device", SMALL_KW["device"])):
            el = load_edgelist(cpath, engine=engine, offset=len(header), **kw)
            n = int(el.num_edges)
            assert n == 2, (fmt, engine)
            assert sorted(np.asarray(el.src[:n]).tolist()) == [0, 2]


# ---- property suite: random messy edgelists, all engines x codecs -----------

def test_property_parity_across_engines_and_codecs(tmp_path):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    edges_st = st.lists(
        st.tuples(st.integers(0, 199), st.integers(0, 199),
                  st.floats(min_value=0, max_value=99,
                            allow_nan=False).map(lambda x: round(x, 2))),
        min_size=0, max_size=80)

    def render(edges, *, weighted, base, seed):
        """Messy but parseable text: mixed separators, CRLF line ends,
        comment lines, blank lines, trailing garbage."""
        rng = np.random.default_rng(seed)
        lines = []
        for u, v, w in edges:
            sep = [" ", "\t", "  "][rng.integers(0, 3)]
            line = f"{u + base}{sep}{v + base}"
            if weighted:
                line += f"{sep}{w}"
            if rng.random() < 0.2:
                line += "\r"                     # CRLF
            lines.append(line)
            if rng.random() < 0.1:
                lines.append("# a comment line")
            if rng.random() < 0.1:
                lines.append("")
        if rng.random() < 0.5:
            lines.append("trailing garbage!")
        return ("\n".join(lines) + "\n").encode()

    counter = [0]

    @settings(max_examples=12, deadline=None)
    @given(edges=edges_st, weighted=st.booleans(), base=st.integers(0, 1))
    def prop(edges, weighted, base):
        counter[0] += 1
        v = 200
        src = np.array([u for u, _, _ in edges], np.int32)
        dst = np.array([d for _, d, _ in edges], np.int32)
        w = (np.array([x for _, _, x in edges], np.float32)
             if weighted else None)
        oracle = csr_np(src, dst, w, v)
        text = render(edges, weighted=weighted, base=base, seed=len(edges))
        path = str(tmp_path / f"p{counter[0]}.el")
        with open(path, "wb") as f:
            f.write(text)
        for fmt in FORMATS:
            if fmt == "framed-zstd" and "zstd" not in codecs.available_codecs():
                continue
            cpath = _compressed(path, fmt, frame_beta=4096)
            for engine, kw in (("numpy", {}), ("threads", {}),
                               ("device", SMALL_KW["device"])):
                csr = load_csr(cpath, engine=engine, weighted=weighted,
                               base=base, num_vertices=v, **kw)
                _assert_rows_match(csr, oracle, v, weighted=weighted)

    prop()


# ---- compressed .gvel v2 -----------------------------------------------------

def _codec_params():
    return ["zlib", pytest.param("zstd", marks=pytest.mark.skipif(
        "zstd" not in codecs.available_codecs(),
        reason="zstandard not installed"))]


@pytest.mark.parametrize("codec", _codec_params())
@pytest.mark.parametrize("weighted", [False, True])
def test_compressed_snapshot_prebuilt_csr_exact(tmp_path, codec, weighted):
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=1, seed=3)
    el = load_edgelist(path, engine="numpy", weighted=weighted,
                       num_vertices=v)
    gv = str(tmp_path / "g.z.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress=codec, frame_beta=2048)
    snap = read_snapshot(gv)
    assert snap.version == VERSION_COMPRESSED
    csr = load_csr(gv, weighted=weighted)        # front door autodetects
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    assert np.array_equal(np.asarray(csr.targets), np.asarray(oracle.targets))
    if weighted:
        assert np.allclose(np.asarray(csr.weights), np.asarray(oracle.weights))


def test_compressed_snapshot_edgelist_only_fused_build(tmp_path):
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=8)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.el.z.gvel")
    save_snapshot(gv, edgelist=el, compress="zlib")
    csr = load_csr(gv)
    _assert_rows_match(csr, oracle, v, weighted=False)
    el2 = load_edgelist(gv)
    n = int(el2.num_edges)
    assert np.array_equal(np.asarray(el2.src[:n]), np.asarray(el.src))


def test_uncompressed_save_still_writes_v1(tmp_path):
    """Forward/backward compat: no compression -> a version-1 file any
    pre-v2 reader can load."""
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=2)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.gvel")
    save_snapshot(gv, edgelist=el)
    assert read_snapshot(gv).version == VERSION


def test_handwritten_v1_file_still_loads(tmp_path):
    """A minimal v1 file written with raw struct calls (the format-spec
    worked example) loads unchanged under the v2-aware reader."""
    src = np.array([0, 1, 2], "<i4")
    dst = np.array([1, 2, 0], "<i4")
    sections = [(1, 1, src), (2, 1, dst)]
    table, off = [], 40 + 24 * len(sections)
    for sid, code, arr in sections:
        off = -(-off // 4096) * 4096
        table.append((sid, code, off, arr.nbytes))
        off += arr.nbytes
    gv = str(tmp_path / "tiny.gvel")
    with open(gv, "wb") as f:
        f.write(struct.pack("<8sIIQQII", b"GVELSNAP", 1, 0b010, 3, 3,
                            len(sections), 0))
        for entry in table:
            f.write(struct.pack("<IIQQ", *entry))
        for (sid, code, arr), (_, _, soff, _) in zip(sections, table):
            f.seek(soff)
            f.write(arr.tobytes())
        f.truncate(off)
    el = load_edgelist(gv)
    assert int(el.num_edges) == 3
    assert np.asarray(el.src[:3]).tolist() == [0, 1, 2]


def test_compressed_snapshot_smaller_on_repetitive_data(tmp_path):
    """The point of the feature: compressible graphs shrink on disk."""
    v, e = 100, 20000
    src = np.arange(e, dtype=np.int64) % v       # highly regular
    dst = (np.arange(e, dtype=np.int64) + 1) % v
    path = str(tmp_path / "reg.el")
    write_edgelist(path, src, dst, base=1)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    raw_gv = str(tmp_path / "reg.gvel")
    z_gv = str(tmp_path / "reg.z.gvel")
    save_snapshot(raw_gv, edgelist=el)
    save_snapshot(z_gv, edgelist=el, compress="zlib")
    assert os.path.getsize(z_gv) < os.path.getsize(raw_gv)


# ---- corruption matrix through the loader ------------------------------------

def test_truncated_framed_input_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=4)
    cpath = _compressed(path, "framed-zlib", frame_beta=1024)
    with open(cpath, "r+b") as f:
        f.truncate(os.path.getsize(cpath) - 9)
    with pytest.raises(ValueError, match="truncated"):
        load_csr(cpath, engine="numpy", num_vertices=v)
    with pytest.raises(ValueError, match="truncated"):
        load_csr(cpath, engine="device", num_vertices=v,
                 **SMALL_KW["device"])


def test_bitflipped_framed_input_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=4)
    cpath = _compressed(path, "framed-zlib", frame_beta=1024)
    with open(cpath, "r+b") as f:
        f.seek(codecs.FRAMED_HDR_LEN + codecs.FRAME_HDR_LEN + 20)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x10]))
    with pytest.raises(ValueError):
        load_csr(cpath, engine="numpy", num_vertices=v)


def test_truncated_gzip_input_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=4)
    cpath = _compressed(path, "gzip")
    with open(cpath, "r+b") as f:
        f.truncate(os.path.getsize(cpath) // 2)
    with pytest.raises(ValueError, match="gzip"):
        load_csr(cpath, engine="numpy", num_vertices=v)


def test_multimember_gzip_streaming_rejected_host_ok(tmp_path):
    """Multi-member gzip lies about its uncompressed length (ISIZE is
    the last member only): the streaming engine must refuse rather than
    drop edges; the host engines decompress fully and succeed."""
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=6)
    raw = open(path, "rb").read()
    half = raw.rfind(b"\n", 0, len(raw) // 2) + 1
    cpath = path + ".gz"
    with open(cpath, "wb") as f:
        f.write(gzip.compress(raw[:half]) + gzip.compress(raw[half:]))
    csr = load_csr(cpath, engine="numpy", num_vertices=v)
    _assert_rows_match(csr, oracle, v, weighted=False)
    with pytest.raises(ValueError, match="multi-member"):
        load_csr(cpath, engine="device", num_vertices=v, **SMALL_KW["device"])


def test_corrupt_compressed_snapshot_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.z.gvel")
    save_snapshot(gv, edgelist=el, compress="zlib")
    # bit-flip inside the first section's compressed payload
    with open(gv, "r+b") as f:
        f.seek(4096 + 30)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x20]))
    with pytest.raises(SnapshotError):
        read_snapshot(gv)
    with pytest.raises(SnapshotError):
        load_csr(gv)


def test_unknown_codec_id_in_snapshot_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.z.gvel")
    save_snapshot(gv, edgelist=el, compress="zlib")
    # first v2 table entry: sid u32, dtype u32, offset u64, nbytes u64,
    # codec_id u32 at entry offset 24
    with open(gv, "r+b") as f:
        f.seek(40 + 24)
        f.write(struct.pack("<I", 99))
    with pytest.raises(SnapshotError, match="unknown codec id 99"):
        read_snapshot(gv)


def test_truncated_compressed_snapshot_rejected(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.z.gvel")
    save_snapshot(gv, edgelist=el, compress="zlib")
    with open(gv, "r+b") as f:
        f.truncate(os.path.getsize(gv) - 11)
    with pytest.raises(SnapshotError, match="truncated"):
        read_snapshot(gv)


def test_externally_compressed_snapshot_clear_error(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.gvel")
    save_snapshot(gv, edgelist=el)
    gz = gv + ".gz"
    with open(gz, "wb") as f:
        f.write(gzip.compress(open(gv, "rb").read()))
    with pytest.raises(ValueError, match="compressed .gvel"):
        load_csr(gz)
    with pytest.raises(ValueError, match="--compress"):
        load_edgelist(gz)


# ---- compressed MTX ----------------------------------------------------------

@pytest.mark.parametrize("fmt", ["gzip", "framed-zlib"])
def test_compressed_mtx_roundtrip(tmp_path, fmt):
    from repro.core import mtx_to_snapshot, read_mtx, write_mtx

    rng = np.random.default_rng(7)
    v, e = 40, 200
    src, dst = rng.integers(0, v, e), rng.integers(0, v, e)
    m = str(tmp_path / "m.mtx")
    write_mtx(m, src, dst, num_vertices=v)
    mz = _compressed(m, fmt, frame_beta=512)
    el = read_mtx(mz)
    assert int(el.num_edges) == e and el.num_vertices == v
    gv = str(tmp_path / "m.gvel")
    mtx_to_snapshot(mz, gv, compress="zlib")
    snap = read_snapshot(gv)
    assert snap.version == VERSION_COMPRESSED and snap.num_edges == e
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), None, v)
    _assert_rows_match(load_csr(gv), oracle, v, weighted=False)
