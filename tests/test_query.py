"""Selective loading: ``.csr(rows=)`` / ``.neighbors(v)`` / ``.degree(v)``
parity against full ``csr_np`` oracle slices across {raw, zlib-framed,
zstd-framed} x weighted x base, edge rows (empty range, single vertex,
last vertex, isolated vertices, frame-boundary spans, full-range ==
``.csr()`` bitwise), fallback paths (text, edgelist-only snapshots,
``num_vertices`` overrides), the snapshot-engine selective hooks, and a
slice-of-full == partial-load Hypothesis property."""
import os

import numpy as np
import pytest

from repro.core import (codecs, get_engine, load_edgelist, open_graph,
                        save_snapshot)
from repro.core.build import csr_np
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist
from repro.core.snapshot import SnapshotError
from repro.core.source import slice_csr

FMTS = ["raw", "zlib", "zstd"]
# small frames force multi-frame sections so row ranges exercise the
# seek-and-decode path, not a degenerate one-frame stream
FRAME_BETA = 96


def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    """Random multigraph; the last 3 vertices are never endpoints, so
    every snapshot has isolated rows at the tail."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v - 3, e)
    dst = rng.integers(0, v - 3, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}_{seed}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, oracle


def _snapshot(tmp_path, fmt, *, weighted=False, base=1, seed=0,
              frame_beta=FRAME_BETA, v=60, e=400):
    if fmt == "zstd":
        pytest.importorskip("zstandard")
    path, v, oracle = _graph(tmp_path, weighted=weighted, base=base,
                             seed=seed, v=v, e=e)
    el = load_edgelist(path, engine="numpy", weighted=weighted,
                       num_vertices=v, base=base)
    gv = str(tmp_path / f"q_{fmt}_{weighted}_{base}_{seed}.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress=None if fmt == "raw" else fmt,
                  frame_beta=frame_beta)
    return gv, v, oracle


def _expect(oracle, lo, hi):
    e_lo, e_hi = int(oracle.offsets[lo]), int(oracle.offsets[hi])
    off = oracle.offsets[lo:hi + 1] - oracle.offsets[lo]
    w = None if oracle.weights is None else oracle.weights[e_lo:e_hi]
    return off, oracle.targets[e_lo:e_hi], w


def _assert_rows(part, oracle, lo, hi):
    off, tgt, w = _expect(oracle, lo, hi)
    assert part.row_start == lo
    assert part.num_vertices == oracle.num_vertices
    assert part.offsets.dtype == oracle.offsets.dtype
    assert part.targets.dtype == oracle.targets.dtype
    assert np.array_equal(part.offsets, off)
    assert np.array_equal(part.targets, tgt)
    if w is None:
        assert part.weights is None
    else:
        assert part.weights.dtype == w.dtype
        assert np.array_equal(part.weights, w)


# ---- parity matrix: formats x weighted x base --------------------------------

@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("base", [0, 1])
def test_rows_and_points_parity(tmp_path, fmt, weighted, base):
    gv, v, oracle = _snapshot(tmp_path, fmt, weighted=weighted, base=base)
    s = open_graph(gv)
    ranges = [(7, 7),            # empty
              (0, 0),            # empty at the origin
              (5, 6),            # single vertex
              (v - 1, v),        # last vertex (isolated)
              (v - 3, v),        # the all-isolated tail
              (17, 43),          # interior span
              (0, v)]            # full range
    for lo, hi in ranges:
        _assert_rows(s.csr(rows=(lo, hi)), oracle, lo, hi)
    for u in (0, 5, 29, v - 3, v - 1):
        e_lo, e_hi = int(oracle.offsets[u]), int(oracle.offsets[u + 1])
        assert np.array_equal(s.neighbors(u), oracle.targets[e_lo:e_hi])
        assert s.degree(u) == e_hi - e_lo
        if weighted:
            ids, w = s.neighbors(u, with_weights=True)
            assert np.array_equal(ids, oracle.targets[e_lo:e_hi])
            assert np.array_equal(w, oracle.weights[e_lo:e_hi])
    for u in (v - 3, v - 2, v - 1):       # isolated: empty, degree 0
        assert s.neighbors(u).size == 0
        assert s.degree(u) == 0


@pytest.mark.parametrize("fmt", FMTS)
def test_full_range_matches_csr_bitwise(tmp_path, fmt):
    gv, v, _ = _snapshot(tmp_path, fmt, weighted=True)
    s = open_graph(gv)
    full, part = s.csr(), s.csr(rows=(0, v))
    assert part.row_start == 0
    for a, b in ((full.offsets, part.offsets), (full.targets, part.targets),
                 (full.weights, part.weights)):
        assert a.dtype == b.dtype
        assert a.tobytes() == b.tobytes()


def test_range_object_and_pair_equivalent(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "zlib")
    s = open_graph(gv)
    a, b = s.csr(rows=range(11, 37)), s.csr(rows=(11, 37))
    assert np.array_equal(a.offsets, b.offsets)
    assert np.array_equal(a.targets, b.targets)
    _assert_rows(a, oracle, 11, 37)


def test_raw_rows_are_mmap_views(tmp_path):
    """Raw snapshots serve row slices zero-copy: two slices of the same
    handle are windows into one mapping, and are read-only."""
    gv, v, _ = _snapshot(tmp_path, "raw")
    s = open_graph(gv)
    a, b = s.csr(rows=(0, v)), s.csr(rows=(10, 20))
    assert np.shares_memory(a.targets, b.targets)
    assert not a.targets.flags.writeable
    with pytest.raises(ValueError):
        b.targets[0] = 1


# ---- validation --------------------------------------------------------------

def test_bad_rows_rejected(tmp_path):
    gv, v, _ = _snapshot(tmp_path, "raw")
    s = open_graph(gv)
    with pytest.raises(ValueError):
        s.csr(rows=range(0, 10, 2))          # stride
    with pytest.raises(ValueError):
        s.csr(rows=(7, 3))                   # reversed
    with pytest.raises(ValueError):
        s.csr(rows="0:10")                   # not a range
    with pytest.raises(IndexError):
        s.csr(rows=(0, v + 1))
    with pytest.raises(IndexError):
        s.csr(rows=(-1, 3))
    for u in (-1, v):
        with pytest.raises(IndexError):
            s.neighbors(u)
        with pytest.raises(IndexError):
            s.degree(u)


def test_with_weights_on_unweighted_raises(tmp_path):
    gv, _, _ = _snapshot(tmp_path, "zlib", weighted=False)
    s = open_graph(gv)
    with pytest.raises(ValueError, match="unweighted"):
        s.neighbors(3, with_weights=True)


# ---- fallback paths: same results without the selective fast path ------------

def test_text_source_fallback_parity(tmp_path):
    path, v, oracle = _graph(tmp_path, weighted=True, base=1)
    s = open_graph(path, engine="numpy", weighted=True, num_vertices=v)
    _assert_rows(s.csr(rows=(9, 31)), oracle, 9, 31)
    _assert_rows(s.csr(rows=(0, v)), oracle, 0, v)
    u = 13
    e_lo, e_hi = int(oracle.offsets[u]), int(oracle.offsets[u + 1])
    assert np.array_equal(s.neighbors(u), oracle.targets[e_lo:e_hi])
    ids, w = s.neighbors(u, with_weights=True)
    assert np.array_equal(w, oracle.weights[e_lo:e_hi])
    assert s.degree(u) == e_hi - e_lo
    with pytest.raises(IndexError):
        s.neighbors(v)


def test_edgelist_only_snapshot_falls_back(tmp_path):
    path, v, oracle = _graph(tmp_path, weighted=False, base=1)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "el_only.gvel")
    save_snapshot(gv, edgelist=el, compress="zlib")
    s = open_graph(gv)
    _assert_rows(s.csr(rows=(4, 25)), oracle, 4, 25)
    assert s.degree(7) == int(oracle.offsets[8]) - int(oracle.offsets[7])


def test_num_vertices_override_falls_back(tmp_path):
    """A forced num_vertices that disagrees with the header routes to
    the full build (padded rows), not the stored CSR."""
    gv, v, oracle = _snapshot(tmp_path, "raw")
    s = open_graph(gv, num_vertices=v + 5)
    part = s.csr(rows=(v, v + 5))            # rows past the header's V
    assert part.num_rows == 5
    assert part.targets.size == 0
    assert np.array_equal(part.offsets, np.zeros(6, np.int64))
    mid = s.csr(rows=(17, 43))
    off, tgt, _ = _expect(oracle, 17, 43)    # padded rows don't shift these
    assert mid.num_vertices == v + 5
    assert np.array_equal(mid.offsets, off)
    assert np.array_equal(mid.targets, tgt)


def test_slice_csr_rejects_local_csr(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "raw")
    part = open_graph(gv).csr(rows=(5, 20))
    with pytest.raises(ValueError, match="row_start"):
        slice_csr(part, 0, 5)


# ---- engine-level selective hooks --------------------------------------------

def test_snapshot_engine_hooks(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "zlib", weighted=True)
    eng = get_engine("snapshot")
    part = eng.read_csr_rows(gv, 10, 30, weighted=True)
    _assert_rows(part, oracle, 10, 30)
    ids, w = eng.read_neighbors(gv, 12, weighted=True)
    e_lo, e_hi = int(oracle.offsets[12]), int(oracle.offsets[13])
    assert np.array_equal(ids, oracle.targets[e_lo:e_hi])
    assert np.array_equal(w, oracle.weights[e_lo:e_hi])
    assert eng.read_degree(gv, 12) == e_hi - e_lo
    # no CSR sections / V mismatch -> None (callers fall back)
    path, v2, _ = _graph(tmp_path, weighted=False, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", num_vertices=v2)
    el_only = str(tmp_path / "hooks_el.gvel")
    save_snapshot(el_only, edgelist=el)
    assert eng.read_csr_rows(el_only, 0, 5) is None
    assert eng.read_neighbors(el_only, 0) is None
    assert eng.read_degree(el_only, 0) is None
    assert eng.read_csr_rows(gv, 0, 5, num_vertices=v + 1) is None


# ---- partial decode: only the frames the span touches ------------------------

def _spy_decodes(monkeypatch):
    calls = []
    real_frame, real_full = codecs.decode_frame, codecs.decompress_frames

    def frame_spy(payload, entry, codec, **kw):
        calls.append(("frame", kw.get("context", ""), entry.index))
        return real_frame(payload, entry, codec, **kw)

    def full_spy(*a, **kw):
        calls.append(("full", kw.get("context", ""), -1))
        return real_full(*a, **kw)

    monkeypatch.setattr(codecs, "decode_frame", frame_spy)
    monkeypatch.setattr(codecs, "decompress_frames", full_spy)
    return calls


def test_row_range_decodes_only_touched_frames(tmp_path, monkeypatch):
    gv, v, oracle = _snapshot(tmp_path, "zlib", weighted=True)
    frames = open_graph(gv).info().section_frames
    assert frames["csr_indices"] > 3      # multi-frame, or the test is vacuous
    calls = _spy_decodes(monkeypatch)
    s = open_graph(gv)
    _assert_rows(s.csr(rows=(20, 24)), oracle, 20, 24)
    assert not [c for c in calls if c[0] == "full"], \
        "partial read fell back to a full-section decode"
    e_lo, e_hi = int(oracle.offsets[20]), int(oracle.offsets[24])
    isz_off, isz_idx = 8, 4
    expect_off = {i for i in range(frames["csr_offsets"])
                  if i * FRAME_BETA < (24 + 1) * isz_off
                  and (i + 1) * FRAME_BETA > 20 * isz_off}
    expect_idx = {i for i in range(frames["csr_indices"])
                  if i * FRAME_BETA < e_hi * isz_idx
                  and (i + 1) * FRAME_BETA > e_lo * isz_idx}
    by_sec = {}
    for kind, ctx, idx in calls:
        by_sec.setdefault(ctx.rsplit(" ", 1)[1], set()).add(idx)
    assert by_sec["4"] == expect_off       # SEC_CSR_OFFSETS
    assert by_sec["5"] == expect_idx       # SEC_CSR_INDICES
    assert set(by_sec) <= {"4", "5", "6"}  # never an edgelist section
    n = len(calls)
    _assert_rows(s.csr(rows=(20, 24)), oracle, 20, 24)   # repeat: cached
    assert len(calls) == n


def test_point_read_decodes_no_weight_frames(tmp_path, monkeypatch):
    gv, v, oracle = _snapshot(tmp_path, "zlib", weighted=True)
    calls = _spy_decodes(monkeypatch)
    open_graph(gv).neighbors(30)
    secs = {c[1].rsplit(" ", 1)[1] for c in calls}
    assert "6" not in secs                 # SEC_CSR_WEIGHTS untouched


def test_frame_boundary_spanning_range(tmp_path, monkeypatch):
    """A range whose byte span crosses a frame boundary assembles from
    both frames — and only those."""
    gv, v, oracle = _snapshot(tmp_path, "zlib", frame_beta=64)
    # offsets are 8 bytes: rows [6, 10) span bytes [48, 88) -> frames 0+1
    calls = _spy_decodes(monkeypatch)
    s = open_graph(gv)
    _assert_rows(s.csr(rows=(6, 10)), oracle, 6, 10)
    off_frames = {i for k, c, i in calls if c.endswith(" 4")}
    assert off_frames == {0, 1}


# ---- property: slice-of-full == partial-load ---------------------------------

def test_rows_property_slice_equals_partial(tmp_path):
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    built = {}

    def snap_for(seed, weighted):
        key = (seed, weighted)
        if key not in built:
            built[key] = _snapshot(tmp_path, "zlib", weighted=weighted,
                                   seed=seed, frame_beta=64,
                                   v=40, e=40 + (seed * 67) % 260)
        return built[key]

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(st.integers(0, 5), st.booleans(),
           st.integers(0, 40), st.integers(0, 40))
    def prop(seed, weighted, a, b):
        gv, v, oracle = snap_for(seed, weighted)
        lo, hi = min(a, b), max(a, b)
        s = open_graph(gv)
        part = s.csr(rows=(lo, hi))
        whole = slice_csr(s.csr(), lo, hi)
        assert np.array_equal(part.offsets, whole.offsets)
        assert np.array_equal(part.targets, whole.targets)
        if weighted:
            assert np.array_equal(part.weights, whole.weights)
        else:
            assert part.weights is None
        _assert_rows(part, oracle, lo, hi)

    prop()
