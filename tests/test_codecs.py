"""Codec registry + framed block format: round-trips and the corruption
matrix (truncation, bit flips, lying lengths, unknown codecs — every
case must raise, never return wrong bytes)."""
import gzip
import os
import struct

import numpy as np
import pytest

from repro.core import codecs
from repro.core.blocks import SequentialBlockSource, plan_blocks, stage_blocks
from repro.core.codecs import (FRAMED_HDR_LEN, FRAME_HDR_LEN,
                               available_codecs, compress_frames,
                               decompress_frames, file_bytes, get_codec,
                               parse_codec_spec, read_framed_header,
                               write_framed)


def _payload(n=10000, seed=0):
    return bytes(np.random.default_rng(seed).integers(
        32, 120, n, dtype=np.uint8))


# ---- registry ----------------------------------------------------------------

def test_zlib_always_registered():
    assert "zlib" in available_codecs()
    assert get_codec("zlib").codec_id == 1


def test_zstd_registered_iff_importable():
    try:
        import zstandard  # noqa: F401
        assert "zstd" in available_codecs()
    except ImportError:
        assert "zstd" not in available_codecs()


def test_unknown_codec_lists_available():
    with pytest.raises(ValueError, match="zlib"):
        get_codec("no-such-codec")
    with pytest.raises(ValueError, match="unknown codec id"):
        codecs.codec_for_id(250)


def test_codec_id_zero_reserved():
    class Bad:
        name, codec_id = "bad", 0

        def compress(self, d, level):
            return d

        def decompress(self, d, n):
            return d

    with pytest.raises(ValueError, match="reserved"):
        codecs.register_codec(Bad())


def test_parse_codec_spec():
    codec, level = parse_codec_spec("zlib")
    assert codec.name == "zlib" and level is None
    codec, level = parse_codec_spec("zlib:9")
    assert level == 9
    with pytest.raises(ValueError, match="level"):
        parse_codec_spec("zlib:fast")
    with pytest.raises(ValueError, match="unknown codec"):
        parse_codec_spec("lzma")


def test_zstd_codec_roundtrip():
    pytest.importorskip("zstandard")
    c = get_codec("zstd")
    data = _payload()
    assert c.decompress(c.compress(data, None), len(data)) == data


# ---- frame layer -------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 100, 4096, 4097, 3 * 4096])
def test_frames_roundtrip_sizes(n):
    data = _payload(n)
    stream = compress_frames(data, get_codec("zlib"), frame_beta=4096)
    out = decompress_frames(stream, n, get_codec("zlib"))
    assert bytes(out) == data


def test_frames_truncated_header_rejected():
    data = _payload()
    stream = compress_frames(data, get_codec("zlib"), frame_beta=4096)
    with pytest.raises(ValueError, match="truncated frame"):
        decompress_frames(stream[:-1], len(data), get_codec("zlib"))
    with pytest.raises(ValueError, match="truncated frame header"):
        decompress_frames(stream[:FRAME_HDR_LEN - 2], len(data),
                          get_codec("zlib"))


def test_frames_bitflip_rejected():
    data = _payload()
    stream = bytearray(compress_frames(data, get_codec("zlib"),
                                       frame_beta=4096))
    stream[FRAME_HDR_LEN + 5] ^= 0x40            # flip a payload bit
    with pytest.raises(ValueError):              # zlib error or crc mismatch
        decompress_frames(bytes(stream), len(data), get_codec("zlib"))


def test_frames_crc_mismatch_rejected():
    # recompress the frame with different bytes but keep the old header crc
    codec = get_codec("zlib")
    good, evil = b"x" * 100, b"y" * 100
    comp_evil = codec.compress(evil, None)
    stream = struct.pack(codecs.FRAME_HDR_FMT, len(comp_evil), 100,
                         __import__("zlib").crc32(good)) + comp_evil
    with pytest.raises(ValueError, match="checksum"):
        decompress_frames(stream, 100, codec)


def test_frames_wrong_declared_raw_len_rejected():
    codec = get_codec("zlib")
    raw = b"z" * 100
    comp = codec.compress(raw, None)
    stream = struct.pack(codecs.FRAME_HDR_FMT, len(comp), 200,
                         __import__("zlib").crc32(raw)) + comp
    with pytest.raises(ValueError, match="declared 200"):
        decompress_frames(stream, 200, codec)


def test_frames_total_length_mismatch_rejected():
    data = _payload(1000)
    stream = compress_frames(data, get_codec("zlib"), frame_beta=4096)
    with pytest.raises(ValueError, match="declared total"):
        decompress_frames(stream, 999, get_codec("zlib"))
    with pytest.raises(ValueError, match="expected 1001"):
        decompress_frames(stream, 1001, get_codec("zlib"))


# ---- framed file container ---------------------------------------------------

def test_framed_file_roundtrip(tmp_path):
    data = _payload(50000)
    path = str(tmp_path / "x.elz")
    write_framed(path, data, codec="zlib", frame_beta=4096)
    assert codecs.is_framed(path)
    assert codecs.compression_of(path) == "framed"
    info = read_framed_header(path)
    assert info.orig_len == 50000 and info.frame_beta == 4096
    assert info.frame_count == 13 and info.codec.name == "zlib"
    assert bytes(file_bytes(path)) == data
    assert bytes(file_bytes(path, offset=100)) == data[100:]


def test_framed_unknown_codec_id_rejected(tmp_path):
    data = _payload(100)
    path = str(tmp_path / "x.elz")
    write_framed(path, data, codec="zlib")
    with open(path, "r+b") as f:
        f.seek(12)                               # codec_id field
        f.write(struct.pack("<I", 77))
    with pytest.raises(ValueError, match="unknown codec id 77"):
        file_bytes(path)


def test_framed_bad_version_rejected(tmp_path):
    path = str(tmp_path / "x.elz")
    write_framed(path, b"hello", codec="zlib")
    with open(path, "r+b") as f:
        f.seek(8)
        f.write(struct.pack("<I", 9))
    with pytest.raises(ValueError, match="version 9"):
        file_bytes(path)


def test_framed_header_frame_count_mismatch_rejected(tmp_path):
    path = str(tmp_path / "x.elz")
    write_framed(path, _payload(10000), codec="zlib", frame_beta=4096)
    with open(path, "r+b") as f:
        f.seek(32)                               # frame_count field
        f.write(struct.pack("<I", 1))
    with pytest.raises(ValueError, match="frames"):
        read_framed_header(path)


def test_framed_truncated_payload_rejected(tmp_path):
    path = str(tmp_path / "x.elz")
    write_framed(path, _payload(10000), codec="zlib", frame_beta=1024)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 7)
    with pytest.raises(ValueError, match="truncated"):
        file_bytes(path)
    with open(path, "r+b") as f:
        f.truncate(FRAMED_HDR_LEN - 3)
    with pytest.raises(ValueError, match="truncated framed header"):
        file_bytes(path)


# ---- gzip --------------------------------------------------------------------

def test_gzip_roundtrip(tmp_path):
    data = _payload(30000)
    path = str(tmp_path / "x.gz")
    with open(path, "wb") as f:
        f.write(gzip.compress(data))
    assert codecs.compression_of(path) == "gzip"
    assert bytes(file_bytes(path)) == data
    assert codecs.gzip_length_hint(path) == 30000


def test_gzip_corrupt_rejected(tmp_path):
    data = gzip.compress(_payload(30000))
    path = str(tmp_path / "x.gz")
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])          # truncated mid-stream
    with pytest.raises(ValueError, match="gzip"):
        file_bytes(path)


def test_peek_bytes_on_truncated_gzip_is_empty(tmp_path):
    """Sniffing a gzip truncated inside its first deflate block must
    return b'' (and the loader a ValueError), not leak EOFError."""
    full = gzip.compress(b"1 2\n" * 500)
    path = str(tmp_path / "t.el.gz")
    with open(path, "wb") as f:
        f.write(full[:14])
    assert codecs.peek_bytes(path, 8) == b""
    with pytest.raises(ValueError, match="gzip"):
        file_bytes(path)


def test_open_stream_framed_tell_reports_uncompressed_positions(tmp_path):
    """MTX header scanning needs tell() on framed streams."""
    data = b"header line\nbody starts here\nmore\n"
    path = str(tmp_path / "x.elz")
    write_framed(path, data, codec="zlib", frame_beta=8)
    with codecs.open_stream(path) as f:
        assert f.readline() == b"header line\n"
        assert f.tell() == len(b"header line\n")
        assert f.read() == b"body starts here\nmore\n"


def test_raw_file_not_sniffed_as_compressed(tmp_path):
    path = str(tmp_path / "x.el")
    with open(path, "w") as f:
        f.write("1 2\n")
    assert codecs.compression_of(path) is None
    assert bytes(file_bytes(path)) == b"1 2\n"


# ---- sequential block source vs random-access staging ------------------------

@pytest.mark.parametrize("beta,batch", [(4096, 3), (1024, 1), (2048, 8)])
def test_sequential_source_stage_parity(tmp_path, beta, batch):
    data = _payload(33333, seed=5)
    path = str(tmp_path / "x.elz")
    write_framed(path, data, codec="zlib", frame_beta=beta)
    source, forced = codecs.open_block_source(path)
    assert forced == beta
    plan = plan_blocks(source.length, beta=beta, overlap=64)
    raw = np.frombuffer(data, np.uint8)
    for start in range(0, plan.num_blocks, batch):
        ids = np.arange(start, min(start + batch, plan.num_blocks))
        got = np.array(source.stage(plan, ids))
        assert np.array_equal(got, stage_blocks(raw, plan, ids)), start
    source.finish()


def test_sequential_source_out_of_order_rejected():
    src = SequentialBlockSource(iter([b"a" * 100]), 100)
    plan = plan_blocks(100, beta=80, overlap=8)
    with pytest.raises(ValueError, match="out of order"):
        src.stage(plan, np.array([1]))


def test_sequential_source_short_stream_rejected():
    src = SequentialBlockSource(iter([b"a" * 50]), 100, describe="test stream")
    plan = plan_blocks(100, beta=80, overlap=8)
    for i in range(plan.num_blocks):
        src.stage(plan, np.array([i]))
    with pytest.raises(ValueError, match="50 bytes"):
        src.finish()


def test_sequential_source_long_stream_rejected():
    src = SequentialBlockSource(iter([b"a" * 100, b"b" * 10]), 100)
    plan = plan_blocks(100, beta=80, overlap=8)
    for i in range(plan.num_blocks):
        src.stage(plan, np.array([i]))
    with pytest.raises(ValueError, match="110 bytes"):
        src.finish()


# ---- property: frames round-trip any bytes at any frame size -----------------

def test_frames_property_roundtrip():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.binary(min_size=0, max_size=5000),
           st.integers(min_value=1, max_value=700))
    def prop(data, frame_beta):
        stream = compress_frames(data, get_codec("zlib"),
                                 frame_beta=frame_beta)
        assert bytes(decompress_frames(stream, len(data),
                                       get_codec("zlib"))) == data

    prop()
