"""Per-arch smoke tests (reduced configs): shapes, finiteness, parity.

The prefill->decode == train-forward parity test is the strongest
correctness check: the cached incremental path must reproduce the full
forward within bf16 tolerance for every architecture family.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import (forward_decode, forward_prefill, forward_train,
                          init_caches, init_params, loss_fn)

B, S = 2, 64
KEY = jax.random.key(0)


def _batch(cfg, seed=0):
    key = jax.random.fold_in(KEY, seed)
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.embed_stub:
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in ARCHS:
        cfg = reduced_config(name)
        out[name] = (cfg, init_params(KEY, cfg))
    return out


@pytest.mark.parametrize("name", list(ARCHS))
def test_forward_shapes_and_finiteness(zoo, name):
    cfg, params = zoo[name]
    batch = _batch(cfg)
    logits, aux = forward_train(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", list(ARCHS))
def test_grads_finite(zoo, name):
    cfg, params = zoo[name]
    g = jax.grad(loss_fn)(params, _batch(cfg), cfg)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if not ARCHS[n].embed_stub])
def test_prefill_decode_matches_train_forward(zoo, name):
    """Teacher-forced decode must track the full forward."""
    cfg, params = zoo[name]
    batch = _batch(cfg)
    toks = batch["tokens"]
    full_logits, _ = forward_train(params, batch, cfg)

    prompt = {k: (v[:, :S - 1] if v.ndim > 1 and v.shape[1] == S else v)
              for k, v in batch.items() if k != "labels"}
    lg_prefill, caches = forward_prefill(params, prompt, cfg, max_seq=S)
    np.testing.assert_allclose(np.asarray(lg_prefill, np.float32),
                               np.asarray(full_logits[:, S - 2], np.float32),
                               rtol=5e-2, atol=5e-2)

    dbatch = {"token": toks[:, S - 1],
              "pos": jnp.full((B,), S - 1, jnp.int32)}
    lg_dec, _ = forward_decode(params, dbatch, caches, cfg, max_seq=S)
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(full_logits[:, S - 1], np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("name", ["mixtral-8x22b", "recurrentgemma-2b"])
def test_window_decode_consistency(zoo, name):
    """Multi-step decode through the ring cache stays finite and matches
    a re-prefill at every checkpointed position."""
    cfg, params = zoo[name]
    assert cfg.window is not None
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    _, caches = forward_prefill(params, {"tokens": toks[:, :32]}, cfg,
                                max_seq=S)
    for t in range(32, 40):
        lg, caches = forward_decode(
            params, {"token": toks[:, t - 0 if False else t],
                     "pos": jnp.full((B,), t, jnp.int32)},
            caches, cfg, max_seq=S)
        assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_moe_aux_loss_positive(zoo):
    cfg, params = zoo["mixtral-8x22b"]
    _, aux = forward_train(params, _batch(cfg), cfg)
    assert float(aux) >= 0.99   # balanced router ~= 1.0


def test_param_count_analytic_close_to_actual():
    for name in ARCHS:
        cfg = reduced_config(name)
        params = init_params(KEY, cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / max(actual, 1) < 0.35, (
            name, actual, analytic)
