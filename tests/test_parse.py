"""Unit tests: vectorized parsers (jnp device path + numpy host path)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.parse import parse_accumulate, parse_block, parse_blocks
from repro.core.parse_np import chunk_bounds, parse_chunk_np


def _pad(text: bytes, mult: int = 64) -> np.ndarray:
    buf = np.frombuffer(text, np.uint8)
    pad = (-len(buf)) % mult
    return np.concatenate([buf, np.full(pad, 10, np.uint8)])


ALLOWED = set(b"0123456789.- \t\r")


def _oracle(text: bytes, weighted=False, base=1):
    src, dst, w = [], [], []
    for line in text.split(b"\n"):
        # GVEL semantics: any line with a byte outside the edge grammar
        # (comments, junk) is rejected wholesale
        if any(c not in ALLOWED for c in line):
            continue
        parts = line.split()
        if len(parts) < 2:
            continue
        src.append(int(parts[0]) - base)
        dst.append(int(parts[1]) - base)
        w.append(float(parts[2]) if weighted and len(parts) > 2 else 1.0)
    return src, dst, w


CASES = [
    b"1 2\n3 4\n",
    b"1 2\n\n\n3 4\n",                      # blank lines
    b"10 20\n% comment 5 5\n30 40\n",       # comment rejected
    b"1\t2\n3  4\n5 6",                     # tabs, multi-space, no trailing nl
    b"999999999 1\n1 999999999\n",          # 9-digit ids
    b"1 2 extra tokens 3\n",                # extra junk -> bad line
]


@pytest.mark.parametrize("text", CASES)
def test_parse_block_matches_oracle(text):
    buf = _pad(text)
    s, d, w, c = parse_block(jnp.asarray(buf), jnp.int32(0),
                             jnp.int32(len(buf)), weighted=False, base=1,
                             edge_cap=32)
    es, ed, _ = _oracle(text)
    assert int(c) == len(es)
    assert np.asarray(s[:len(es)]).tolist() == es
    assert np.asarray(d[:len(ed)]).tolist() == ed


@pytest.mark.parametrize("text", CASES)
def test_parse_np_matches_oracle(text):
    s, d, w, c = parse_chunk_np(np.frombuffer(text, np.uint8), weighted=False)
    es, ed, _ = _oracle(text)
    assert c == len(es)
    assert s.tolist() == es and d.tolist() == ed


def test_weighted_floats():
    text = b"1 2 0.5\n2 3 -1.25\n3 4 7\n4 5 12.0625\n"
    buf = _pad(text)
    s, d, w, c = parse_block(jnp.asarray(buf), jnp.int32(0),
                             jnp.int32(len(buf)), weighted=True, base=1,
                             edge_cap=16)
    assert int(c) == 4
    np.testing.assert_allclose(np.asarray(w[:4]), [0.5, -1.25, 7.0, 12.0625],
                               rtol=1e-6)
    s2, d2, w2, c2 = parse_chunk_np(np.frombuffer(text, np.uint8),
                                    weighted=True)
    np.testing.assert_allclose(w2, [0.5, -1.25, 7.0, 12.0625], rtol=1e-12)


def test_missing_weight_defaults_to_one():
    text = b"1 2\n2 3 4.5\n"
    buf = _pad(text)
    s, d, w, c = parse_block(jnp.asarray(buf), jnp.int32(0),
                             jnp.int32(len(buf)), weighted=True, base=1,
                             edge_cap=8)
    np.testing.assert_allclose(np.asarray(w[:2]), [1.0, 4.5])


def test_zero_based_ids():
    text = b"0 1\n1 2\n"
    buf = _pad(text)
    s, d, _, c = parse_block(jnp.asarray(buf), jnp.int32(0),
                             jnp.int32(len(buf)), weighted=False, base=0,
                             edge_cap=8)
    assert np.asarray(s[:2]).tolist() == [0, 1]


def test_ownership_partition_is_exact():
    """Every line owned by exactly one block for any beta."""
    rng = np.random.default_rng(0)
    lines = [f"{rng.integers(1, 99)} {rng.integers(1, 99)}" for _ in range(200)]
    text = ("\n".join(lines) + "\n").encode()
    data = np.frombuffer(text, np.uint8)
    for beta in (16, 64, 256):
        ov = 32
        total = 0
        nb = -(-len(data) // beta)
        for i in range(nb):
            lo = i * beta - ov
            buf = np.full(ov + beta, 10, np.uint8)
            s, e = max(lo, 0), min(i * beta + beta, len(data))
            buf[s - lo:e - lo] = data[s:e]
            _, _, _, c = parse_block(jnp.asarray(buf), jnp.int32(ov),
                                     jnp.int32(ov + beta), weighted=False,
                                     base=1, edge_cap=ov + beta)
            total += int(c)
        assert total == 200, beta


def test_parse_accumulate_packs_batches():
    """The fused step packs each batch's edges contiguously at the
    running offset, leaving -1 padding past the total."""
    bufs = jnp.asarray(np.stack([_pad(b"1 2\n3 4\n"), _pad(b"5 6\n")]))
    os_ = jnp.zeros(2, jnp.int32)
    oe = jnp.full(2, bufs.shape[1], jnp.int32)
    acc_s = jnp.full((16,), -1, jnp.int32)
    acc_d = jnp.full((16,), -1, jnp.int32)
    tot = jnp.zeros((), jnp.int32)
    acc_s, acc_d, _, tot = parse_accumulate(
        acc_s, acc_d, None, tot, bufs, os_, oe, weighted=False, base=1,
        edge_bound=8, donate=False)
    # second batch lands after the first batch's edges
    acc_s, acc_d, _, tot = parse_accumulate(
        acc_s, acc_d, None, tot, jnp.asarray(np.stack([_pad(b"7 8\n")])),
        os_[:1], oe[:1], weighted=False, base=1, edge_bound=8, donate=False)
    assert int(tot) == 4
    assert np.asarray(acc_s).tolist() == [0, 2, 4, 6] + [-1] * 12
    assert np.asarray(acc_d).tolist() == [1, 3, 5, 7] + [-1] * 12


def test_chunk_bounds_newline_aligned():
    text = b"11 22\n33 44\n55 66\n77 88\n"
    data = np.frombuffer(text, np.uint8)
    bounds = chunk_bounds(data, 3)
    assert bounds[0][0] == 0 and bounds[-1][1] == len(data)
    for lo, hi in bounds[:-1]:
        assert hi == 0 or data[hi - 1] == 10   # cuts at newline
