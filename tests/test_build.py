"""CSR construction strategies vs the numpy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build, degrees
from repro.core.types import EdgeList
from repro.core.csr import convert_to_csr


def _random_edges(v, e, seed=0, pad=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    if pad:
        src = np.concatenate([src, np.full(pad, -1, np.int32)])
        dst = np.concatenate([dst, np.full(pad, -1, np.int32)])
    return src, dst


def _rows(offsets, targets, v):
    off = np.asarray(offsets)
    tgt = np.asarray(targets)
    return [np.sort(tgt[off[u]:off[u + 1]]) for u in range(v)]


@pytest.mark.parametrize("rho", [1, 2, 4, 7, 8])
def test_staged_equals_global(rho):
    v, e = 64, 1000
    src, dst = _random_edges(v, e, seed=rho)
    ref = build.csr_np(src, dst, None, v)
    og, tg, _ = build.csr_global(jnp.asarray(src), jnp.asarray(dst), None, v)
    os_, ts, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                                  rho=rho)
    assert np.array_equal(np.asarray(og), np.asarray(ref.offsets))
    assert np.array_equal(np.asarray(os_), np.asarray(ref.offsets))
    r_ref = _rows(ref.offsets, ref.targets, v)
    for name, (o, t) in {"global": (og, tg), "staged": (os_, ts)}.items():
        r = _rows(o, t, v)
        for u in range(v):
            assert np.array_equal(r[u], r_ref[u]), (name, u)


def test_staged_handles_padding_sentinels():
    v = 32
    src, dst = _random_edges(v, 100, seed=3, pad=28)
    ref = build.csr_np(src, dst, None, v)
    o, t, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                               rho=4)
    assert int(o[-1]) == 100
    r_ref = _rows(ref.offsets, ref.targets, v)
    r = _rows(o, t, v)
    for u in range(v):
        assert np.array_equal(r[u], r_ref[u])


def test_weighted_csr_keeps_edge_weight_pairing():
    v, e = 16, 200
    rng = np.random.default_rng(1)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    o, t, ww = build.csr_staged(jnp.asarray(src), jnp.asarray(dst),
                                jnp.asarray(w), v, rho=4, weighted=True)
    # every (target, weight) pair within a row must be an original edge pair
    pairs = {(int(u), int(vv), float(x)) for u, vv, x in zip(src, dst, w)}
    off = np.asarray(o)
    for u in range(v):
        for j in range(off[u], off[u + 1]):
            assert (u, int(t[j]), float(np.asarray(ww)[j])) in pairs


def test_degree_strategies_agree():
    v, e = 128, 5000
    src, _ = _random_edges(v, e, seed=9, pad=17)
    ref = degrees.degrees_np(src, v)
    a = degrees.degrees_global(jnp.asarray(src), v)
    b = degrees.combine_degrees(degrees.degrees_partitioned(jnp.asarray(src), v, 4))
    c = degrees.degrees_sort(jnp.asarray(src), v)
    for x in (a, b, c):
        assert np.array_equal(np.asarray(x), ref)


def test_offsets_from_degrees():
    deg = jnp.asarray([3, 0, 2, 5], jnp.int32)
    off = degrees.offsets_from_degrees(deg, 4)
    assert np.asarray(off).tolist() == [0, 3, 3, 5, 10]


def test_convert_to_csr_engines_match():
    v, e = 48, 400
    src, dst = _random_edges(v, e, seed=5)
    el = EdgeList(src, dst, None, np.int64(e), v)
    a = convert_to_csr(el, method="staged", rho=4)
    b = convert_to_csr(el, engine="numpy")
    assert np.array_equal(np.asarray(a.offsets, np.int64),
                          np.asarray(b.offsets))
    ra, rb = _rows(a.offsets, a.targets, v), _rows(b.offsets, b.targets, v)
    for u in range(v):
        assert np.array_equal(ra[u], rb[u])


# ---- binned (propagation-blocking) build -------------------------------------
#
# csr_binned realizes the *stable* (src, original index) rank, so its
# offsets AND targets must match the stable-sort oracle bit for bit —
# not just per-row as multisets.

def _weights_for(src, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random(len(src)).astype(np.float32)


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("bin_bits", [None, 1, 3, 64])
def test_binned_bitwise_matches_oracle(weighted, bin_bits):
    v, e = 64, 1000
    src, dst = _random_edges(v, e, seed=11, pad=24)
    w = _weights_for(src, seed=11) if weighted else None
    ref = build.csr_np(src, dst, w, v)
    o, t, ww = build.csr_binned(
        jnp.asarray(src), jnp.asarray(dst),
        None if w is None else jnp.asarray(w), v,
        bin_bits=bin_bits, weighted=weighted)
    assert int(o[-1]) == 1000            # padding sank below every edge
    assert np.array_equal(np.asarray(o, np.int64), np.asarray(ref.offsets))
    assert np.array_equal(np.asarray(t)[:1000], np.asarray(ref.targets))
    if weighted:
        assert np.array_equal(np.asarray(ww)[:1000], np.asarray(ref.weights))


@pytest.mark.parametrize("case", ["empty", "skew", "v1", "tiny"])
def test_binned_edge_shapes(case):
    if case == "empty":
        v, src, dst = 8, np.empty(0, np.int32), np.empty(0, np.int32)
    elif case == "skew":                 # every edge on one vertex
        v = 32
        src = np.full(257, 7, np.int32)
        dst = np.arange(257, dtype=np.int32) % v
    elif case == "v1":
        v = 1
        src = np.zeros(9, np.int32)
        dst = np.zeros(9, np.int32)
    else:                                # single edge
        v, src, dst = 4, np.asarray([2], np.int32), np.asarray([1], np.int32)
    ref = build.csr_np(src, dst, None, v)
    o, t, _ = build.csr_binned(jnp.asarray(src), jnp.asarray(dst), None, v)
    assert np.array_equal(np.asarray(o, np.int64), np.asarray(ref.offsets))
    assert np.array_equal(np.asarray(t)[:len(ref.targets)],
                          np.asarray(ref.targets))


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("num_workers", [1, 4])
@pytest.mark.parametrize("bin_bits", [None, 2])
def test_binned_np_matches_oracle(weighted, num_workers, bin_bits):
    v, e = 100, 3000                     # v not a power of two: ragged last bin
    src, dst = _random_edges(v, e, seed=13, pad=32)
    w = _weights_for(src, seed=13) if weighted else None
    ref = build.csr_np(src, dst, w, v)
    got = build.csr_binned_np(src, dst, w, v, bin_bits=bin_bits,
                              num_workers=num_workers)
    assert np.array_equal(got.offsets, ref.offsets)
    assert np.array_equal(got.targets, ref.targets)
    if weighted:
        assert np.array_equal(got.weights, ref.weights)


def test_binned_respects_base_through_convert():
    v, e = 48, 400
    src, dst = _random_edges(v, e, seed=5)
    el = EdgeList(src, dst, None, np.int64(e), v)
    a = convert_to_csr(el, method="binned")
    b = convert_to_csr(el, method="binned", engine="numpy")
    ref = convert_to_csr(el, engine="numpy")
    for got in (a, b):
        assert np.array_equal(np.asarray(got.offsets, np.int64),
                              np.asarray(ref.offsets))
        assert np.array_equal(np.asarray(got.targets), np.asarray(ref.targets))


# ---- int32 offsets contract --------------------------------------------------
#
# Device builds accumulate offsets as int32 (jnp.cumsum(deg, int32)): at
# E >= 2**31 the cumsum would wrap silently.  The guard must refuse
# loudly at trace time.  Exercised with a mocked limit — the check reads
# the module global, so a 2B-edge graph is not needed.

def test_offsets_width_guard_mocked_limit(monkeypatch):
    monkeypatch.setattr(build, "INT32_OFFSETS_LIMIT", 100)
    v = 16
    src, dst = _random_edges(v, 129, seed=2)
    js, jd = jnp.asarray(src), jnp.asarray(dst)
    for fn in (lambda: build.csr_binned(js, jd, None, v),
               lambda: build.csr_staged(js, jd, None, v, rho=4),
               lambda: build.csr_global(js, jd, None, v)):
        with pytest.raises(ValueError, match="exceeds int32 offsets"):
            fn()


def test_offsets_width_guard_under_limit_ok(monkeypatch):
    monkeypatch.setattr(build, "INT32_OFFSETS_LIMIT", 150)
    v = 16
    src, dst = _random_edges(v, 130, seed=2)
    o, t, _ = build.csr_binned(jnp.asarray(src), jnp.asarray(dst), None, v)
    assert int(o[-1]) == 130
