"""CSR construction strategies vs the numpy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build, degrees
from repro.core.types import EdgeList
from repro.core.csr import convert_to_csr


def _random_edges(v, e, seed=0, pad=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    if pad:
        src = np.concatenate([src, np.full(pad, -1, np.int32)])
        dst = np.concatenate([dst, np.full(pad, -1, np.int32)])
    return src, dst


def _rows(offsets, targets, v):
    off = np.asarray(offsets)
    tgt = np.asarray(targets)
    return [np.sort(tgt[off[u]:off[u + 1]]) for u in range(v)]


@pytest.mark.parametrize("rho", [1, 2, 4, 7, 8])
def test_staged_equals_global(rho):
    v, e = 64, 1000
    src, dst = _random_edges(v, e, seed=rho)
    ref = build.csr_np(src, dst, None, v)
    og, tg, _ = build.csr_global(jnp.asarray(src), jnp.asarray(dst), None, v)
    os_, ts, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                                  rho=rho)
    assert np.array_equal(np.asarray(og), np.asarray(ref.offsets))
    assert np.array_equal(np.asarray(os_), np.asarray(ref.offsets))
    r_ref = _rows(ref.offsets, ref.targets, v)
    for name, (o, t) in {"global": (og, tg), "staged": (os_, ts)}.items():
        r = _rows(o, t, v)
        for u in range(v):
            assert np.array_equal(r[u], r_ref[u]), (name, u)


def test_staged_handles_padding_sentinels():
    v = 32
    src, dst = _random_edges(v, 100, seed=3, pad=28)
    ref = build.csr_np(src, dst, None, v)
    o, t, _ = build.csr_staged(jnp.asarray(src), jnp.asarray(dst), None, v,
                               rho=4)
    assert int(o[-1]) == 100
    r_ref = _rows(ref.offsets, ref.targets, v)
    r = _rows(o, t, v)
    for u in range(v):
        assert np.array_equal(r[u], r_ref[u])


def test_weighted_csr_keeps_edge_weight_pairing():
    v, e = 16, 200
    rng = np.random.default_rng(1)
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    o, t, ww = build.csr_staged(jnp.asarray(src), jnp.asarray(dst),
                                jnp.asarray(w), v, rho=4, weighted=True)
    # every (target, weight) pair within a row must be an original edge pair
    pairs = {(int(u), int(vv), float(x)) for u, vv, x in zip(src, dst, w)}
    off = np.asarray(o)
    for u in range(v):
        for j in range(off[u], off[u + 1]):
            assert (u, int(t[j]), float(np.asarray(ww)[j])) in pairs


def test_degree_strategies_agree():
    v, e = 128, 5000
    src, _ = _random_edges(v, e, seed=9, pad=17)
    ref = degrees.degrees_np(src, v)
    a = degrees.degrees_global(jnp.asarray(src), v)
    b = degrees.combine_degrees(degrees.degrees_partitioned(jnp.asarray(src), v, 4))
    c = degrees.degrees_sort(jnp.asarray(src), v)
    for x in (a, b, c):
        assert np.array_equal(np.asarray(x), ref)


def test_offsets_from_degrees():
    deg = jnp.asarray([3, 0, 2, 5], jnp.int32)
    off = degrees.offsets_from_degrees(deg, 4)
    assert np.asarray(off).tolist() == [0, 3, 3, 5, 10]


def test_convert_to_csr_engines_match():
    v, e = 48, 400
    src, dst = _random_edges(v, e, seed=5)
    el = EdgeList(src, dst, None, np.int64(e), v)
    a = convert_to_csr(el, method="staged", rho=4)
    b = convert_to_csr(el, engine="numpy")
    assert np.array_equal(np.asarray(a.offsets, np.int64),
                          np.asarray(b.offsets))
    ra, rb = _rows(a.offsets, a.targets, v), _rows(b.offsets, b.targets, v)
    for u in range(v):
        assert np.array_equal(ra[u], rb[u])
