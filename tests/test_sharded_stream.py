"""Byte-range-sharded streaming load: shard_plan partitioning, span
block sources, and the end-to-end mesh CSR build under 4 host devices.

The subprocess tests each assert the sharded result against the host
``build.csr_np`` oracle *bitwise* on offsets/targets (span order ==
file order + stable bucketing + sender-major all_to_all + stable local
sort reproduce global file order per row; see
``exchange_by_owner``'s docstring) — not just as edge sets.
"""
import gzip
import json
import os

import numpy as np
import pytest

from repro.core import codecs
from repro.core.blocks import (MemoryBlockSource, SequentialBlockSource,
                               plan_blocks, shard_plan)


# ---------------------------------------------------------------------------
# shard_plan: host-side partition properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbytes,beta,d", [
    (100_000, 2048, 4), (100_000, 2048, 3), (1_000, 256, 7),
    (50, 4096, 4), (0, 1024, 2), (8192, 1024, 8),
])
def test_shard_plan_partitions_blocks(nbytes, beta, d):
    plan = plan_blocks(nbytes, beta=beta, overlap=64)
    spans = [shard_plan(plan, k, d) for k in range(d)]
    # disjoint, ordered, exhaustive cover of [0, num_blocks)
    assert spans[0].block_lo == 0
    assert spans[-1].block_hi == plan.num_blocks
    for a, b in zip(spans, spans[1:]):
        assert a.block_hi == b.block_lo
    # balanced to within one block
    sizes = [s.num_blocks for s in spans]
    assert max(sizes) - min(sizes) <= 1
    # byte spans clamp to the file and never regress
    for s in spans:
        assert 0 <= s.byte_lo <= s.byte_hi <= plan.file_len
        assert s.edge_cap == s.num_blocks * plan.edge_cap


def test_shard_plan_validates():
    plan = plan_blocks(1000, beta=256, overlap=64)
    with pytest.raises(ValueError):
        shard_plan(plan, 0, 0)
    with pytest.raises(ValueError):
        shard_plan(plan, 2, 2)
    with pytest.raises(ValueError):
        shard_plan(plan, -1, 2)


def _lines(n, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    src = rng.integers(1, 900, n)
    dst = rng.integers(1, 900, n)
    if weighted:
        w = (rng.random(n) * 9).round(3)
        body = "\n".join(f"{s} {d} {x}" for s, d, x in zip(src, dst, w))
    else:
        body = "\n".join(f"{s} {d}" for s, d in zip(src, dst))
    return (body + "\n").encode()


# ---------------------------------------------------------------------------
# span block sources: staged bytes match the in-memory source, per shard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["raw", "gzip", "framed-zlib"])
@pytest.mark.parametrize("d", [1, 3, 4])
def test_shard_source_staging_parity(tmp_path, fmt, d):
    data = _lines(3000, seed=2)
    raw = tmp_path / "g.el"
    raw.write_bytes(data)
    if fmt == "raw":
        path = str(raw)
    elif fmt == "gzip":
        path = str(tmp_path / "g.el.gz")
        with open(path, "wb") as f:
            f.write(gzip.compress(data))
    else:
        path = str(tmp_path / "g.el.fz")
        codecs.write_framed(path, data, codec="zlib", frame_beta=4096)

    length, forced = codecs.stream_geometry(path)
    assert length == len(data)
    plan = plan_blocks(length, beta=forced or 2048, overlap=64)
    ref = MemoryBlockSource(np.frombuffer(data, np.uint8))
    for k in range(d):
        span = shard_plan(plan, k, d)
        if span.num_blocks == 0:
            with pytest.raises(ValueError):
                codecs.open_shard_block_source(path, plan, span)
            continue
        source = codecs.open_shard_block_source(path, plan, span)
        for lo in range(span.block_lo, span.block_hi, 3):
            ids = np.arange(lo, min(lo + 3, span.block_hi))
            got = source.stage(plan, ids)
            want = ref.stage(plan, ids)
            assert np.array_equal(got, want), (fmt, k, lo)
        source.finish()


@pytest.mark.parametrize("k,d,match", [
    (1, 3, "before this shard span"),   # mid-stream span: coverage check
    (1, 2, "expected"),                 # tail span: exact-drain check
])
def test_span_source_truncated_stream_raises(k, d, match):
    data = b"1 2\n3 4\n5 6\n" * 400
    plan = plan_blocks(len(data), beta=256, overlap=64)
    span = shard_plan(plan, k, d)

    def chunks():
        # begins at the span's left margin but ends short of span.byte_hi
        start = max(span.block_lo * plan.beta - plan.overlap, 0)
        yield data[start:span.byte_hi - 40]

    src = SequentialBlockSource(
        chunks(), len(data),
        start=max(span.block_lo * plan.beta - plan.overlap, 0),
        end=span.byte_hi if span.block_hi < plan.num_blocks else None,
        first_block=span.block_lo)
    with pytest.raises(ValueError, match=match):
        for lo in range(span.block_lo, span.block_hi, 4):
            src.stage(plan, np.arange(lo, min(lo + 4, span.block_hi)))
        src.finish()


def test_span_source_rejects_out_of_order():
    data = b"1 2\n" * 500
    plan = plan_blocks(len(data), beta=256, overlap=64)
    src = SequentialBlockSource(iter([data]), len(data))
    src.stage(plan, np.arange(0, 2))
    with pytest.raises(ValueError, match="out of order"):
        src.stage(plan, np.arange(5, 6))


# ---------------------------------------------------------------------------
# tuner: per-shard-count profile slot
# ---------------------------------------------------------------------------

def test_tuned_shard_slot(tmp_path, monkeypatch):
    from repro.core import loader, tune
    from repro.core.loader import LoadOptions, resolve_tuned

    cache = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    rows = [{"beta": 4096, "batch_blocks": 2, "seconds": 0.5, "mb_per_s": 1.0}]
    tune.save_geometry(rows, weighted=False, shards=4)
    rows1 = [{"beta": 65536, "batch_blocks": 8, "seconds": 0.4,
              "mb_per_s": 1.0}]
    tune.save_geometry(rows1, weighted=False)

    prof = json.loads(cache.read_text())
    slots = prof["hosts"][tune.host_key()]
    assert set(slots) == {"unweighted", "unweighted_d4"}

    opts = LoadOptions(engine="device", weighted=False, tune=True)
    r1 = resolve_tuned(opts)
    assert r1.engine_kw["beta"] == 65536
    r4 = resolve_tuned(opts, shards=4)
    assert r4.engine_kw["beta"] == 4096
    # explicit geometry still wins over the profile
    pinned = opts.replace(engine_kw={"beta": 1024, "batch_blocks": 2})
    assert resolve_tuned(pinned, shards=4).engine_kw["beta"] == 1024


# ---------------------------------------------------------------------------
# front-door guards (no mesh computation needed)
# ---------------------------------------------------------------------------

def test_read_csr_sharded_via_guards(tmp_path):
    from repro.core.compat import make_mesh
    from repro.core.loader import LoadOptions, read_csr_sharded_via

    path = tmp_path / "g.el"
    path.write_bytes(b"1 2\n2 3\n")
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="no axis"):
        read_csr_sharded_via(str(path), LoadOptions(engine="device"),
                             mesh=mesh, axis="model")
    with pytest.raises(ValueError, match="symmetric"):
        read_csr_sharded_via(str(path),
                             LoadOptions(engine="device", symmetric=True),
                             mesh=mesh)
    with pytest.raises(ValueError, match="no sharded streaming path"):
        read_csr_sharded_via(str(path), LoadOptions(engine="numpy"),
                             mesh=mesh)


def test_csr_sharded_front_door_rejects_mtx_and_gvel(tmp_path):
    from repro.core import open_graph, save_snapshot
    from repro.core.compat import make_mesh
    from repro.core.types import EdgeList

    mesh = make_mesh((1,), ("data",))
    mtx = tmp_path / "g.mtx"
    mtx.write_text("%%MatrixMarket matrix coordinate pattern general\n"
                   "3 3 2\n1 2\n2 3\n")
    with pytest.raises(ValueError, match="MTX"):
        open_graph(str(mtx)).csr_sharded(mesh)

    snap = tmp_path / "g.gvel"
    el = EdgeList(np.array([0, 1], np.int32), np.array([1, 2], np.int32),
                  None, np.int64(2), 3)
    save_snapshot(str(snap), edgelist=el)
    with pytest.raises(ValueError, match="snapshot"):
        open_graph(str(snap)).csr_sharded(mesh)


def test_csr_sharded_single_device_memoized(tmp_path):
    """d=1 degenerate mesh: the sharded path reduces to the streaming
    load; memoized per (mesh, axis, rho)."""
    from repro.core import build, open_graph
    from repro.core.compat import make_mesh

    rng = np.random.default_rng(3)
    n, v = 1200, 97
    src = rng.integers(0, v, n)
    dst = rng.integers(0, v, n)
    path = tmp_path / "g.el"
    path.write_text("\n".join(f"{s+1} {d+1}" for s, d in zip(src, dst)) + "\n")

    mesh = make_mesh((1,), ("data",))
    g = open_graph(str(path), engine="device", beta=2048)
    csr = g.csr_sharded(mesh)
    assert g.csr_sharded(mesh) is csr
    assert g.csr_sharded(mesh, rho=8) is not csr

    oracle = build.csr_np(src, dst, None, v)
    off = np.asarray(csr.offsets)
    tgt = np.asarray(csr.targets)
    rows = off.shape[1] - 1
    assert rows >= v
    assert np.array_equal(off[0, :v + 1], np.asarray(oracle.offsets))
    assert np.array_equal(tgt[0, :n], np.asarray(oracle.targets))


# ---------------------------------------------------------------------------
# end-to-end sharded load under 4 forced host devices (subprocess)
# ---------------------------------------------------------------------------

_ORACLE_HELPERS = '''
import numpy as np
from repro.core import build

def check_bitwise(csr, src, dst, w, v, d):
    """Sharded CSR == csr_np oracle, bitwise on offsets/targets."""
    oracle = build.csr_np(src, dst, w, v)
    oo = np.asarray(oracle.offsets); ot = np.asarray(oracle.targets)
    off = np.asarray(csr.offsets); tgt = np.asarray(csr.targets)
    ww = np.asarray(csr.weights) if w is not None else None
    rows = off.shape[1] - 1
    assert rows * d >= v, (rows, d, v)
    n = 0
    for k in range(d):
        for r in range(rows):
            u = k * rows + r
            lo, hi = int(off[k, r]), int(off[k, r + 1])
            if u >= v:
                assert lo == hi, (k, r)
                continue
            glo, ghi = int(oo[u]), int(oo[u + 1])
            assert hi - lo == ghi - glo, (u, lo, hi, glo, ghi)
            assert np.array_equal(tgt[k, lo:hi], ot[glo:ghi]), u
            if ww is not None:
                np.testing.assert_allclose(
                    ww[k, lo:hi], np.asarray(oracle.weights)[glo:ghi],
                    rtol=1e-6, atol=1e-7)
            n += hi - lo
    assert n == len(src), (n, len(src))
'''


def test_sharded_parity_matrix(devices4, tmp_path):
    """weighted x base x codec grid vs the csr_np oracle, one subprocess."""
    code = _ORACLE_HELPERS + f"""
import gzip, os
import jax
from repro.core import codecs, open_graph
from repro.core.compat import make_mesh
from repro.core import parse_np

calls = [0]
orig = parse_np.parse_chunk_np
parse_np.parse_chunk_np = lambda *a, **k: (calls.__setitem__(0, calls[0] + 1)
                                           or orig(*a, **k))

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(11)
n, v = 4000, 333
src = rng.integers(0, v, n); dst = rng.integers(0, v, n)
w = (rng.random(n) * 9).round(3).astype(np.float32)
tmp = r"{tmp_path}"

for weighted in (False, True):
    for base in (0, 1):
        if weighted:
            body = "\\n".join(f"{{s+base}} {{d+base}} {{x:.3f}}"
                              for s, d, x in zip(src, dst, w))
        else:
            body = "\\n".join(f"{{s+base}} {{d+base}}"
                              for s, d in zip(src, dst))
        raw = os.path.join(tmp, f"g_{{weighted}}_{{base}}.el")
        open(raw, "w").write(body + "\\n")
        data = open(raw, "rb").read()
        gz = raw + ".gz"
        open(gz, "wb").write(gzip.compress(data))
        fz = raw + ".fz"
        codecs.write_framed(fz, data, codec="zlib", frame_beta=4096)
        for path in (raw, gz, fz):
            g = open_graph(path, engine="device", weighted=weighted,
                           base=base, beta=2048)
            csr = g.csr_sharded(mesh)
            check_bitwise(csr, src, dst, w if weighted else None, v, 4)
assert calls[0] == 0, f"host parser ran {{calls[0]}} times on the hot path"
print("PARITY-MATRIX-OK")
"""
    assert "PARITY-MATRIX-OK" in devices4(code)


def test_mesh_wider_than_file(devices4, tmp_path):
    """A 4-shard mesh over a 2-line file: empty spans stay device-resident
    and the CSR still matches the oracle."""
    code = _ORACLE_HELPERS + f"""
from repro.core import open_graph
from repro.core.compat import make_mesh

path = r"{tmp_path}/tiny.el"
open(path, "w").write("1 2\\n2 1\\n")
mesh = make_mesh((4,), ("data",))
csr = open_graph(path, engine="device").csr_sharded(mesh)
src = np.array([0, 1]); dst = np.array([1, 0])
check_bitwise(csr, src, dst, None, 2, 4)
print("TINY-OK")
"""
    assert "TINY-OK" in devices4(code)


def test_indivisible_v_with_zero_edge_shard(devices4, tmp_path):
    """V=13 over d=4 (rows=4: last shard owns only vertex 12) with all
    edges among vertices 0..5 — shards own zero edges / zero vertices'
    worth of real rows and the build still matches."""
    code = _ORACLE_HELPERS + f"""
from repro.core import open_graph
from repro.core.compat import make_mesh

rng = np.random.default_rng(5)
n = 600
src = rng.integers(0, 6, n); dst = rng.integers(0, 6, n)
path = r"{tmp_path}/lop.el"
open(path, "w").write(
    "\\n".join(f"{{s+1}} {{d+1}}" for s, d in zip(src, dst)) + "\\n")
mesh = make_mesh((4,), ("data",))
csr = open_graph(path, engine="device", num_vertices=13,
                 beta=1024).csr_sharded(mesh)
assert csr.num_vertices == 13
check_bitwise(csr, src, dst, None, 13, 4)
print("INDIVISIBLE-OK")
"""
    assert "INDIVISIBLE-OK" in devices4(code)


def test_send_cap_overflow_raises(devices4, tmp_path):
    """A hand-passed send_cap too small for a hub graph raises instead of
    silently dropping edges."""
    code = f"""
import numpy as np
from repro.core.compat import make_mesh
from repro.core.distributed import load_csr_sharded_stream

path = r"{tmp_path}/hub.el"
# every edge targets owner shard 0 (src=1): buckets are maximally skewed
open(path, "w").write("".join("1 {{}}\\n".format(i % 40 + 1)
                              for i in range(400)))
mesh = make_mesh((4,), ("data",))
try:
    load_csr_sharded_stream(mesh, "data", path, num_vertices=40, send_cap=1)
except ValueError as exc:
    assert "overflow" in str(exc), exc
    print("OVERFLOW-OK")
else:
    raise SystemExit("expected ValueError")
"""
    assert "OVERFLOW-OK" in devices4(code)


def test_host_shard_and_load_uses_stream_path(devices4, tmp_path):
    """The compatibility wrapper rides the streamed pipeline: no host
    parser call, same oracle-bitwise result."""
    code = _ORACLE_HELPERS + f"""
from repro.core import host_shard_and_load, parse_np
from repro.core.compat import make_mesh

calls = [0]
orig = parse_np.parse_chunk_np
parse_np.parse_chunk_np = lambda *a, **k: (calls.__setitem__(0, calls[0] + 1)
                                           or orig(*a, **k))
rng = np.random.default_rng(9)
n, v = 2000, 128
src = rng.integers(0, v, n); dst = rng.integers(0, v, n)
path = r"{tmp_path}/c.el"
open(path, "w").write(
    "\\n".join(f"{{s+1}} {{d+1}}" for s, d in zip(src, dst)) + "\\n")
mesh = make_mesh((4,), ("data",))
csr = host_shard_and_load(mesh, "data", path, num_vertices=v)
check_bitwise(csr, src, dst, None, v, 4)
assert calls[0] == 0, calls[0]
print("COMPAT-OK")
"""
    assert "COMPAT-OK" in devices4(code)
