"""ServeRuntime churn contract: snapshot swap under the live server
(zero dropped in-flight requests), straggler degrade instead of stall,
preemption-safe drain, corpus resume, stats surface (docs/serving.md)."""
import os
import shutil

import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.core import make_graph_file
from repro.core.cache import SourceCache
from repro.core.source import open_graph
from repro.data.corpus import CorpusConfig, WalkCorpus
from repro.ft.coordinator import Coordinator, FTConfig
from repro.models import init_params
from repro.serve.runtime import ServeRuntime

CFG = reduced_config("phi4-mini-3.8b")
CC = CorpusConfig(batch=2, seq=8, vocab_size=CFG.vocab_size, seed=5)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.key(3), CFG)


@pytest.fixture()
def snaps(tmp_path):
    """Two different graphs as snapshots; ``a`` is the served path."""
    ela = str(tmp_path / "a.el")
    va, _ = make_graph_file(ela, "rmat", scale=7, edge_factor=6, seed=2)
    a = str(tmp_path / "live.gvel")
    open_graph(ela, engine="numpy", num_vertices=va).save(a)
    elb = str(tmp_path / "b.el")
    vb, _ = make_graph_file(elb, "uniform", scale=6, edge_factor=4, seed=9)
    b = str(tmp_path / "b.gvel")
    open_graph(elb, engine="numpy", num_vertices=vb).save(b)
    return a, b


def _runtime(params, **kw):
    kw.setdefault("batch", 2)
    kw.setdefault("max_seq", 32)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("cache", SourceCache(capacity=4))
    return ServeRuntime(CFG, params, **kw)


def test_serves_more_requests_than_slots(params, snaps):
    a, _ = snaps
    rt = _runtime(params)
    reqs = [rt.submit(a, max_new=4) for _ in range(5)]
    rt.drain()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    st = rt.stats()
    assert st["requests"] == 5 and st["tokens"] == 20
    assert st["ticks"] > 0 and 0 < st["occupancy"] <= 1.0
    assert st["cache"]["hits"] >= 4        # one open, handle reused


def test_deterministic_across_runtimes(params, snaps):
    a, _ = snaps
    rt1 = _runtime(params)
    rt2 = _runtime(params)
    q1 = [rt1.submit(a, max_new=3, rid=i) for i in range(3)]
    q2 = [rt2.submit(a, max_new=3, rid=i) for i in range(3)]
    rt1.drain(), rt2.drain()
    for x, y in zip(q1, q2):
        assert np.array_equal(x.prompt, y.prompt)
        assert x.out == y.out


def test_snapshot_swap_under_live_runtime(params, snaps):
    """The (b) churn criterion: swap the snapshot on disk while
    requests are in flight — nothing is dropped, and the next request
    resolves the new graph via mtime invalidation, no restart."""
    a, b = snaps
    rt = _runtime(params)
    inflight = [rt.submit(a, max_new=4, rid=i) for i in range(5)]
    for _ in range(2):                     # mid-serving, slots busy
        rt.tick()
    shutil.copyfile(b, a)                  # swap under the live server
    post = rt.submit(a, max_new=4, rid=0)  # same rid, new graph bytes
    rt.drain()
    assert all(r.done and len(r.out) == 4 for r in inflight + [post])
    assert rt.cache.stats()["invalidations"] >= 1
    # the post-swap prompt equals a cold open of the swapped file...
    want = _runtime(params).submit(a, max_new=1, rid=0)
    assert np.array_equal(post.prompt, want.prompt)
    # ...and reflects the new graph, not the old one
    assert not np.array_equal(inflight[0].prompt, post.prompt)


def test_straggler_degrades_admission_width(params):
    rt = _runtime(params, ft=FTConfig(straggler_policy="degrade",
                                      straggler_factor=4.0,
                                      straggler_window=6))
    for _ in range(6):
        rt._observe(0.01)
    assert rt.engine.max_active == 2
    rt._observe(1.0)                       # straggler tick -> halve
    assert rt.engine.max_active == 1
    assert rt.stats()["degrades"] == 1
    for _ in range(6):                     # pressure clears -> restore
        rt._observe(0.01)
    assert rt.engine.max_active == 2
    assert rt.stats()["restores"] == 1


def test_degraded_width_still_completes(params, snaps):
    a, _ = snaps
    # huge window: healthy ticks never restore the width mid-test
    rt = _runtime(params, ft=FTConfig(straggler_policy="degrade",
                                      straggler_window=10**6))
    rt.engine.max_active = 1               # degraded: serialized slots
    reqs = [rt.submit(a, max_new=3) for _ in range(4)]
    rt.drain()
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert max(r.slot for r in reqs) == 0  # only slot 0 ever admitted


def test_preemption_pauses_then_resumes_drain(params, snaps):
    a, _ = snaps
    rt = _runtime(params)
    reqs = [rt.submit(a, max_new=6) for _ in range(4)]
    rt.coord.preempted = True              # simulated SIGTERM
    assert rt.drain() == 0                 # stops at the tick boundary
    assert not all(r.done for r in reqs)   # work still queued, not lost
    rt.coord.preempted = False
    rt.drain()
    assert all(r.done and len(r.out) == 6 for r in reqs)


def test_corpus_through_cache_resumes(params, snaps):
    a, _ = snaps
    rt = _runtime(params)
    ref = []
    with rt.corpus(a, CC) as stream:
        for _ in range(5):
            ref.append(np.asarray(next(stream)[1]["tokens"]))
    assert rt.stats()["resumes"] == 0
    with rt.corpus(a, CC, start_step=2) as stream:
        for want in range(2, 5):
            step, batch = next(stream)
            assert step == want
            assert np.array_equal(np.asarray(batch["tokens"]), ref[step])
    assert rt.stats()["resumes"] == 1
    # the corpus resolved through the same cache the requests use
    assert rt.cache.stats()["hits"] >= 1


def test_close_restores_signal_handlers(params):
    import signal
    before = signal.getsignal(signal.SIGUSR1)
    with ServeRuntime(CFG, params, batch=2, max_seq=16,
                      cache=SourceCache(capacity=2),
                      ft=FTConfig(handle_signals=True)) as rt:
        assert signal.getsignal(signal.SIGUSR1) == rt.coord._on_signal
    assert signal.getsignal(signal.SIGUSR1) == before


# ---- robustness: corrupt graphs + degenerate graphs (docs/robustness.md) ----

def _compressed_snap(tmp_path, name, *, seed=2):
    """zlib-framed snapshot with small frames (corruption is section-
    local, so the quarantine scope is observable)."""
    from repro.core import load_edgelist, save_snapshot
    from repro.core.csr import convert_to_csr
    el = str(tmp_path / (name + ".el"))
    v, _ = make_graph_file(el, "rmat", scale=7, edge_factor=6, seed=seed)
    elist = load_edgelist(el, engine="numpy", num_vertices=v, base=1)
    gv = str(tmp_path / name)
    save_snapshot(gv, edgelist=elist,
                  csr=convert_to_csr(elist, engine="numpy"),
                  compress="zlib", frame_beta=96)
    return gv, v


def test_corrupt_graph_quarantined_while_others_serve(params, tmp_path):
    """Tentpole (3): a CRC-failing section quarantines (path, section),
    requests against it get structured CorruptGraphError, admission
    degrades via the straggler path, other graphs keep serving, and a
    swap on disk recovers — all visible in stats()."""
    from test_faults import _corrupt_section
    from repro.core.faults import CorruptGraphError

    live, v = _compressed_snap(tmp_path, "live.gvel", seed=2)
    good, _ = _compressed_snap(tmp_path, "good.gvel", seed=9)
    import shutil
    shutil.copyfile(live, live + ".bak")
    rt = _runtime(params)
    _corrupt_section(live, "csr_indices")

    with pytest.raises(CorruptGraphError) as ei:
        rt.submit(live, max_new=2)
    assert ei.value.path == live and ei.value.section == "csr_indices"
    assert rt.engine.max_active == 1          # degraded, not stalled
    # repeat offenders fail fast from quarantine, no second degrade
    with pytest.raises(CorruptGraphError, match="quarantined"):
        rt.submit(live, max_new=2)
    # ...while other graphs in the same cache/runtime still serve
    req = rt.submit(good, max_new=3)
    rt.drain()
    assert req.done and len(req.out) == 3
    st = rt.stats()
    assert st["corrupt_requests"] == 1
    assert st["degrades"] == 1
    faults_st = st["cache"]["faults"]
    assert faults_st["quarantines"] == 1
    assert faults_st["quarantined"][0]["section"] == "csr_indices"
    assert any("fault: corrupt graph" in e for e in rt.coord.events)

    # swap the good bytes back: quarantine lifts, requests serve again
    os.replace(live + ".bak", live)
    os.utime(live)
    req2 = rt.submit(live, max_new=2)
    rt.drain()
    assert req2.done and len(req2.out) == 2
    assert rt.cache.stats()["faults"]["recovered"] >= 1


def test_zero_edge_graph_serves_end_to_end(params, tmp_path):
    """Satellite (4): a V>0, E=0 graph flows through SourceCache.query
    -> neighbors/degree -> a full ServeRuntime request, under injected
    open faults (retried transparently)."""
    from repro.core import load_edgelist, save_snapshot, write_edgelist
    from repro.core.csr import convert_to_csr
    from repro.core.faults import FaultPlan, FaultSpec, fault_plan

    el = str(tmp_path / "zero.el")
    write_edgelist(el, np.array([], np.int64), np.array([], np.int64),
                   None, base=1)
    elist = load_edgelist(el, engine="numpy", num_vertices=6, base=1)
    gv = str(tmp_path / "zero.gvel")
    save_snapshot(gv, edgelist=elist,
                  csr=convert_to_csr(elist, engine="numpy"),
                  compress="zlib", frame_beta=64)

    rt = _runtime(params)
    plan = FaultPlan([FaultSpec("open", "oserror", times=1)])
    with fault_plan(plan):
        assert list(rt.cache.query(gv, "neighbors", vertex=0)) == []
        assert rt.cache.query(gv, "degree", vertex=5) == 0
        req = rt.submit(gv, max_new=3)       # edgeless walk: self-loops
        rt.drain()
    assert req.done and len(req.out) == 3
    assert plan.injected() == {"open:oserror": 1}
    assert rt.cache.stats()["faults"]["open_retries"] == 1
    assert rt.stats()["corrupt_requests"] == 0
