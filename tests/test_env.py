"""Platform-config layer: XLA flag merging, device-count clamping, and
the fingerprint that keys measured tune profiles."""
import re
import warnings

import pytest

from repro.core import env, tune


def test_set_xla_flag_merges_not_clobbers(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--user_flag=keep --bare")
    env.set_xla_flag("--ours", "1")
    flags = env.get_xla_flags()
    assert flags["--user_flag"] == "keep"
    assert flags["--bare"] is None
    assert flags["--ours"] == "1"
    # replacing an existing flag touches only that flag
    env.set_xla_flag("--ours", "2")
    flags = env.get_xla_flags()
    assert flags["--ours"] == "2" and flags["--user_flag"] == "keep"


def test_forced_host_devices_roundtrip(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    assert env.forced_host_devices() is None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")      # backend is already up in tests
        env.set_host_devices(1)
    assert env.forced_host_devices() == 1


def test_set_host_devices_clamps_to_cores(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    monkeypatch.setattr(env.os, "cpu_count", lambda: 2)
    with pytest.warns(RuntimeWarning, match="2 CPUs available"):
        env.set_host_devices(64)
    assert env.forced_host_devices() == 2


def test_late_platform_change_warns(monkeypatch):
    if not env._jax_initialized():
        pytest.skip("backend not initialized yet in this process")
    with pytest.warns(RuntimeWarning, match="after the JAX backend"):
        env.set_platform("cpu")


def test_fingerprint_shape_and_tune_key():
    fp = env.fingerprint()
    assert re.fullmatch(r"[a-z]+-\w+-cpu\d+-\w+-d\d+-x(32|64)", fp)
    prof = env.platform_profile()
    assert f"cpu{prof['cpu_count']}" in fp
    assert prof["backend"] in fp
    # the autotuner keys its profiles by exactly this fingerprint
    assert tune.host_key() == fp
