"""Fault tolerance: coordinator policies, failure injection + restart."""
import numpy as np
import jax
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import reduced_config
from repro.data.synthetic import synthetic_batch
from repro.ft.coordinator import Coordinator, FTConfig
from repro.models import init_params
from repro.train import loop as train_loop
from repro.train.optimizer import OptimizerConfig
from repro.train.state import init_state
from repro.train.step import make_train_step

CFG = reduced_config("phi4-mini-3.8b")
OC = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=50)


def _setup():
    params = init_params(jax.random.key(0), CFG)
    state = init_state(params)
    step = jax.jit(make_train_step(CFG, OC))
    src = lambda i: synthetic_batch(CFG, 2, 16, i)
    return state, step, src


def test_straggler_detection():
    c = Coordinator(FTConfig(straggler_factor=2.0, straggler_window=10))
    for _ in range(8):
        assert c.observe_step(0.1) == "ok"
    assert c.observe_step(0.5) == "straggler-warn"
    assert any("straggler" in e for e in c.events)


def test_failure_injection_and_restart(tmp_path):
    """Crash at step 5, restart from the step-4 checkpoint, finish run;
    losses after restart equal an uninterrupted run (determinism)."""
    state, step, src = _setup()
    coord = Coordinator(FTConfig(ckpt_every=2))
    coord.inject_failure(5)
    with pytest.raises(RuntimeError, match="injected"):
        train_loop.run(state, step, src, num_steps=8,
                       ckpt_dir=str(tmp_path), coordinator=coord,
                       log=lambda s: None)
    # restart path
    astate = jax.eval_shape(lambda: init_state(
        init_params(jax.random.key(0), CFG)))
    restored, at = ckpt_io.restore(astate, str(tmp_path))
    assert at >= 2
    assert int(restored.step) == at
    state2, hist2 = train_loop.run(restored, step, src, num_steps=8,
                                   coordinator=Coordinator(FTConfig()),
                                   log=lambda s: None)
    assert int(state2.step) == 8

    # uninterrupted reference
    ref_state, ref_step, ref_src = _setup()
    ref, hist_ref = train_loop.run(ref_state, ref_step, ref_src, num_steps=8,
                                   coordinator=Coordinator(FTConfig()),
                                   log=lambda s: None)
    ref_by_step = {h["step"]: h["loss"] for h in hist_ref}
    for h in hist2:
        np.testing.assert_allclose(h["loss"], ref_by_step[h["step"]],
                                   rtol=1e-5)


def test_preemption_checkpoints_and_stops(tmp_path):
    state, step, src = _setup()
    coord = Coordinator(FTConfig(ckpt_every=100))

    calls = {"n": 0}
    real_observe = coord.observe_step

    def observe(dt):
        calls["n"] += 1
        if calls["n"] == 3:
            coord.preempted = True      # simulated SIGTERM
        return real_observe(dt)

    coord.observe_step = observe
    state2, hist = train_loop.run(state, step, src, num_steps=50,
                                  ckpt_dir=str(tmp_path), coordinator=coord,
                                  log=lambda s: None)
    assert len(hist) == 3
    assert ckpt_io.latest_step(str(tmp_path)) == 3


def test_checkpoint_cadence():
    c = Coordinator(FTConfig(ckpt_every=4))
    assert not c.should_checkpoint(0)
    assert c.should_checkpoint(4)
    assert not c.should_checkpoint(5)


def test_signal_handlers_saved_and_restored():
    """Regression: a second Coordinator used to clobber the first's
    handler with no way back; close() now restores the displaced one."""
    import signal

    before = signal.getsignal(signal.SIGUSR1)
    c1 = Coordinator(FTConfig(handle_signals=True))
    assert signal.getsignal(signal.SIGUSR1) == c1._on_signal
    c2 = Coordinator(FTConfig(handle_signals=True))
    assert signal.getsignal(signal.SIGUSR1) == c2._on_signal
    c2.close()                         # unwinds to c1's handler...
    assert signal.getsignal(signal.SIGUSR1) == c1._on_signal
    c1.close()                         # ...and back to the original
    assert signal.getsignal(signal.SIGUSR1) == before
    c1.close()                         # idempotent


def test_coordinator_context_manager_and_signal_delivery():
    import os
    import signal

    before = signal.getsignal(signal.SIGUSR1)
    with Coordinator(FTConfig(handle_signals=True)) as c:
        os.kill(os.getpid(), signal.SIGUSR1)
        assert c.should_stop()
        assert any("preempt" in e for e in c.events)
    assert signal.getsignal(signal.SIGUSR1) == before


def test_no_signal_coordinator_close_is_noop():
    import signal

    before = signal.getsignal(signal.SIGTERM)
    Coordinator(FTConfig()).close()
    assert signal.getsignal(signal.SIGTERM) == before


def test_degrade_policy_and_validation():
    c = Coordinator(FTConfig(straggler_factor=2.0, straggler_window=10,
                             straggler_policy="degrade"))
    for _ in range(8):
        assert c.observe_step(0.1) == "ok"
    assert c.observe_step(0.5) == "straggler-degrade"
    with pytest.raises(ValueError, match="straggler_policy"):
        Coordinator(FTConfig(straggler_policy="panic"))
