"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import (csr_offsets, degree_histogram, degree_histogram_ref,
                           exclusive_scan, exclusive_scan_ref, neighbor_gather,
                           neighbor_gather_ref, parse_edges, parse_edges_ref)

settings.register_profile("kern", max_examples=25, deadline=None)
settings.load_profile("kern")


# ---- parse_edges --------------------------------------------------------------

def _mk_bufs(num_blocks, n, seed, weighted=False):
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(num_blocks):
        lines = []
        size = 0
        while size < n - 24:
            if weighted:
                ln = f"{rng.integers(1, 10**6)} {rng.integers(1, 10**6)} " \
                     f"{rng.random():.4f}"
            else:
                ln = f"{rng.integers(1, 10**6)} {rng.integers(1, 10**6)}"
            lines.append(ln)
            size += len(ln) + 1
        buf = ("\n".join(lines) + "\n").encode()
        row = np.full(n, 10, np.uint8)
        row[:len(buf)] = np.frombuffer(buf, np.uint8)[:n]
        rows.append(row)
    return jnp.asarray(np.stack(rows))


@pytest.mark.parametrize("num_blocks,buf_len,weighted", [
    (1, 256, False), (3, 512, False), (2, 1024, True), (4, 256, True),
])
def test_parse_edges_kernel_vs_ref(num_blocks, buf_len, weighted):
    bufs = _mk_bufs(num_blocks, buf_len, seed=buf_len + num_blocks, weighted=weighted)
    cap = buf_len // 4 + 2
    k = parse_edges(bufs, 0, buf_len, weighted=weighted, edge_cap=cap)
    owned = jnp.asarray([0, buf_len], jnp.int32)
    r = parse_edges_ref(bufs, owned, weighted=weighted, base=1, edge_cap=cap)
    assert np.array_equal(np.asarray(k[3]), np.asarray(r[3]))   # counts
    assert np.array_equal(np.asarray(k[0]), np.asarray(r[0]))   # src
    assert np.array_equal(np.asarray(k[1]), np.asarray(r[1]))   # dst
    if weighted:
        np.testing.assert_allclose(np.asarray(k[2]), np.asarray(r[2]),
                                   rtol=1e-5)


@given(st.integers(1, 4), st.sampled_from([128, 256, 512]),
       st.booleans(), st.integers(0, 10**6))
def test_parse_edges_hypothesis(nb, n, weighted, seed):
    bufs = _mk_bufs(nb, n, seed, weighted)
    cap = n // 4 + 2
    k = parse_edges(bufs, 0, n, weighted=weighted, edge_cap=cap)
    owned = jnp.asarray([0, n], jnp.int32)
    r = parse_edges_ref(bufs, owned, weighted=weighted, base=1, edge_cap=cap)
    assert np.array_equal(np.asarray(k[0]), np.asarray(r[0]))
    assert np.array_equal(np.asarray(k[3]), np.asarray(r[3]))


# ---- degree_histogram ----------------------------------------------------------

@pytest.mark.parametrize("v,e,eblk,vt", [
    (100, 1000, 128, 64), (513, 2047, 256, 128), (64, 64, 512, 512),
])
def test_degree_histogram_sweep(v, e, eblk, vt):
    rng = np.random.default_rng(v + e)
    src = rng.integers(0, v, e).astype(np.int32)
    src[::11] = -1
    got = degree_histogram(jnp.asarray(src), num_vertices=v, e_blk=eblk, vt=vt)
    ref = degree_histogram_ref(jnp.asarray(src), num_vertices=v)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@given(st.integers(2, 300), st.integers(0, 2000), st.integers(0, 99))
def test_degree_histogram_hypothesis(v, e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    got = degree_histogram(jnp.asarray(src), num_vertices=v, e_blk=256, vt=128)
    assert np.array_equal(np.asarray(got),
                          np.bincount(src, minlength=v).astype(np.int32))


# ---- exclusive_scan -------------------------------------------------------------

@pytest.mark.parametrize("n,blk", [(10, 16), (1024, 128), (1000, 256),
                                   (4097, 512)])
def test_exclusive_scan_sweep(n, blk):
    rng = np.random.default_rng(n)
    x = rng.integers(0, 50, n).astype(np.int32)
    got, tot = exclusive_scan(jnp.asarray(x), blk=blk)
    ref, rtot = exclusive_scan_ref(jnp.asarray(x))
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    assert int(tot) == int(rtot)


def test_csr_offsets_shape():
    deg = jnp.asarray([2, 0, 3], jnp.int32)
    off = csr_offsets(deg, blk=16)
    assert np.asarray(off).tolist() == [0, 2, 2, 5]


@given(st.lists(st.integers(0, 100), min_size=1, max_size=500))
def test_exclusive_scan_hypothesis(xs):
    x = np.asarray(xs, np.int32)
    got, tot = exclusive_scan(jnp.asarray(x), blk=64)
    assert np.array_equal(np.asarray(got), np.cumsum(x) - x)
    assert int(tot) == int(x.sum())


# ---- neighbor_gather -------------------------------------------------------------

@pytest.mark.parametrize("v,e,width,bt", [(20, 100, 16, 8), (50, 500, 32, 16),
                                          (5, 40, 64, 4)])
def test_neighbor_gather_sweep(v, e, width, bt):
    rng = np.random.default_rng(v * e)
    src = np.sort(rng.integers(0, v, e)).astype(np.int32)
    deg = np.bincount(src, minlength=v)
    offsets = np.zeros(v + 1, np.int32)
    np.cumsum(deg, out=offsets[1:])
    targets = rng.integers(0, v, e).astype(np.int32)
    verts = rng.integers(0, v, 3 * bt).astype(np.int32)
    got = neighbor_gather(jnp.asarray(verts), jnp.asarray(offsets),
                          jnp.asarray(targets), width=width, bt=bt)
    ref = neighbor_gather_ref(jnp.asarray(verts), jnp.asarray(offsets),
                              jnp.asarray(targets), width=width)
    assert np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    # semantic check: rows match the CSR
    for i, u in enumerate(verts):
        row = targets[offsets[u]:offsets[u + 1]][:width]
        assert np.asarray(got[0][i][:len(row)]).tolist() == row.tolist()
