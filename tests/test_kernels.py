"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import (csr_offsets, degree_histogram, degree_histogram_ref,
                           exclusive_scan, exclusive_scan_ref, neighbor_gather,
                           neighbor_gather_ref, parse_edges, parse_edges_ref)

# hypothesis is optional: the parametrized sweeps must run everywhere, only
# the property-based sweeps skip when it is absent.
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("kern", max_examples=25, deadline=None)
    settings.load_profile("kern")
except ImportError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    class st:  # placeholder strategies so decorators evaluate
        integers = sampled_from = booleans = lists = staticmethod(
            lambda *a, **k: None)


# ---- parse_edges --------------------------------------------------------------

def _mk_bufs(num_blocks, n, seed, weighted=False):
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(num_blocks):
        lines = []
        size = 0
        while size < n - 24:
            if weighted:
                ln = f"{rng.integers(1, 10**6)} {rng.integers(1, 10**6)} " \
                     f"{rng.random():.4f}"
            else:
                ln = f"{rng.integers(1, 10**6)} {rng.integers(1, 10**6)}"
            lines.append(ln)
            size += len(ln) + 1
        buf = ("\n".join(lines) + "\n").encode()
        row = np.full(n, 10, np.uint8)
        row[:len(buf)] = np.frombuffer(buf, np.uint8)[:n]
        rows.append(row)
    return jnp.asarray(np.stack(rows))


@pytest.mark.parametrize("num_blocks,buf_len,weighted", [
    (1, 256, False), (3, 512, False), (2, 1024, True), (4, 256, True),
])
def test_parse_edges_kernel_vs_ref(num_blocks, buf_len, weighted):
    bufs = _mk_bufs(num_blocks, buf_len, seed=buf_len + num_blocks, weighted=weighted)
    cap = buf_len // 4 + 2
    k = parse_edges(bufs, 0, buf_len, weighted=weighted, edge_cap=cap)
    owned = jnp.asarray([0, buf_len], jnp.int32)
    r = parse_edges_ref(bufs, owned, weighted=weighted, base=1, edge_cap=cap)
    assert np.array_equal(np.asarray(k[3]), np.asarray(r[3]))   # counts
    assert np.array_equal(np.asarray(k[0]), np.asarray(r[0]))   # src
    assert np.array_equal(np.asarray(k[1]), np.asarray(r[1]))   # dst
    if weighted:
        np.testing.assert_allclose(np.asarray(k[2]), np.asarray(r[2]),
                                   rtol=1e-5)


@given(st.integers(1, 4), st.sampled_from([128, 256, 512]),
       st.booleans(), st.integers(0, 10**6))
def test_parse_edges_hypothesis(nb, n, weighted, seed):
    bufs = _mk_bufs(nb, n, seed, weighted)
    cap = n // 4 + 2
    k = parse_edges(bufs, 0, n, weighted=weighted, edge_cap=cap)
    owned = jnp.asarray([0, n], jnp.int32)
    r = parse_edges_ref(bufs, owned, weighted=weighted, base=1, edge_cap=cap)
    assert np.array_equal(np.asarray(k[0]), np.asarray(r[0]))
    assert np.array_equal(np.asarray(k[3]), np.asarray(r[3]))


# ---- parse_edges_accumulate (fused pallas-engine path) -----------------------

@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_parse_edges_accumulate_matches_core(weighted, use_kernel):
    """The fused kernel path must match ``core.parse.parse_accumulate``
    bit for bit — same per-byte algebra, same shared compaction."""
    from repro.core.parse import make_accumulators, parse_accumulate
    from repro.kernels import parse_edges_accumulate

    nb, n = 3, 512
    bufs = _mk_bufs(nb, n, seed=7, weighted=weighted)
    cap = nb * (n // 4 + 2)
    bound = nb * (n // 4 + 2)
    os_, oe = jnp.full((nb,), 0, jnp.int32), jnp.full((nb,), n, jnp.int32)

    ref = make_accumulators(cap, weighted=weighted)
    ref = parse_accumulate(*ref, bufs, os_, oe, weighted=weighted, base=1,
                           edge_bound=bound, donate=False)
    got = make_accumulators(cap, weighted=weighted)
    got = parse_edges_accumulate(*got, bufs, 0, n, weighted=weighted, base=1,
                                 edge_bound=bound, use_kernel=use_kernel,
                                 interpret=True, donate=False)
    assert int(got[3]) == int(ref[3])
    assert np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    if weighted:
        assert np.array_equal(np.asarray(got[2]), np.asarray(ref[2]))


def test_parse_edges_accumulate_packs_across_batches():
    from repro.core.parse import make_accumulators
    from repro.kernels import parse_edges_accumulate

    def pad(text, n=64):
        row = np.full(n, 10, np.uint8)
        b = np.frombuffer(text, np.uint8)
        row[:len(b)] = b
        return row

    acc = make_accumulators(16, weighted=False)
    acc = parse_edges_accumulate(
        *acc, jnp.asarray(np.stack([pad(b"1 2\n3 4\n"), pad(b"5 6\n")])),
        0, 64, weighted=False, base=1, edge_bound=8, donate=False)
    acc = parse_edges_accumulate(
        *acc, jnp.asarray(np.stack([pad(b"7 8\n")])), 0, 64,
        weighted=False, base=1, edge_bound=8, donate=False)
    assert int(acc[3]) == 4
    assert np.asarray(acc[0]).tolist() == [0, 2, 4, 6] + [-1] * 12
    assert np.asarray(acc[1]).tolist() == [1, 3, 5, 7] + [-1] * 12


# ---- degree_histogram ----------------------------------------------------------

@pytest.mark.parametrize("v,e,eblk,vt", [
    (100, 1000, 128, 64), (513, 2047, 256, 128), (64, 64, 512, 512),
])
def test_degree_histogram_sweep(v, e, eblk, vt):
    rng = np.random.default_rng(v + e)
    src = rng.integers(0, v, e).astype(np.int32)
    src[::11] = -1
    got = degree_histogram(jnp.asarray(src), num_vertices=v, e_blk=eblk, vt=vt)
    ref = degree_histogram_ref(jnp.asarray(src), num_vertices=v)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@given(st.integers(2, 300), st.integers(0, 2000), st.integers(0, 99))
def test_degree_histogram_hypothesis(v, e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e).astype(np.int32)
    got = degree_histogram(jnp.asarray(src), num_vertices=v, e_blk=256, vt=128)
    assert np.array_equal(np.asarray(got),
                          np.bincount(src, minlength=v).astype(np.int32))


# ---- exclusive_scan -------------------------------------------------------------

@pytest.mark.parametrize("n,blk", [(10, 16), (1024, 128), (1000, 256),
                                   (4097, 512)])
def test_exclusive_scan_sweep(n, blk):
    rng = np.random.default_rng(n)
    x = rng.integers(0, 50, n).astype(np.int32)
    got, tot = exclusive_scan(jnp.asarray(x), blk=blk)
    ref, rtot = exclusive_scan_ref(jnp.asarray(x))
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    assert int(tot) == int(rtot)


def test_csr_offsets_shape():
    deg = jnp.asarray([2, 0, 3], jnp.int32)
    off = csr_offsets(deg, blk=16)
    assert np.asarray(off).tolist() == [0, 2, 2, 5]


@given(st.lists(st.integers(0, 100), min_size=1, max_size=500))
def test_exclusive_scan_hypothesis(xs):
    x = np.asarray(xs, np.int32)
    got, tot = exclusive_scan(jnp.asarray(x), blk=64)
    assert np.array_equal(np.asarray(got), np.cumsum(x) - x)
    assert int(tot) == int(x.sum())


# ---- neighbor_gather -------------------------------------------------------------

@pytest.mark.parametrize("v,e,width,bt", [(20, 100, 16, 8), (50, 500, 32, 16),
                                          (5, 40, 64, 4)])
def test_neighbor_gather_sweep(v, e, width, bt):
    rng = np.random.default_rng(v * e)
    src = np.sort(rng.integers(0, v, e)).astype(np.int32)
    deg = np.bincount(src, minlength=v)
    offsets = np.zeros(v + 1, np.int32)
    np.cumsum(deg, out=offsets[1:])
    targets = rng.integers(0, v, e).astype(np.int32)
    verts = rng.integers(0, v, 3 * bt).astype(np.int32)
    got = neighbor_gather(jnp.asarray(verts), jnp.asarray(offsets),
                          jnp.asarray(targets), width=width, bt=bt)
    ref = neighbor_gather_ref(jnp.asarray(verts), jnp.asarray(offsets),
                              jnp.asarray(targets), width=width)
    assert np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(ref[1]))
    # semantic check: rows match the CSR
    for i, u in enumerate(verts):
        row = targets[offsets[u]:offsets[u + 1]][:width]
        assert np.asarray(got[0][i][:len(row)]).tolist() == row.tolist()
