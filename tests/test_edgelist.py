"""Reader integration: all engines agree on real files; MTX honored."""
import os

import numpy as np
import pytest

from repro.core import (baselines, convert_to_csr, make_graph_file, read_csr,
                        read_edgelist, read_edgelist_numpy, read_mtx,
                        read_mtx_csr, symmetrize, write_mtx)


@pytest.fixture(scope="module")
def graph_file(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("g") / "g.el")
    v, e = make_graph_file(path, "rmat", scale=9, edge_factor=8, seed=7)
    return path, v, e


def _keyset(el):
    n = int(el.num_edges)
    return sorted(zip(np.asarray(el.src[:n]).tolist(),
                      np.asarray(el.dst[:n]).tolist()))


def test_all_readers_agree(graph_file):
    path, v, e = graph_file
    els = {
        "jax": read_edgelist(path, num_vertices=v, beta=8 * 1024),
        "numpy": read_edgelist_numpy(path, num_vertices=v, num_chunks=3),
        "naive": baselines.read_edgelist_naive(path, num_vertices=v),
        "loadtxt": baselines.read_edgelist_loadtxt(path, num_vertices=v),
        "pigo": baselines.read_edgelist_pigo(path, num_vertices=v),
    }
    ref = _keyset(els["naive"])
    for name, el in els.items():
        assert int(el.num_edges) == e, name
        assert _keyset(el) == ref, name


@pytest.mark.parametrize("beta", [4 * 1024, 64 * 1024])
def test_jax_reader_block_size_invariance(graph_file, beta):
    path, v, e = graph_file
    el = read_edgelist(path, num_vertices=v, beta=beta, batch_blocks=3)
    assert int(el.num_edges) == e


def test_read_csr_matches_pigo_csr(graph_file):
    path, v, e = graph_file
    csr = read_csr(path, num_vertices=v, method="staged", rho=4)
    el = baselines.read_edgelist_pigo(path, num_vertices=v)
    ref = baselines.csr_pigo(el)
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(ref.offsets))
    off = np.asarray(ref.offsets)
    for u in range(0, v, 37):
        assert np.array_equal(np.sort(np.asarray(csr.targets[off[u]:off[u + 1]])),
                              np.sort(np.asarray(ref.targets[off[u]:off[u + 1]])))


def test_symmetrize_doubles_edges(graph_file):
    path, v, e = graph_file
    el = read_edgelist_numpy(path, num_vertices=v, symmetric=True)
    assert int(el.num_edges) == 2 * e


def test_weighted_file(tmp_path):
    from repro.core.generate import write_edgelist
    rng = np.random.default_rng(0)
    src = rng.integers(0, 50, 200)
    dst = rng.integers(0, 50, 200)
    w = rng.random(200).astype(np.float32)
    path = str(tmp_path / "w.el")
    write_edgelist(path, src, dst, w)
    el = read_edgelist_numpy(path, weighted=True, num_vertices=50)
    assert int(el.num_edges) == 200
    order = np.lexsort((np.asarray(el.dst[:200]), np.asarray(el.src[:200])))
    ro = np.lexsort((dst, src))
    np.testing.assert_allclose(np.asarray(el.weights[:200])[order],
                               w[ro], atol=1e-4)


def test_mtx_attrs_honored(tmp_path):
    """The PIGO bug the paper calls out: symmetric MTX must materialize
    reverse edges; pattern MTX has no weights."""
    path = str(tmp_path / "g.mtx")
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 0])
    write_mtx(path, src, dst, None, num_vertices=3, symmetric=True)
    el = read_mtx(path)
    assert int(el.num_edges) == 6
    assert el.weights is None
    csr = read_mtx_csr(path)
    deg = np.diff(np.asarray(csr.offsets))
    assert deg.tolist() == [2, 2, 2]


def test_mtx_header_validation(tmp_path):
    path = str(tmp_path / "bad.mtx")
    with open(path, "w") as f:
        f.write("not a matrix market file\n1 2\n")
    with pytest.raises(ValueError):
        read_mtx(path)


def test_mtx_entry_count_check(tmp_path):
    path = str(tmp_path / "trunc.mtx")
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate pattern general\n")
        f.write("3 3 5\n1 2\n2 3\n")     # claims 5, has 2
    with pytest.raises(ValueError):
        read_mtx(path)
