"""The fused/donated streaming accumulator, the staging arena, the
overlong-line guard, the block-geometry autotuner, and the bench_diff
perf gate.

The load-bearing suite here is the bitwise parity matrix: the fused
``parse_accumulate`` path (one jitted program per batch, donated
accumulators, trimmed tail batch) must produce **element-identical**
CSR outputs to the pre-change two-step pipeline (``parse_blocks`` +
``_accumulate_batch`` with a padded tail) across weighted x base x
codec (raw / gzip / framed-zlib).
"""
import gzip
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import load_csr, open_graph
from repro.core.blocks import (MemoryBlockSource, StagingArena, flat_len,
                               owned_range, plan_blocks, stage_blocks,
                               NEWLINE)
from repro.core.build import csr_np, csr_staged
from repro.core.codecs import write_framed
from repro.core.generate import write_edgelist
from repro.core.loader import LoadOptions, _accumulate_batch, resolve_tuned
from repro.core.parse import parse_accumulate, parse_blocks
from repro.core.types import CSR
from repro.core import parse as parse_mod
from repro.core import tune as tune_mod

I32 = jnp.int32
SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")


def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    # <= 4 significant digits: exact in float32 under either summation
    # order, so the bitwise comparison below is meaningful
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


def _unfused_pipeline_csr(data: np.ndarray, v: int, *, weighted, base,
                          beta, overlap, batch_blocks) -> CSR:
    """The pre-change streaming engine, reproduced: separately-jitted
    ``parse_blocks`` per padded batch + scatter ``_accumulate_batch``
    (donation off), then the same pow-2 shrink + staged build the
    loader has always used."""
    plan = plan_blocks(len(data), beta=beta, overlap=overlap)
    os_, oe = owned_range(plan)
    ec = plan.edge_cap
    cap = plan.num_blocks * ec
    acc_src = jnp.full((cap,), -1, I32)
    acc_dst = jnp.full((cap,), -1, I32)
    acc_w = jnp.zeros((cap,), jnp.float32) if weighted else None
    total = jnp.zeros((), I32)
    ostart = jnp.full((batch_blocks,), os_, I32)
    oend = jnp.full((batch_blocks,), oe, I32)
    for start in range(0, plan.num_blocks, batch_blocks):
        ids = np.arange(start, min(start + batch_blocks, plan.num_blocks))
        bufs = stage_blocks(data, plan, ids)
        if len(ids) < batch_blocks:       # the old padded tail batch
            pad = np.full((batch_blocks - len(ids), plan.buf_len), NEWLINE,
                          np.uint8)
            bufs = np.concatenate([bufs, pad])
        src_b, dst_b, w_b, counts = parse_blocks(
            jnp.asarray(bufs), ostart, oend, weighted=weighted, base=base,
            edge_cap=ec)
        acc_src, acc_dst, acc_w, total = _accumulate_batch(
            acc_src, acc_dst, acc_w, total, src_b, dst_b, w_b, counts,
            cap=cap, donate=False)
    n = int(total)
    cap2 = 1 << max(n - 1, 1).bit_length()
    if cap2 < acc_src.shape[0]:
        acc_src, acc_dst = acc_src[:cap2], acc_dst[:cap2]
        acc_w = acc_w[:cap2] if weighted else None
    offsets, targets, ww = csr_staged(acc_src, acc_dst, acc_w, v, rho=4,
                                      weighted=weighted)
    return CSR(np.asarray(offsets).astype(np.int64), np.asarray(targets[:n]),
               np.asarray(ww[:n]) if weighted else None, v)


# ---------------------------------------------------------------------------
# bitwise parity: fused engine == pre-change pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["raw", "gzip", "framed-zlib"])
@pytest.mark.parametrize("weighted,base", [(False, 1), (False, 0),
                                           (True, 1), (True, 0)])
def test_fused_engine_bitwise_equals_unfused(tmp_path, codec, weighted, base):
    beta, bb, overlap = 2048, 2, 64
    path, v, e, _ = _graph(tmp_path, weighted=weighted, base=base,
                           seed=base + 2 * weighted, e=700)
    raw = np.fromfile(path, np.uint8)
    ref = _unfused_pipeline_csr(raw, v, weighted=weighted, base=base,
                                beta=beta, overlap=overlap, batch_blocks=bb)
    if codec == "gzip":
        load_path = path + ".gz"
        with open(load_path, "wb") as f:
            f.write(gzip.compress(raw.tobytes(), 6))
    elif codec == "framed-zlib":
        load_path = path + ".elz"
        # frame size == beta so the forced plan matches the reference
        write_framed(load_path, raw.tobytes(), codec="zlib", frame_beta=beta)
    else:
        load_path = path
    got = load_csr(load_path, engine="device", weighted=weighted, base=base,
                   num_vertices=v, beta=beta, batch_blocks=bb)
    assert np.array_equal(got.offsets, ref.offsets)
    assert np.array_equal(got.targets, ref.targets)
    if weighted:
        assert np.array_equal(got.weights, ref.weights)
    else:
        assert got.weights is None and ref.weights is None


@pytest.mark.parametrize("weighted,base", [(False, 1), (True, 0)])
def test_pallas_engine_bitwise_equals_device(tmp_path, weighted, base):
    """Both streaming engines run the same fused-donated accumulate off
    the same per-byte algebra; their CSR outputs must be identical."""
    path, v, e, _ = _graph(tmp_path, weighted=weighted, base=base, seed=21,
                           e=900)
    dev = load_csr(path, engine="device", weighted=weighted, base=base,
                   num_vertices=v, beta=2048, batch_blocks=2)
    pal = load_csr(path, engine="pallas", weighted=weighted, base=base,
                   num_vertices=v, beta=2048, batch_blocks=2)
    assert np.array_equal(dev.offsets, pal.offsets)
    assert np.array_equal(dev.targets, pal.targets)
    if weighted:
        assert np.array_equal(dev.weights, pal.weights)


@pytest.mark.parametrize("beta,bb", [(1024, 2), (2048, 3), (4096, 8),
                                     (16384, 2)])
def test_multi_batch_grid_matches_oracle(tmp_path, beta, bb):
    """beta x batch_blocks grid (every combo exercises a remainder tail
    or a single short batch) against the host oracle."""
    path, v, e, oracle = _graph(tmp_path, weighted=True, base=1, seed=9,
                                e=900)
    csr = load_csr(path, engine="device", weighted=True, num_vertices=v,
                   beta=beta, batch_blocks=bb)
    assert np.array_equal(np.asarray(csr.offsets, np.int64), oracle.offsets)
    off = oracle.offsets
    for u in range(v):
        mine = sorted(zip(np.asarray(csr.targets[off[u]:off[u + 1]]).tolist(),
                          np.asarray(csr.weights[off[u]:off[u + 1]]).tolist()))
        ref = sorted(zip(oracle.targets[off[u]:off[u + 1]].tolist(),
                         oracle.weights[off[u]:off[u + 1]].tolist()))
        assert mine == ref, (beta, bb, u)


def test_tail_remainder_not_padded(tmp_path):
    """5 blocks / batch_blocks=4 -> the tail runs a 1-block program;
    edges and totals still exact."""
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=3,
                                e=1200)
    size = os.path.getsize(path)
    beta = -(-size // 5)             # exactly 5 blocks
    csr = load_csr(path, engine="device", num_vertices=v, beta=beta,
                   batch_blocks=4)
    assert np.array_equal(np.asarray(csr.offsets, np.int64), oracle.offsets)
    assert int(csr.offsets[-1]) == e


# ---------------------------------------------------------------------------
# donation: in-place accumulation and its documented fallback
# ---------------------------------------------------------------------------

def _tiny_batch(text=b"1 2\n3 4\n"):
    buf = np.frombuffer(text, np.uint8)
    pad = np.concatenate([buf, np.full((-len(buf)) % 64, NEWLINE, np.uint8)])
    bufs = jnp.asarray(pad[None, :])
    os_ = jnp.zeros((1,), I32)
    oe = jnp.full((1,), bufs.shape[1], I32)
    return bufs, os_, oe


def test_parse_accumulate_donate_and_fallback_agree():
    bufs, os_, oe = _tiny_batch()
    outs = {}
    for donate in (False, True):
        acc_s = jnp.full((8,), -1, I32)
        acc_d = jnp.full((8,), -1, I32)
        tot = jnp.zeros((), I32)
        outs[donate] = parse_accumulate(
            acc_s, acc_d, None, tot, bufs, os_, oe, weighted=False, base=1,
            edge_bound=8, donate=donate)
    for a, b in zip(outs[False], outs[True]):
        if a is not None:
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_donation_consumes_inputs_when_supported():
    if not parse_mod.donation_supported():
        pytest.skip("backend refuses donation; fallback covered elsewhere")
    bufs, os_, oe = _tiny_batch()
    acc_s = jnp.full((8,), -1, I32)
    acc_d = jnp.full((8,), -1, I32)
    out = parse_accumulate(acc_s, acc_d, None, jnp.zeros((), I32), bufs,
                           os_, oe, weighted=False, base=1, edge_bound=8,
                           donate=True)
    out[0].block_until_ready()
    assert acc_s.is_deleted() and acc_d.is_deleted()


def test_loader_parity_when_donation_refused(tmp_path, monkeypatch):
    """The documented fallback: a backend that refuses donation runs the
    same fused program without donate_argnums and loads identically."""
    path, v, e, oracle = _graph(tmp_path, weighted=True, base=1, seed=5)
    with_donation = load_csr(path, engine="device", weighted=True,
                             num_vertices=v, beta=2048, batch_blocks=2)
    monkeypatch.setattr(parse_mod, "donation_supported", lambda: False)
    without = load_csr(path, engine="device", weighted=True, num_vertices=v,
                       beta=2048, batch_blocks=2)
    assert np.array_equal(with_donation.offsets, without.offsets)
    assert np.array_equal(with_donation.targets, without.targets)
    assert np.array_equal(with_donation.weights, without.weights)


# ---------------------------------------------------------------------------
# staging arena
# ---------------------------------------------------------------------------

def test_arena_consecutive_stages_not_aliased(tmp_path):
    """Batch i is consumed while batch i+1 stages: the two staged views
    must never share memory.  Slot reuse only comes back at batch i+2
    (the ring), by which point the loader has copied batch i out."""
    data = np.frombuffer(b"".join(f"{i} {i + 1}\n".encode()
                                  for i in range(1, 4000)), np.uint8)
    plan = plan_blocks(len(data), beta=1024, overlap=64)
    arena = StagingArena(flat_len(2, plan))
    source = MemoryBlockSource(data)
    ids = [np.arange(0, 2), np.arange(2, 4), np.arange(4, 6)]
    v0 = source.stage(plan, ids[0], arena=arena)
    v0_copy = np.array(v0)
    v1 = source.stage(plan, ids[1], arena=arena)
    assert not np.shares_memory(v0, v1)
    # staging batch 1 must not have clobbered batch 0's bytes
    assert np.array_equal(v0, v0_copy)
    v2 = source.stage(plan, ids[2], arena=arena)
    assert np.shares_memory(v0, v2)        # ring of 2: slot reused
    # and reuse still stages the right bytes
    assert np.array_equal(np.array(v2), stage_blocks(data, plan, ids[2]))


def test_arena_reuse_refills_padding(tmp_path):
    """A dirty ring slot must not leak the previous batch's bytes into
    the newline padding of a shorter/terminal batch."""
    lines = b"".join(f"{i} {i}\n".encode() for i in range(100, 400))
    data = np.frombuffer(lines, np.uint8)
    plan = plan_blocks(len(data), beta=512, overlap=64)
    arena = StagingArena(flat_len(2, plan))
    source = MemoryBlockSource(data)
    nb = plan.num_blocks
    staged = []
    for start in range(0, nb, 2):
        ids = np.arange(start, min(start + 2, nb))
        got = np.array(source.stage(plan, ids, arena=arena))
        assert np.array_equal(got, stage_blocks(data, plan, ids)), start
        staged.append(got)
    assert len(staged) >= 3                # ring actually wrapped


# ---------------------------------------------------------------------------
# overlong-line detection
# ---------------------------------------------------------------------------

def _comment_file(tmp_path):
    """8 edge lines (32 bytes), one 100-byte comment line, 30 more edges.

    The comment's content occupies bytes [32, 130] (newline at 131), so
    with ``beta=128`` block 1's left-context window [64, 128) holds no
    newline — the deterministic boundary-crossing violation.
    """
    path = str(tmp_path / "comment.el")
    with open(path, "w") as f:
        f.write("1 2\n" * 8)
        f.write("%" + "c" * 98 + "\n")          # 100 bytes incl newline
        f.write("".join(f"{i} {i + 1}\n" for i in range(50, 80)))
    return path


def test_overlong_comment_crossing_boundary_raises(tmp_path):
    path = _comment_file(tmp_path)
    with pytest.raises(ValueError, match="byte offset 128"):
        load_csr(path, engine="device", beta=128, overlap=64,
                 batch_blocks=2)


def test_overlong_comment_inside_one_block_is_fine(tmp_path):
    path = _comment_file(tmp_path)
    csr = load_csr(path, engine="device", beta=1 << 20, overlap=64)
    assert int(csr.offsets[-1]) == 8 + 30       # comment skipped, edges kept


def test_overlong_detection_through_gzip(tmp_path):
    path = _comment_file(tmp_path)
    gz = path + ".gz"
    with open(path, "rb") as fin, open(gz, "wb") as fout:
        fout.write(gzip.compress(fin.read(), 6))
    with pytest.raises(ValueError, match="overlap=64"):
        load_csr(gz, engine="device", beta=128, overlap=64, batch_blocks=2)


def test_stage_blocks_check_lines_names_offset():
    data = np.frombuffer(b"1 2\n" + b"x" * 300 + b"\n3 4\n", np.uint8)
    plan = plan_blocks(len(data), beta=128, overlap=64)
    with pytest.raises(ValueError, match=r"byte offset 128"):
        stage_blocks(data, plan, np.arange(plan.num_blocks),
                     check_lines=True)
    # without the flag (raw byte staging) the same call stages silently
    stage_blocks(data, plan, np.arange(plan.num_blocks))


def test_in_contract_lines_never_flagged(tmp_path):
    """Lines up to overlap bytes never trigger the check, any geometry."""
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=12)
    for beta in (256, 1024, 4096):
        csr = load_csr(path, engine="device", num_vertices=v, beta=beta,
                       overlap=64, batch_blocks=3)
        assert np.array_equal(np.asarray(csr.offsets, np.int64),
                              oracle.offsets)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def _seed_profile(tmp_path, monkeypatch, beta=4096, batch_blocks=3):
    cache = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", cache)
    prof = {"version": tune_mod.PROFILE_VERSION, "hosts": {
        tune_mod.host_key(): {
            "unweighted": {"beta": beta, "batch_blocks": batch_blocks,
                           "sweep": []},
            "weighted": {"beta": beta * 2, "batch_blocks": batch_blocks,
                         "sweep": []}}}}
    with open(cache, "w") as f:
        json.dump(prof, f)
    return cache


def test_tuned_geometry_hits_cache_without_sweeping(tmp_path, monkeypatch):
    _seed_profile(tmp_path, monkeypatch)
    monkeypatch.setattr(tune_mod, "run_sweep",
                        lambda *a, **k: pytest.fail("sweep ran on cache hit"))
    assert tune_mod.tuned_geometry(weighted=False) == {
        "beta": 4096, "batch_blocks": 3}
    assert tune_mod.tuned_geometry(weighted=True) == {
        "beta": 8192, "batch_blocks": 3}


def test_tuned_geometry_sweeps_and_persists_on_miss(tmp_path, monkeypatch):
    cache = str(tmp_path / "fresh.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", cache)
    rows = [{"beta": 1024, "batch_blocks": 2, "seconds": 0.5,
             "mb_per_s": 1.0},
            {"beta": 2048, "batch_blocks": 4, "seconds": 0.9,
             "mb_per_s": 0.5}]
    monkeypatch.setattr(tune_mod, "run_sweep", lambda *a, **k: list(rows))
    got = tune_mod.tuned_geometry(weighted=False)
    assert got == {"beta": 1024, "batch_blocks": 2}
    saved = json.load(open(cache))
    entry = saved["hosts"][tune_mod.host_key()]["unweighted"]
    assert entry["beta"] == 1024 and entry["sweep"] == rows
    # second call must read the file, not re-sweep
    monkeypatch.setattr(tune_mod, "run_sweep",
                        lambda *a, **k: pytest.fail("re-swept"))
    assert tune_mod.tuned_geometry(weighted=False) == got
    assert tune_mod.clear_cache() is True
    assert not os.path.exists(cache)


def test_run_sweep_measures_real_grid():
    data = tune_mod.synthetic_sample(48 * 1024)
    rows = tune_mod.run_sweep(data, betas=(4096, 16384), batch_blocks=(2,),
                              repeat=1)
    assert len(rows) == 2
    assert rows == sorted(rows, key=lambda r: r["seconds"])
    assert all(r["seconds"] > 0 for r in rows)
    best = tune_mod.best_geometry(rows)
    assert best["beta"] in (4096, 16384)


def test_resolve_tuned_fills_unpinned_geometry(tmp_path, monkeypatch):
    _seed_profile(tmp_path, monkeypatch)
    opts = LoadOptions(engine="device", tune=True)
    kw = resolve_tuned(opts).engine_kw
    assert kw == {"beta": 4096, "batch_blocks": 3}
    # explicit values win; only the missing knob is filled
    opts = LoadOptions(engine="device", tune=True,
                       engine_kw={"beta": 777216})
    kw = resolve_tuned(opts).engine_kw
    assert kw == {"beta": 777216, "batch_blocks": 3}
    # host engines ignore tuning entirely
    opts = LoadOptions(engine="numpy", tune=True)
    assert resolve_tuned(opts).engine_kw == {}


def test_load_csr_tune_end_to_end(tmp_path, monkeypatch):
    _seed_profile(tmp_path, monkeypatch, beta=2048, batch_blocks=2)
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=8)
    csr = load_csr(path, engine="device", num_vertices=v, tune=True)
    assert np.array_equal(np.asarray(csr.offsets, np.int64), oracle.offsets)
    src = open_graph(path, engine="device", num_vertices=v, tune=True)
    assert np.array_equal(np.asarray(src.csr().offsets, np.int64),
                          oracle.offsets)


# ---------------------------------------------------------------------------
# bench_diff perf gate
# ---------------------------------------------------------------------------

def _rows(**speedups):
    return [{"name": k, "seconds": 1.0, "mb": 1.0, "speedup": v}
            for k, v in speedups.items()]


def _bench_diff(tmp_path, base_rows, cur_rows, *extra):
    b = tmp_path / "base.json"
    c = tmp_path / "cur.json"
    b.write_text(json.dumps(base_rows))
    c.write_text(json.dumps(cur_rows))
    return subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, "bench_diff.py"),
         str(b), str(c), *extra], capture_output=True, text=True)


def test_bench_diff_passes_within_tolerance(tmp_path):
    r = _bench_diff(tmp_path, _rows(a=2.0, b=10.0), _rows(a=1.8, b=9.0))
    assert r.returncode == 0, r.stdout + r.stderr


def test_bench_diff_fails_on_regression(tmp_path):
    r = _bench_diff(tmp_path, _rows(a=2.0), _rows(a=1.0), "--tol", "0.25")
    assert r.returncode == 1
    assert "REGRESSION" in r.stdout + r.stderr


def test_bench_diff_require_floor(tmp_path):
    ok = _bench_diff(tmp_path, _rows(s=5.0), _rows(s=1.3),
                     "--require-only", "--require", "s>=1.0")
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = _bench_diff(tmp_path, _rows(s=5.0), _rows(s=0.9),
                      "--require-only", "--require", "s>=1.0")
    assert bad.returncode == 1
    missing = _bench_diff(tmp_path, _rows(s=5.0), _rows(other=9.9),
                          "--require-only", "--require", "s>=1.0")
    assert missing.returncode == 1
