"""End-to-end behaviour tests: file -> GVEL -> CSR -> walks -> training."""
import os

import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.core import convert_to_csr, make_graph_file, read_csr, read_edgelist
from repro.data.pipeline import Prefetcher
from repro.data.walks import walk_batch
from repro.models import init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.state import init_state
from repro.train.step import make_train_step


@pytest.fixture(scope="module")
def graph(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("sys") / "g.el")
    v, e = make_graph_file(path, "rmat", scale=9, edge_factor=8, seed=21)
    return path, v, e


def test_end_to_end_graph_to_training(graph):
    """The paper's technique as the data substrate: text file -> staged CSR
    -> random-walk corpus -> LM training; loss must drop."""
    path, v, e = graph
    csr = read_csr(path, num_vertices=v, method="staged", rho=4)
    assert int(csr.offsets[-1]) == e

    cfg = reduced_config("phi4-mini-3.8b")
    params = init_params(jax.random.key(0), cfg)
    state = init_state(params)
    oc = OptimizerConfig(lr=2e-3, warmup_steps=2, decay_steps=60)
    step = jax.jit(make_train_step(cfg, oc))

    losses = []
    for i in range(30):
        batch = walk_batch(csr, cfg, 8, 32, step=i)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_end_to_end_with_prefetcher(graph):
    path, v, e = graph
    csr = read_csr(path, num_vertices=v, engine="numpy")
    cfg = reduced_config("phi4-mini-3.8b")
    state = init_state(init_params(jax.random.key(1), cfg))
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, decay_steps=60)
    step = jax.jit(make_train_step(cfg, oc))
    pf = Prefetcher(lambda i: walk_batch(csr, cfg, 4, 16, i), lookahead=2)
    try:
        for i in range(5):
            state, m = step(state, pf.get(expect_step=i))
            assert np.isfinite(float(m["loss"]))
    finally:
        pf.close()
    assert int(state.step) == 5


def test_jax_engine_matches_numpy_engine_on_csr(graph):
    path, v, e = graph
    a = read_csr(path, num_vertices=v, engine="jax", method="staged")
    b = read_csr(path, num_vertices=v, engine="numpy")
    assert np.array_equal(np.asarray(a.offsets, np.int64),
                          np.asarray(b.offsets))


def test_train_driver_cli(tmp_path, graph):
    from repro.launch.train import main
    path, v, e = graph
    rc = main(["--arch", "musicgen-large", "--reduced", "--steps", "3",
               "--batch", "2", "--seq", "16",
               "--ckpt-dir", str(tmp_path / "ck")])
    assert rc == 0


def test_serve_driver_cli():
    from repro.launch.serve import main
    rc = main(["--arch", "phi4-mini-3.8b", "--reduced", "--requests", "3",
               "--max-new", "4", "--batch", "2", "--max-seq", "32"])
    assert rc == 0
