"""GraphSource front door: wrapper parity vs ``load_*`` across engines
x codecs, header-only ``info()``, section-selective lazy decompression
(instrumented codec counter), deferred corruption errors, memoization,
``LoadOptions`` normalization, and the ``python -m repro.core.source``
probe."""
import gzip
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LoadOptions, available_engines, codecs, get_engine,
                        load_csr, load_edgelist, open_graph, read_snapshot,
                        register_engine, save_snapshot, write_framed)
from repro.core.build import csr_np
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist
from repro.core.loader import _REGISTRY
from repro.core.mtx import read_mtx, write_mtx
from repro.core.snapshot import (SEC_CSR_INDICES, SEC_CSR_OFFSETS,
                                 SEC_CSR_WEIGHTS, SEC_DST, SEC_EDGE_WEIGHTS,
                                 SEC_SRC, SnapshotError)

ENGINES = ["device", "numpy", "threads", "pallas"]
# same staging shapes as test_loader.py so jitted programs are shared;
# framed files force beta to their frame size
SMALL_KW = {"device": dict(beta=4096, batch_blocks=2),
            "pallas": dict(beta=2048, batch_blocks=2)}
FRAME_BETA = {"device": 4096, "pallas": 2048, "numpy": 4096, "threads": 4096}
FORMATS = ["raw", "gzip", "framed-zlib"]


def _graph(tmp_path, *, weighted, base, seed=0, v=60, e=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    path = str(tmp_path / f"g_{weighted}_{base}.el")
    write_edgelist(path, src, dst, w, base=base)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return path, v, e, oracle


def _compressed(path, fmt, frame_beta=4096):
    if fmt == "raw":
        return path
    raw = open(path, "rb").read()
    if fmt == "gzip":
        out = path + ".gz"
        with open(out, "wb") as f:
            f.write(gzip.compress(raw))
        return out
    out = path + ".elz"
    write_framed(out, raw, codec="zlib", frame_beta=frame_beta)
    return out


def _zlib_snapshot(tmp_path, *, weighted=False, seed=3, name="g.z.gvel"):
    """Both-sections (edgelist + prebuilt CSR) zlib-compressed .gvel."""
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=1, seed=seed)
    el = load_edgelist(path, engine="numpy", weighted=weighted,
                       num_vertices=v)
    gv = str(tmp_path / name)
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress="zlib")
    return gv, v, e, oracle


def _assert_edgelists_identical(a, b):
    na, nb = int(a.num_edges), int(b.num_edges)
    assert na == nb
    assert a.num_vertices == b.num_vertices
    assert np.array_equal(np.asarray(a.src[:na]), np.asarray(b.src[:nb]))
    assert np.array_equal(np.asarray(a.dst[:na]), np.asarray(b.dst[:nb]))
    if a.weights is None:
        assert b.weights is None
    else:
        assert np.array_equal(np.asarray(a.weights[:na]),
                              np.asarray(b.weights[:nb]))


def _assert_csrs_identical(a, b):
    assert a.num_vertices == b.num_vertices
    assert np.array_equal(np.asarray(a.offsets, np.int64),
                          np.asarray(b.offsets, np.int64))
    assert np.array_equal(np.asarray(a.targets), np.asarray(b.targets))
    if a.weights is None:
        assert b.weights is None
    else:
        assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))


# ---- wrapper parity: load_* == GraphSource products -------------------------

@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("weighted,base", [(False, 1), (False, 0),
                                           (True, 1), (True, 0)])
def test_wrapper_parity(tmp_path, engine, fmt, weighted, base):
    """load_edgelist/load_csr outputs are element-identical to the
    GraphSource products they now wrap — same engine, same bytes."""
    path, v, e, oracle = _graph(tmp_path, weighted=weighted, base=base,
                                seed=base + 2 * weighted)
    cpath = _compressed(path, fmt, frame_beta=FRAME_BETA[engine])
    kw = SMALL_KW.get(engine, {})

    el_w = load_edgelist(cpath, engine=engine, weighted=weighted, base=base,
                         **kw)
    src = open_graph(cpath, engine=engine, weighted=weighted, base=base, **kw)
    _assert_edgelists_identical(el_w, src.edgelist())

    csr_w = load_csr(cpath, engine=engine, weighted=weighted, base=base,
                     num_vertices=v, **kw)
    src2 = open_graph(cpath, engine=engine, weighted=weighted, base=base,
                      num_vertices=v, **kw)
    _assert_csrs_identical(csr_w, src2.csr())


@pytest.mark.parametrize("compress", [None, "zlib"])
@pytest.mark.parametrize("weighted", [False, True])
def test_wrapper_parity_snapshot_engine(tmp_path, compress, weighted):
    path, v, e, _ = _graph(tmp_path, weighted=weighted, base=1, seed=5)
    el = load_edgelist(path, engine="numpy", weighted=weighted,
                       num_vertices=v)
    gv = str(tmp_path / "g.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress=compress)
    _assert_edgelists_identical(
        load_edgelist(gv, weighted=weighted),
        open_graph(gv, weighted=weighted).edgelist())
    _assert_csrs_identical(
        load_csr(gv, weighted=weighted),
        open_graph(gv, weighted=weighted).csr())


# ---- laziness: header-only info(), section-selective decode -----------------

def test_info_reads_header_only_despite_corrupt_payload(tmp_path):
    """Corrupt a byte inside the first (edgelist src) section payload:
    info() — header + table only — must not notice; the eager reader
    and the first .edgelist() access must."""
    gv, v, e, oracle = _zlib_snapshot(tmp_path)
    with open(gv, "r+b") as f:
        f.seek(4096 + 30)              # inside section 1's frame stream
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x20]))
    get_engine("snapshot").clear_memo()

    src = open_graph(gv)               # validate=True: headers are fine
    info = src.info()
    assert info.format == "gvel" and info.version == 2
    assert info.num_vertices == v and info.num_edges == e
    assert info.codec == "zlib"
    assert info.has_edgelist and info.has_csr

    with pytest.raises(SnapshotError):         # deferred to first access
        src.edgelist()
    # ... but the CSR sections are intact, and only they decode:
    _assert_csrs_identical(src.csr(), oracle)
    with pytest.raises(SnapshotError):         # eager reader: fails at open
        read_snapshot(gv)


def test_inconsistent_csr_offsets_stay_fatal_on_retry(tmp_path):
    """Offsets whose tail disagrees with the header raise at first
    decode AND on every retry — the lazily-memoized cell must not
    serve the inconsistent array the second time around."""
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=11)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    bad_off = np.asarray(oracle.offsets).copy()
    bad_off[-1] -= 1                    # lengths stay right, tail lies
    from repro.core import CSR
    gv = str(tmp_path / "bad_off.z.gvel")
    save_snapshot(gv, edgelist=el,
                  csr=CSR(bad_off, oracle.targets, None, v),
                  compress="zlib")
    get_engine("snapshot").clear_memo()
    src = open_graph(gv)
    with pytest.raises(SnapshotError, match="offsets end"):
        src.csr()
    with pytest.raises(SnapshotError, match="offsets end"):
        src.csr()                       # retry must not serve bad data
    with pytest.raises(SnapshotError, match="offsets end"):
        open_graph(gv).csr()            # nor a fresh handle via the memo


def _decoded_sids(calls):
    return {int(c.rsplit(" ", 1)[1]) for c in calls}


@pytest.mark.parametrize("weighted", [False, True])
def test_csr_never_decodes_edgelist_frames(tmp_path, monkeypatch, weighted):
    """Instrumented codec counter: cold .csr() on a both-sections
    compressed snapshot decodes only CSR sections — never the edgelist
    frame streams, and not even CSR weights unless asked for."""
    gv, v, e, oracle = _zlib_snapshot(tmp_path, weighted=weighted)
    calls = []
    orig = codecs.decompress_frames

    def spy(payload, raw_len, codec, *, context="frame stream"):
        calls.append(context)
        return orig(payload, raw_len, codec, context=context)

    monkeypatch.setattr(codecs, "decompress_frames", spy)
    get_engine("snapshot").clear_memo()

    src = open_graph(gv)
    src.info()
    assert calls == []                         # info() decodes nothing
    csr = src.csr()
    assert _decoded_sids(calls) == ({SEC_CSR_OFFSETS, SEC_CSR_INDICES,
                                     SEC_CSR_WEIGHTS} if weighted else
                                    {SEC_CSR_OFFSETS, SEC_CSR_INDICES})
    assert np.array_equal(np.asarray(csr.offsets, np.int64),
                          np.asarray(oracle.offsets))
    calls.clear()
    src.edgelist()                             # now the edgelist decodes
    assert _decoded_sids(calls) == ({SEC_SRC, SEC_DST, SEC_EDGE_WEIGHTS}
                                    if weighted else {SEC_SRC, SEC_DST})


def test_unweighted_load_of_weighted_snapshot_skips_weight_sections(
        tmp_path, monkeypatch):
    gv, v, e, _ = _zlib_snapshot(tmp_path, weighted=True)
    calls = []
    orig = codecs.decompress_frames

    def spy(payload, raw_len, codec, *, context="frame stream"):
        calls.append(context)
        return orig(payload, raw_len, codec, context=context)

    monkeypatch.setattr(codecs, "decompress_frames", spy)
    get_engine("snapshot").clear_memo()
    csr = open_graph(gv, weighted=False).csr()
    assert csr.weights is None
    assert _decoded_sids(calls) == {SEC_CSR_OFFSETS, SEC_CSR_INDICES}


def test_info_on_text_never_parses(tmp_path, monkeypatch):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=9)
    monkeypatch.setattr("repro.core.source.read_edgelist_via",
                        lambda *a, **k: pytest.fail("info() parsed the file"))
    monkeypatch.setattr("repro.core.source.read_csr_via",
                        lambda *a, **k: pytest.fail("info() parsed the file"))
    info = open_graph(path).info()
    assert info.format == "text" and info.codec is None
    assert info.num_vertices is None and info.num_edges is None
    assert info.size_bytes == os.path.getsize(path)


def test_info_compressed_text(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=9)
    fz = _compressed(path, "framed-zlib")
    info = open_graph(fz).info()
    assert info.format == "text" and info.codec == "framed-zlib"
    assert info.raw_bytes == os.path.getsize(path)
    gz = _compressed(path, "gzip")
    info = open_graph(gz).info()
    assert info.codec == "gzip"
    assert info.raw_bytes == os.path.getsize(path)   # trailer ISIZE


# ---- memoization -------------------------------------------------------------

def test_products_memoized(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=2)
    src = open_graph(path, num_vertices=v)
    assert src.edgelist() is src.edgelist()
    assert src.csr() is src.csr()
    assert src.csr(method="staged", rho=4) is src.csr()
    assert src.csr(method="global") is not src.csr()
    assert src.info() is src.info()


def test_csr_fallback_reuses_memoized_edgelist(tmp_path, monkeypatch):
    """With one engine pinned at open, the symmetric CSR route feeds on
    the memoized edgelist instead of re-reading the file."""
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=2)
    src = open_graph(path, engine="numpy", symmetric=True, num_vertices=v)
    el = src.edgelist()
    for target in ("repro.core.loader.read_edgelist_via",
                   "repro.core.source.read_edgelist_via"):
        monkeypatch.setattr(
            target,
            lambda *a, **k: pytest.fail("re-read despite memoized edgelist"))
    csr = src.csr()
    assert int(csr.offsets[-1]) == 2 * e and int(el.num_edges) == 2 * e


# ---- MTX through the front door ---------------------------------------------

def test_mtx_front_door(tmp_path):
    rng = np.random.default_rng(7)
    v, e = 40, 200
    s, d = rng.integers(0, v, e), rng.integers(0, v, e)
    w = (rng.random(e) * 5).round(2).astype(np.float32)
    m = str(tmp_path / "m.mtx")
    write_mtx(m, s, d, w, num_vertices=v)
    src = open_graph(m)
    info = src.info()
    assert info.format == "mtx" and info.num_vertices == v
    assert info.num_edges == e and info.weighted and info.symmetric is False
    _assert_edgelists_identical(src.edgelist(), read_mtx(m))
    # explicit weighted=False drops the banner's weights
    el = open_graph(m, weighted=False).edgelist()
    assert el.weights is None
    # weighted load of a pattern file is an error
    p = str(tmp_path / "p.mtx")
    write_mtx(p, s, d, num_vertices=v)
    with pytest.raises(ValueError, match="pattern"):
        open_graph(p, weighted=True).edgelist()
    # num_vertices conflicting with the size line is an error
    with pytest.raises(ValueError, match="num_vertices"):
        open_graph(m, num_vertices=v + 5).edgelist()


# ---- stream ------------------------------------------------------------------

def test_stream_product(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=4)
    (s, d, w, total), cap = open_graph(
        path, engine="device", **SMALL_KW["device"]).stream()
    assert int(total) == e and w is None and cap >= e
    with pytest.raises(ValueError, match="stream"):
        open_graph(path, engine="numpy").stream()


# ---- save (the symmetric write path) ----------------------------------------

def test_save_roundtrip(tmp_path):
    path, v, e, oracle = _graph(tmp_path, weighted=True, base=1, seed=6)
    src = open_graph(path, engine="numpy", weighted=True, num_vertices=v)
    out = src.save(str(tmp_path / "g.z.gvel"), compress="zlib:9")
    assert out.format == "gvel" and out.info().version == 2
    assert out.info().codec == "zlib"
    _assert_csrs_identical(out.csr(), src.csr())
    # codec spec with level must round-trip losslessly
    _assert_edgelists_identical(out.edgelist(), src.edgelist())


def test_save_csr_only_snapshot(tmp_path):
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=6)
    gv = str(tmp_path / "csr_only.gvel")
    save_snapshot(gv, csr=oracle)
    out = open_graph(gv).save(str(tmp_path / "csr_only.z.gvel"),
                              compress="zlib")
    assert out.info().has_csr and not out.info().has_edgelist
    _assert_csrs_identical(out.csr(), oracle)
    # csr=False is unsatisfiable for a CSR-only source: error, not a
    # silently-contradictory output file
    with pytest.raises(SnapshotError, match="csr=False"):
        open_graph(gv).save(str(tmp_path / "nope.gvel"), csr=False)


def test_save_parses_text_input_once(tmp_path, monkeypatch):
    """save() needs both products; a cold text source with no engine
    pinned must not parse the file twice (edgelist read + CSR stream)."""
    import repro.core.source as source_mod
    path, v, e, oracle = _graph(tmp_path, weighted=False, base=1, seed=12)
    reads = []
    orig = source_mod.read_edgelist_via

    def spy(p, opts):
        reads.append(opts.engine)
        return orig(p, opts)

    monkeypatch.setattr(source_mod, "read_edgelist_via", spy)
    monkeypatch.setattr(
        "repro.core.loader.read_edgelist_via",
        lambda *a, **k: pytest.fail("CSR route re-read the file"))
    src = open_graph(path, num_vertices=v)
    out = src.save(str(tmp_path / "once.gvel"))
    assert reads == ["numpy"]          # exactly one parse
    _assert_csrs_identical(out.csr(), src.csr())
    assert np.array_equal(np.asarray(out.csr().offsets, np.int64),
                          np.asarray(oracle.offsets))


def test_unknown_codec_id_rejected_at_open(tmp_path):
    import struct
    gv, v, e, _ = _zlib_snapshot(tmp_path, name="badcodec.z.gvel")
    with open(gv, "r+b") as f:
        f.seek(40 + 24)                # first v2 entry's codec_id field
        f.write(struct.pack("<I", 250))
    get_engine("snapshot").clear_memo()
    with pytest.raises(SnapshotError, match="unknown codec id 250"):
        open_graph(gv)                 # validate=True: table metadata
    # validate=False defers; info() still reports the unknown id
    assert "id250" in open_graph(gv, validate=False).info().codec


# ---- open-time validation ----------------------------------------------------

def test_validate_at_open(tmp_path):
    with pytest.raises(OSError):
        open_graph(str(tmp_path / "missing.el"))
    # validate=False defers existence to first access
    src = open_graph(str(tmp_path / "missing.el"), validate=False)
    with pytest.raises(OSError):
        src.edgelist()
    with pytest.raises(ValueError, match="unknown loader engine"):
        open_graph(str(tmp_path / "missing.el"), engine="no-such",
                   validate=False).edgelist()


def test_externally_compressed_gvel_rejected_at_open(tmp_path):
    path, v, e, _ = _graph(tmp_path, weighted=False, base=1, seed=1)
    el = load_edgelist(path, engine="numpy", num_vertices=v)
    gv = str(tmp_path / "g.gvel")
    save_snapshot(gv, edgelist=el)
    gz = gv + ".gz"
    with open(gz, "wb") as f:
        f.write(gzip.compress(open(gv, "rb").read()))
    with pytest.raises(ValueError, match="compressed .gvel"):
        open_graph(gz)


def test_load_options_normalization():
    with pytest.raises(ValueError, match="base"):
        LoadOptions(base=2)
    with pytest.raises(ValueError, match="offset"):
        LoadOptions(offset=-1)
    with pytest.raises(ValueError, match="engine_kw"):
        LoadOptions(engine_kw={"base": 0})
    opts = LoadOptions(engine="numpy", weighted=True,
                       engine_kw={"chunk_bytes": 1024})
    assert opts.read_kwargs() == dict(chunk_bytes=1024, weighted=True,
                                      base=1, num_vertices=None, offset=0)
    assert "num_vertices" not in opts.stream_kwargs()


# ---- engine registry listing (satellite bugfix regression) ------------------

def test_available_engines_sorted_regardless_of_registration_order():
    class First:
        name = "aaa-test-engine"     # sorts first, registered last

        def read_edgelist(self, path, **kw):
            raise NotImplementedError

    try:
        register_engine(First())
        names = available_engines()
        assert names == sorted(names)
        assert names[0] == "aaa-test-engine"
    finally:
        _REGISTRY.pop("aaa-test-engine", None)


def test_get_engine_unknown_error_lists_sorted_names():
    with pytest.raises(ValueError) as ei:
        get_engine("no-such-engine")
    assert str(available_engines()) in str(ei.value)


# ---- python -m repro.core.source probe ---------------------------------------

def test_module_probe_json(tmp_path):
    gv, v, e, _ = _zlib_snapshot(tmp_path)
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-m", "repro.core.source", gv],
                         capture_output=True, text=True, env=env, cwd=root)
    assert out.returncode == 0, out.stderr
    info = json.loads(out.stdout)
    assert info["format"] == "gvel" and info["codec"] == "zlib"
    assert info["num_vertices"] == v and info["num_edges"] == e
    bad = subprocess.run([sys.executable, "-m", "repro.core.source",
                          str(tmp_path / "nope.el")],
                         capture_output=True, text=True, env=env, cwd=root)
    assert bad.returncode == 1
    assert "error" in json.loads(bad.stdout)
