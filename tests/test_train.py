"""Training mechanics: loss decreases, accumulation parity, compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.data.synthetic import synthetic_batch
from repro.models import init_params, loss_fn
from repro.train.optimizer import (OptimizerConfig, clip_by_global_norm,
                                   global_norm, schedule)
from repro.train.state import init_state
from repro.train.step import make_train_step

CFG = reduced_config("phi4-mini-3.8b")
OC = OptimizerConfig(lr=1e-3, warmup_steps=2, decay_steps=100)


def _fixed_batch(cfg, b=4, s=32):
    key = jax.random.key(7)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_loss_decreases_on_fixed_batch():
    params = init_params(jax.random.key(0), CFG)
    state = init_state(params)
    step = jax.jit(make_train_step(CFG, OC))
    batch = _fixed_batch(CFG)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_grad_accum_matches_full_batch():
    params = init_params(jax.random.key(0), CFG)
    batch = _fixed_batch(CFG, b=8)
    s1 = init_state(params)
    s2 = init_state(params)
    st1 = jax.jit(make_train_step(CFG, OC, accum_steps=1))
    st4 = jax.jit(make_train_step(CFG, OC, accum_steps=4))
    s1, m1 = st1(s1, batch)
    s2, m4 = st4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    # parameters after one step must agree to accumulation-order tolerance
    l1 = jax.tree.leaves(s1.params)
    l4 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_compressed_training_converges():
    params = init_params(jax.random.key(0), CFG)
    batch = _fixed_batch(CFG)
    sc = init_state(params, compression=True)
    stc = jax.jit(make_train_step(CFG, OC, compression=True))
    losses = []
    for _ in range(12):
        sc, m = stc(sc, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_compression_error_feedback_buffers_update():
    params = init_params(jax.random.key(0), CFG)
    sc = init_state(params, compression=True)
    stc = jax.jit(make_train_step(CFG, OC, compression=True))
    sc2, _ = stc(sc, _fixed_batch(CFG))
    err_norm = float(global_norm(sc2.error))
    assert err_norm > 0.0   # quantization residue is being carried


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, g = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(g), np.sqrt(90 + 160), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedule_warmup_and_decay():
    oc = OptimizerConfig(lr=1.0, min_lr=0.1, warmup_steps=10, decay_steps=100)
    assert float(schedule(jnp.int32(0), oc)) == 0.0
    assert float(schedule(jnp.int32(10), oc)) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(jnp.int32(200), oc)) == pytest.approx(0.1, rel=1e-3)


def test_synthetic_batches_deterministic():
    b1 = synthetic_batch(CFG, 4, 16, step=5)
    b2 = synthetic_batch(CFG, 4, 16, step=5)
    b3 = synthetic_batch(CFG, 4, 16, step=6)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
