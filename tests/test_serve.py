"""Serving engine: slot management, continuous batching, output determinism."""
import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.models import forward_prefill, forward_decode, init_params
from repro.serve.engine import Request, ServeEngine

CFG = reduced_config("phi4-mini-3.8b")


@pytest.fixture(scope="module")
def setup():
    params = init_params(jax.random.key(3), CFG)
    return params


def test_engine_completes_all_requests(setup):
    eng = ServeEngine(CFG, setup, batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, CFG.vocab_size, 5).astype(np.int32), 6)
            for i in range(7)]   # 7 requests > 4 slots -> continuous batching
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and len(r.out) == 6


def test_engine_greedy_matches_manual_decode(setup):
    """Single request through the engine == manual prefill+decode chain."""
    params = setup
    prompt = np.asarray([5, 17, 3, 42], np.int32)
    eng = ServeEngine(CFG, params, batch=2, max_seq=32)
    req = Request(0, prompt, 4)
    eng.submit(req)
    eng.run()

    import jax.numpy as jnp
    lg, caches = forward_prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                 CFG, max_seq=32)
    # engine slots are batch=2; replicate manually with batch=1
    toks = []
    tok = int(np.argmax(np.asarray(lg[0])))
    # engine's prefill is step-wise, so compare from its first decoded token
    pos = len(prompt)
    caches1 = caches
    toks.append(tok)
    for _ in range(3):
        lg2, caches1 = forward_decode(
            params, {"token": jnp.asarray([tok]),
                     "pos": jnp.asarray([pos], jnp.int32)},
            caches1, CFG, max_seq=32)
        tok = int(np.argmax(np.asarray(lg2[0])))
        pos += 1
        toks.append(tok)
    assert req.out == toks


def test_engine_respects_max_seq(setup):
    eng = ServeEngine(CFG, setup, batch=2, max_seq=16)
    req = Request(0, np.asarray([1, 2, 3], np.int32), 100)
    eng.submit(req)
    eng.run()
    assert req.done and len(req.out) <= 13
