"""Serving engine: slot management, continuous batching, output determinism."""
import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.models import forward_prefill, forward_decode, init_params
from repro.serve.engine import Request, ServeEngine

CFG = reduced_config("phi4-mini-3.8b")


@pytest.fixture(scope="module")
def setup():
    params = init_params(jax.random.key(3), CFG)
    return params


def test_engine_completes_all_requests(setup):
    eng = ServeEngine(CFG, setup, batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, CFG.vocab_size, 5).astype(np.int32), 6)
            for i in range(7)]   # 7 requests > 4 slots -> continuous batching
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and len(r.out) == 6


def test_engine_greedy_matches_manual_decode(setup):
    """Single request through the engine == manual prefill+decode chain."""
    params = setup
    prompt = np.asarray([5, 17, 3, 42], np.int32)
    eng = ServeEngine(CFG, params, batch=2, max_seq=32)
    req = Request(0, prompt, 4)
    eng.submit(req)
    eng.run()

    import jax.numpy as jnp
    lg, caches = forward_prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                 CFG, max_seq=32)
    # engine slots are batch=2; replicate manually with batch=1
    toks = []
    tok = int(np.argmax(np.asarray(lg[0])))
    # engine's prefill is step-wise, so compare from its first decoded token
    pos = len(prompt)
    caches1 = caches
    toks.append(tok)
    for _ in range(3):
        lg2, caches1 = forward_decode(
            params, {"token": jnp.asarray([tok]),
                     "pos": jnp.asarray([pos], jnp.int32)},
            caches1, CFG, max_seq=32)
        tok = int(np.argmax(np.asarray(lg2[0])))
        pos += 1
        toks.append(tok)
    assert req.out == toks


def test_engine_respects_max_seq(setup):
    eng = ServeEngine(CFG, setup, batch=2, max_seq=16)
    req = Request(0, np.asarray([1, 2, 3], np.int32), 100)
    eng.submit(req)
    eng.run()
    assert req.done and len(req.out) <= 13


def test_queue_never_drops_fifo_per_slot(setup):
    """Regression: many more requests than slots — every request is
    admitted (none dropped at tick boundaries) and completion order per
    slot is FIFO (admission follows submit order)."""
    eng = ServeEngine(CFG, setup, batch=3, max_seq=64)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, CFG.vocab_size, 4).astype(np.int32),
                    int(rng.integers(2, 6)))
            for i in range(11)]            # 11 requests > 3 slots
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert not eng.queue and all(s is None for s in eng.slots)
    assert all(r.done and len(r.out) == r.max_new for r in reqs)
    assert sorted(r.rid for r in eng.completed) == list(range(11))
    # per-slot completion order == per-slot admission (= submit) order
    by_slot = {}
    for r in eng.completed:
        by_slot.setdefault(r.slot, []).append(r.rid)
    for slot, rids in by_slot.items():
        assert rids == sorted(rids), (slot, rids)


def test_slot_freed_and_refilled_same_tick(setup):
    """A slot that completes on tick t admits the next queued request
    on tick t (continuous batching), not t+1."""
    eng = ServeEngine(CFG, setup, batch=1, max_seq=32)
    first = Request(0, np.asarray([1, 2], np.int32), 1)
    second = Request(1, np.asarray([3, 4], np.int32), 1)
    eng.submit(first)
    eng.submit(second)
    eng.step()                             # first completes this tick...
    assert first.done
    assert eng.slots[0] is second          # ...second already admitted
    assert not eng.queue


def test_max_active_caps_admission(setup):
    eng = ServeEngine(CFG, setup, batch=4, max_seq=32)
    eng.max_active = 2
    reqs = [Request(i, np.asarray([1, 2], np.int32), 3) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        assert sum(1 for s in eng.slots if s is not None) <= 2
    assert all(r.done for r in reqs)
    assert {r.slot for r in reqs} <= {0, 1}


def test_run_max_ticks_raises_instead_of_dropping(setup):
    eng = ServeEngine(CFG, setup, batch=1, max_seq=64)
    for i in range(4):
        eng.submit(Request(i, np.asarray([1, 2], np.int32), 8))
    with pytest.raises(RuntimeError, match="pending"):
        eng.run(max_ticks=2)
    assert eng.queue or any(s is not None for s in eng.slots)  # kept, not lost
    eng.run()                              # a fresh drain finishes them
    assert len(eng.completed) == 4
