"""Checkpointing: roundtrip, atomicity, resume, elastic reshard (8->4 devs)."""
import os

import numpy as np
import jax
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import reduced_config
from repro.models import init_params
from repro.train.state import abstract_state, init_state

CFG = reduced_config("phi4-mini-3.8b")


def _state():
    return init_state(init_params(jax.random.key(1), CFG))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    ckpt_io.save(state, str(tmp_path), 7)
    astate = jax.eval_shape(lambda: _state())
    restored, step = ckpt_io.restore(astate, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest_step(tmp_path):
    state = _state()
    h = ckpt_io.save(state, str(tmp_path), 3, async_=True)
    h.join()
    ckpt_io.save(state, str(tmp_path), 9)
    assert ckpt_io.latest_step(str(tmp_path)) == 9


def test_tmp_dirs_are_not_checkpoints(tmp_path):
    state = _state()
    ckpt_io.save(state, str(tmp_path), 5)
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert ckpt_io.latest_step(str(tmp_path)) == 5


def test_resume_replays_deterministically(tmp_path):
    """Train 6 steps; restart from step-3 checkpoint; same final loss."""
    from repro.data.synthetic import synthetic_batch
    from repro.train.optimizer import OptimizerConfig
    from repro.train.step import make_train_step

    oc = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=50)
    step_fn = jax.jit(make_train_step(CFG, oc))
    src = lambda i: synthetic_batch(CFG, 2, 16, i)

    state = _state()
    losses = []
    for i in range(6):
        if i == 3:
            ckpt_io.save(state, str(tmp_path), 3)
        state, m = step_fn(state, src(i))
        losses.append(float(m["loss"]))

    astate = jax.eval_shape(lambda: _state())
    state2, at = ckpt_io.restore(astate, str(tmp_path), 3)
    losses2 = []
    for i in range(3, 6):
        state2, m = step_fn(state2, src(i))
        losses2.append(float(m["loss"]))
    np.testing.assert_allclose(losses[3:], losses2, rtol=1e-6)


def test_elastic_reshard_across_device_counts(tmp_path, devices8):
    """Save on an 8-device mesh, restore on 4 (and back) — values equal."""
    code = f"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.compat import make_mesh, device_mesh
import sys
from repro.checkpoint import io as ckpt_io
from repro.configs import reduced_config
from repro.models import init_params, abstract_params
from repro.distributed import sharding as shd

cfg = reduced_config("phi4-mini-3.8b")
params = init_params(jax.random.key(2), cfg)
mesh8 = make_mesh((4, 2), ("data", "model"))
sh8 = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh8, fsdp=True)
p8 = jax.device_put(params, sh8)
ckpt_io.save(p8, r"{tmp_path}", 1)

devs = np.array(jax.devices()[:4]).reshape(2, 2)
mesh4 = device_mesh(devs, ("data", "model"))
sh4 = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh4, fsdp=True)
p4, step = ckpt_io.restore(jax.eval_shape(lambda: params), r"{tmp_path}", 1,
                           shardings=sh4)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p4)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("RESHARD-OK", step)
"""
    out = devices8(code)
    assert "RESHARD-OK 1" in out
