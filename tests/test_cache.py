"""Hot-graph cache (``repro.core.cache``): LRU bound and eviction
order, ``(path, mtime, size)`` invalidation on snapshot swap,
single-flight cold opens, a threaded hammer (no corruption, no
double-open, deterministic results), query-op dispatch, and the
instrumented-codec counter proving a row-range query through the cache
decodes only the frames its span touches."""
import os
import threading

import numpy as np
import pytest

from repro.core import (codecs, load_edgelist, open_graph, save_snapshot)
from repro.core.build import csr_np
from repro.core.cache import SourceCache, default_cache, query
from repro.core.csr import convert_to_csr
from repro.core.generate import write_edgelist

FRAME_BETA = 96


def _snapshot(tmp_path, name, *, seed=0, v=60, e=400, compress="zlib",
              weighted=False):
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, v, e), rng.integers(0, v, e)
    w = (rng.random(e) * 9).round(3).astype(np.float32) if weighted else None
    el_path = str(tmp_path / f"{name}.el")
    write_edgelist(el_path, src, dst, w, base=1)
    el = load_edgelist(el_path, engine="numpy", weighted=weighted,
                       num_vertices=v)
    gv = str(tmp_path / f"{name}.gvel")
    save_snapshot(gv, edgelist=el, csr=convert_to_csr(el, engine="numpy"),
                  compress=compress, frame_beta=FRAME_BETA)
    oracle = csr_np(src.astype(np.int32), dst.astype(np.int32), w, v)
    return gv, v, oracle


# ---- LRU semantics -----------------------------------------------------------

def test_lru_bound_and_eviction_order(tmp_path):
    paths = [_snapshot(tmp_path, f"g{i}", seed=i)[0] for i in range(3)]
    c = SourceCache(capacity=2)
    a = c.get(paths[0])
    b = c.get(paths[1])
    assert len(c) == 2 and paths[0] in c and paths[1] in c
    c.get(paths[2])                       # evicts paths[0] (LRU)
    assert len(c) == 2
    assert paths[0] not in c and paths[1] in c and paths[2] in c
    assert c.stats()["evictions"] == 1
    c.get(paths[1])                       # touch: 1 newer than 2
    c.get(paths[0])                       # now evicts paths[2]
    assert paths[2] not in c and paths[1] in c
    # the evicted handle still works for its holder, and a re-get
    # returns a fresh handle with identical results
    assert np.array_equal(a.neighbors(5), c.get(paths[0]).neighbors(5))
    assert c.get(paths[1]) is b           # hit: same object


def test_capacity_validation():
    with pytest.raises(ValueError):
        SourceCache(capacity=0)


def test_distinct_kwargs_distinct_entries(tmp_path):
    gv, v, _ = _snapshot(tmp_path, "g", weighted=True)
    c = SourceCache(capacity=4)
    s1 = c.get(gv)
    s2 = c.get(gv, weighted=False)
    assert s1 is not s2
    assert len(c) == 2
    assert c.get(gv) is s1


def test_missing_path_raises_and_caches_nothing(tmp_path):
    c = SourceCache(capacity=2)
    with pytest.raises(FileNotFoundError):
        c.get(str(tmp_path / "nope.gvel"))
    assert len(c) == 0


def test_failed_open_not_cached(tmp_path):
    gv, _, _ = _snapshot(tmp_path, "g")
    boom = {"n": 2}

    def flaky(path, **kw):
        if boom["n"]:
            boom["n"] -= 1
            raise RuntimeError("transient")
        return open_graph(path, **kw)

    c = SourceCache(capacity=2, open_fn=flaky)
    for _ in range(2):
        with pytest.raises(RuntimeError):
            c.get(gv)
    assert len(c) == 0
    assert c.get(gv) is c.get(gv)         # recovered, and cached


def test_failed_open_releases_waiters(tmp_path):
    """Single-flight with a failing opener: the pending event must be
    set on *every* exit from the opener, so a waiter parked on the slot
    retries (and succeeds) instead of blocking forever."""
    gv, _, _ = _snapshot(tmp_path, "g")
    entered = threading.Event()
    gate = threading.Event()
    calls = []

    def flaky(path, **kw):
        calls.append(1)
        if len(calls) == 1:               # first opener fails...
            entered.set()
            gate.wait(5)                  # ...only after the waiter parks
            raise RuntimeError("boom")
        return open_graph(path, **kw)     # retries succeed

    c = SourceCache(capacity=2, open_fn=flaky)
    results = {}

    def opener():
        try:
            results["opener"] = c.get(gv)
        except RuntimeError as exc:
            results["opener"] = exc

    def waiter():
        entered.wait(5)
        results["waiter"] = c.get(gv)

    t1 = threading.Thread(target=opener)
    t2 = threading.Thread(target=waiter)
    t1.start(), t2.start()
    entered.wait(5)
    t2.join(0.3)                          # park the waiter on the slot
    gate.set()                            # now let the opener raise
    t1.join(5), t2.join(5)
    assert not t2.is_alive(), "waiter blocked forever on a failed open"
    assert isinstance(results["opener"], RuntimeError)
    # the waiter retried: it either re-opened itself or found the entry
    assert results["waiter"].neighbors(5) is not None
    assert len(calls) >= 2


# ---- invalidation on snapshot swap -------------------------------------------

def test_swap_invalidates_on_next_request(tmp_path):
    gv, v, oracle1 = _snapshot(tmp_path, "swap", seed=1)
    c = SourceCache(capacity=2)
    got1 = c.query(gv, "neighbors", vertex=7)
    e_lo, e_hi = int(oracle1.offsets[7]), int(oracle1.offsets[8])
    assert np.array_equal(got1, oracle1.targets[e_lo:e_hi])
    # swap a different graph in at the same path (atomic-replace style);
    # force the mtime forward so coarse filesystem clocks can't hide it
    gv2, _, oracle2 = _snapshot(tmp_path, "swap2", seed=2, e=350)
    os.replace(gv2, gv)
    st = os.stat(gv)
    os.utime(gv, ns=(st.st_atime_ns, st.st_mtime_ns + 1_000_000_000))
    got2 = c.query(gv, "neighbors", vertex=7)
    e_lo, e_hi = int(oracle2.offsets[7]), int(oracle2.offsets[8])
    assert np.array_equal(got2, oracle2.targets[e_lo:e_hi])
    assert c.stats()["invalidations"] == 1
    assert c.stats()["misses"] == 2


def test_explicit_invalidate(tmp_path):
    p0, _, _ = _snapshot(tmp_path, "i0")
    p1, _, _ = _snapshot(tmp_path, "i1", seed=1)
    c = SourceCache(capacity=4)
    c.get(p0), c.get(p0, weighted=False), c.get(p1)
    assert len(c) == 3
    assert c.invalidate(p0) == 2          # both kwarg variants drop
    assert len(c) == 1 and p1 in c
    assert c.invalidate(p0) == 0
    c.clear()
    assert len(c) == 0


# ---- single-flight + threaded hammer -----------------------------------------

def test_cold_open_is_single_flight(tmp_path):
    gv, _, _ = _snapshot(tmp_path, "g")
    opens = []
    gate = threading.Event()

    def slow_open(path, **kw):
        opens.append(path)
        gate.wait(5)                      # hold every waiter on the opener
        return open_graph(path, **kw)

    c = SourceCache(capacity=2, open_fn=slow_open)
    got = []
    threads = [threading.Thread(target=lambda: got.append(c.get(gv)))
               for _ in range(8)]
    for t in threads:
        t.start()
    while not opens:                      # first thread reached the open
        pass
    gate.set()
    for t in threads:
        t.join()
    assert len(opens) == 1, "double-open on a cold path"
    assert len(got) == 8 and all(g is got[0] for g in got)


def test_threaded_hammer_mixed_ops(tmp_path):
    corpus = [_snapshot(tmp_path, f"h{i}", seed=i, weighted=(i % 2 == 0))
              for i in range(3)]
    opens = []
    lock = threading.Lock()

    def counting_open(path, **kw):
        with lock:
            opens.append(path)
        return open_graph(path, **kw)

    c = SourceCache(capacity=len(corpus), open_fn=counting_open)
    start = threading.Barrier(8)
    errors = []

    def worker(wid):
        rng = np.random.default_rng(wid)
        try:
            start.wait()
            for _ in range(120):
                gv, v, oracle = corpus[rng.integers(0, len(corpus))]
                op = rng.integers(0, 4)
                u = int(rng.integers(0, v))
                e_lo, e_hi = int(oracle.offsets[u]), int(oracle.offsets[u + 1])
                if op == 0:
                    got = c.query(gv, "neighbors", vertex=u)
                    assert np.array_equal(got, oracle.targets[e_lo:e_hi])
                elif op == 1:
                    assert c.query(gv, "degree", vertex=u) == e_hi - e_lo
                elif op == 2:
                    hi = min(v, u + int(rng.integers(1, 9)))
                    part = c.query(gv, "rows", rows=(u, hi))
                    lo_e = int(oracle.offsets[u])
                    hi_e = int(oracle.offsets[hi])
                    assert np.array_equal(part.targets,
                                          oracle.targets[lo_e:hi_e])
                    assert np.array_equal(
                        part.offsets,
                        oracle.offsets[u:hi + 1] - oracle.offsets[u])
                else:
                    full = c.query(gv, "csr")
                    assert np.array_equal(full.offsets, oracle.offsets)
        except Exception as exc:          # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:3]
    # capacity covers the corpus and nothing was swapped: every path
    # opened exactly once across all 8 threads — no double-open
    assert sorted(opens) == sorted(p for p, _, _ in corpus)
    st = c.stats()
    assert st["misses"] == len(corpus)
    assert st["hits"] == 8 * 120 - len(corpus)
    assert st["evictions"] == 0


# ---- query dispatch ----------------------------------------------------------

def test_query_ops_and_validation(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "q", weighted=True)
    c = SourceCache(capacity=2)
    info = c.query(gv, "info")
    assert info.num_vertices == v
    assert info.section_frames["csr_offsets"] >= 1
    full = c.query(gv, "csr")
    assert np.array_equal(full.offsets, oracle.offsets)
    el = c.query(gv, "edgelist")
    assert int(el.num_edges) == int(oracle.offsets[-1])
    ids, w = c.query(gv, "neighbors", vertex=3, with_weights=True)
    e_lo, e_hi = int(oracle.offsets[3]), int(oracle.offsets[4])
    assert np.array_equal(w, oracle.weights[e_lo:e_hi])
    with pytest.raises(ValueError, match="rows"):
        c.query(gv, "rows")
    with pytest.raises(ValueError, match="vertex"):
        c.query(gv, "neighbors")
    with pytest.raises(ValueError, match="vertex"):
        c.query(gv, "degree")
    with pytest.raises(ValueError, match="unknown query op"):
        c.query(gv, "pagerank")


def test_module_level_query_uses_default_cache(tmp_path):
    gv, v, oracle = _snapshot(tmp_path, "m")
    before = default_cache().stats()["misses"]
    got = query(gv, "degree", vertex=5)
    assert got == int(oracle.offsets[6]) - int(oracle.offsets[5])
    assert default_cache() is default_cache()
    assert default_cache().stats()["misses"] == before + 1
    default_cache().invalidate(gv)        # don't leak tmp handles


# ---- instrumented codec counter ----------------------------------------------

def test_cached_row_query_decodes_only_touched_frames(tmp_path, monkeypatch):
    gv, v, oracle = _snapshot(tmp_path, "frames", weighted=False)
    calls = []
    real_frame, real_full = codecs.decode_frame, codecs.decompress_frames

    def frame_spy(payload, entry, codec, **kw):
        calls.append((kw.get("context", ""), entry.index))
        return real_frame(payload, entry, codec, **kw)

    monkeypatch.setattr(codecs, "decode_frame", frame_spy)
    monkeypatch.setattr(
        codecs, "decompress_frames",
        lambda *a, **kw: calls.append(("FULL", -1)) or real_full(*a, **kw))

    c = SourceCache(capacity=2)
    frames = c.query(gv, "info").section_frames
    assert frames["csr_indices"] > 3
    n0 = len(calls)
    part = c.query(gv, "rows", rows=(20, 24))
    e_lo, e_hi = int(oracle.offsets[20]), int(oracle.offsets[24])
    assert np.array_equal(part.targets, oracle.targets[e_lo:e_hi])
    assert not [1 for ctx, _ in calls if ctx == "FULL"]
    expect_off = {i for i in range(frames["csr_offsets"])
                  if i * FRAME_BETA < 25 * 8 and (i + 1) * FRAME_BETA > 20 * 8}
    expect_idx = {i for i in range(frames["csr_indices"])
                  if i * FRAME_BETA < e_hi * 4
                  and (i + 1) * FRAME_BETA > e_lo * 4}
    by_sec = {}
    for ctx, idx in calls[n0:]:
        by_sec.setdefault(ctx.rsplit(" ", 1)[1], set()).add(idx)
    assert by_sec == {"4": expect_off, "5": expect_idx}
    # a repeat through the cache is decode-free: the handle (and its
    # frame cache) survived in the LRU
    n1 = len(calls)
    c.query(gv, "rows", rows=(20, 24))
    c.query(gv, "neighbors", vertex=22)
    assert len(calls) == n1
