#!/usr/bin/env python
"""Fail on broken relative links in markdown files.

  python scripts/check_links.py README.md docs

Arguments are markdown files or directories (scanned for ``*.md``).
External links (http/https/mailto) and pure in-page anchors are
skipped; everything else is resolved relative to the file that contains
it and must exist.  Exit code 1 lists every broken link.
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — target up to the first closing paren (no nested parens
# in this repo's docs); also matches images ![alt](target).
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# ``code`` spans and fenced blocks may contain (...) that are not links
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_INLINE_CODE = re.compile(r"`[^`]*`")


def md_files(targets: list[str]) -> list[str]:
    out = []
    for t in targets:
        if os.path.isdir(t):
            for name in sorted(os.listdir(t)):
                if name.endswith(".md"):
                    out.append(os.path.join(t, name))
        else:
            out.append(t)
    return out


def check_file(path: str) -> list[str]:
    text = open(path, encoding="utf-8").read()
    text = _FENCE.sub("", text)
    text = _INLINE_CODE.sub("", text)
    broken = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]          # strip in-page anchor
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            broken.append(f"{path}: broken link '{target}' "
                          f"(resolved to {resolved})")
    return broken


def main(argv: list[str]) -> int:
    targets = argv or ["README.md", "docs"]
    files = [f for f in md_files(targets) if os.path.exists(f)]
    missing = [t for t in targets if not os.path.exists(t)]
    broken = [msg for f in files for msg in check_file(f)]
    broken += [f"link-check target does not exist: {t}" for t in missing]
    if broken:
        print("\n".join(broken), file=sys.stderr)
        print(f"check_links: {len(broken)} broken link(s) in {len(files)} "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"check_links: {len(files)} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
