#!/usr/bin/env bash
# Tier-1 verify: the exact command from ROADMAP.md, run from any cwd,
# plus the docs link check and a convert.py snapshot round-trip smoke.
#   scripts/verify.sh                 # full tier-1 + smoke
#   scripts/verify.sh -m 'not slow'   # quick loop (skips the 1M-edge test)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# global watchdog on the tier-1 lane: a hung test (stuck reader, wedged
# prefetch thread) fails the run instead of wedging it.  SIGTERM first,
# SIGKILL 30s later if pytest won't die.
timeout --kill-after=30 "${VERIFY_TIMEOUT_S:-2400}" \
    python -m pytest -x -q "$@"

# multi-device lane: the sharded streaming tests under 4 forced CPU host
# devices.  (tests/conftest.py pops XLA_FLAGS at import — the device
# oracle tests run in subprocesses that set their own flag — so this
# lane's env only pins the host-side tests' view of the platform.)
JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -q tests/test_sharded_stream.py

# docs: every relative link in README.md / docs/*.md must resolve
python scripts/check_links.py README.md docs

# snapshot smoke: tiny text fixture -> scripts/convert.py -> load_csr
# must match the csr_np host oracle, raw and zlib-compressed (.gvel v2)
python - <<'PY'
import os, subprocess, sys, tempfile
import numpy as np
from repro.core import load_csr, make_graph_file, read_edgelist_numpy, read_snapshot
from repro.core.build import csr_np

tmp = tempfile.mkdtemp(prefix="gvel_smoke_")
el_path = os.path.join(tmp, "tiny.el")
v, e = make_graph_file(el_path, "uniform", scale=8, edge_factor=4, seed=3)
el = read_edgelist_numpy(el_path, num_vertices=v)
n = int(el.num_edges)
ref = csr_np(np.asarray(el.src[:n]), np.asarray(el.dst[:n]), None, v)

def check(gv):
    got = load_csr(gv, engine="snapshot")
    assert np.array_equal(np.asarray(got.offsets, np.int64), ref.offsets), gv
    off = ref.offsets
    for u in range(v):
        assert np.array_equal(np.sort(np.asarray(got.targets[off[u]:off[u+1]])),
                              np.sort(ref.targets[off[u]:off[u+1]])), (gv, u)

gv = os.path.join(tmp, "tiny.gvel")
subprocess.run([sys.executable, "scripts/convert.py", el_path, gv,
                "--num-vertices", str(v)], check=True)
check(gv)
gvz = os.path.join(tmp, "tiny.z.gvel")
subprocess.run([sys.executable, "scripts/convert.py", el_path, gvz,
                "--num-vertices", str(v), "--compress", "zlib"], check=True)
assert read_snapshot(gvz).version == 2
check(gvz)
print("snapshot smoke: convert.py round-trip OK (raw + zlib .gvel v2)")
PY

# benchmark smoke: the e2e loader benchmark (incl. compressed + lazy
# rows) must still execute end to end — benchmark code can't rot
# unexecuted.  --json emits machine-readable {name, seconds, mb,
# speedup} rows; BENCH_e2e.json committed from a full (non-quick) run
# is the cross-PR perf trajectory.
python -m benchmarks.e2e_load_csr --quick --json /tmp/BENCH_e2e_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_e2e_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
print(f"benchmark json: {len(rows)} rows OK")
PY

# perf gate: the streaming engine must never fall back below the batch
# round-trip (speedup >= 1.0 even on the --quick graph, where fixed
# costs compress ratios), and the sharded streaming load at d=4 must
# stay on the same baseline axis (its speedup row is normalized through
# the same-split streaming re-timing; a retrace-per-load regression
# shows up here at ~0.14x).  Floors only — quick-run speedups are not
# comparable to the committed full-run rows, so tolerance mode is for
# full-vs-full diffs across PRs (see scripts/bench_diff.py).
# ...and the binned CSR build must stay at least as fast as the staged
# build it fronts (its speedup field is staged/binned, not the baseline
# axis — see benchmarks/e2e_load_csr.py).
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_e2e_quick.json \
    --require-only --require 'e2e.load_csr_streaming>=1.0' \
    --require 'e2e.load_csr_sharded_d4>=1.0' \
    --require 'e2e.csr_build_binned>=1.0'

# query-service smoke + gate: thousands of mixed point/range/full
# requests through the hot-graph cache (tests/test_query.py and
# tests/test_cache.py run in the main pytest lane above).  The floor
# pins serving a request to never cost more than the naive
# open-full-load-slice answer (speedup >= 1.0) — if the selective
# path rots back to full-section reads, it shows up here.
python -m benchmarks.query_service --quick --json /tmp/BENCH_query_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_query_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
names = {r["name"] for r in rows}
assert "e2e.query_mixed" in names, names
print(f"query benchmark json: {len(rows)} rows OK")
PY
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_query_quick.json \
    --require-only --require 'e2e.query_mixed>=1.0'

# serving smoke + gate: sustained walk-LM traffic through the
# ServeRuntime (snapshot corpus -> hot-graph cache -> continuous
# batching; tests/test_runtime.py and tests/test_corpus.py run in the
# main lane above).  The floor pins a served request to never cost
# more than the naive reload-per-request + solo-decode answer — if the
# runtime's cache/batching path rots, it shows up here.
python -m benchmarks.serve_walks --quick --json /tmp/BENCH_serve_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_serve_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
names = {r["name"] for r in rows}
assert {"e2e.serve_walks_tokens", "e2e.serve_resume"} <= names, names
print(f"serve benchmark json: {len(rows)} rows OK")
PY
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_serve_quick.json \
    --require-only --require 'e2e.serve_walks_tokens>=1.0'

# chaos lane: the seeded fault matrix (scripts/chaos_matrix.py;
# docs/robustness.md).  Four local scenarios — transient-retry bitwise
# parity, stuck-reader StageTimeout within the watchdog budget,
# corrupt-frame quarantine + swap-on-disk recovery, and the SIGTERM
# cursor-resume churn contract — plus the sharded lane: a shard whose
# in-span retries exhaust re-executes its byte span bitwise-equal to
# the fault-free load, under 4 forced CPU host devices.  Every
# scenario is timeout-wrapped: a recovery path that hangs is a
# failure, not a stall.
timeout --kill-after=30 600 python scripts/chaos_matrix.py --seed 7
JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    timeout --kill-after=30 600 \
    python scripts/chaos_matrix.py --scenario shard-reexec --seed 7

echo "verify: all green"
