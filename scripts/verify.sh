#!/usr/bin/env bash
# Tier-1 verify: the exact command from ROADMAP.md, run from any cwd,
# plus the docs link check and a convert.py snapshot round-trip smoke.
#   scripts/verify.sh                 # full tier-1 + smoke
#   scripts/verify.sh -m 'not slow'   # quick loop (skips the 1M-edge test)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"

# multi-device lane: the sharded streaming tests under 4 forced CPU host
# devices.  (tests/conftest.py pops XLA_FLAGS at import — the device
# oracle tests run in subprocesses that set their own flag — so this
# lane's env only pins the host-side tests' view of the platform.)
JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -q tests/test_sharded_stream.py

# docs: every relative link in README.md / docs/*.md must resolve
python scripts/check_links.py README.md docs

# snapshot smoke: tiny text fixture -> scripts/convert.py -> load_csr
# must match the csr_np host oracle, raw and zlib-compressed (.gvel v2)
python - <<'PY'
import os, subprocess, sys, tempfile
import numpy as np
from repro.core import load_csr, make_graph_file, read_edgelist_numpy, read_snapshot
from repro.core.build import csr_np

tmp = tempfile.mkdtemp(prefix="gvel_smoke_")
el_path = os.path.join(tmp, "tiny.el")
v, e = make_graph_file(el_path, "uniform", scale=8, edge_factor=4, seed=3)
el = read_edgelist_numpy(el_path, num_vertices=v)
n = int(el.num_edges)
ref = csr_np(np.asarray(el.src[:n]), np.asarray(el.dst[:n]), None, v)

def check(gv):
    got = load_csr(gv, engine="snapshot")
    assert np.array_equal(np.asarray(got.offsets, np.int64), ref.offsets), gv
    off = ref.offsets
    for u in range(v):
        assert np.array_equal(np.sort(np.asarray(got.targets[off[u]:off[u+1]])),
                              np.sort(ref.targets[off[u]:off[u+1]])), (gv, u)

gv = os.path.join(tmp, "tiny.gvel")
subprocess.run([sys.executable, "scripts/convert.py", el_path, gv,
                "--num-vertices", str(v)], check=True)
check(gv)
gvz = os.path.join(tmp, "tiny.z.gvel")
subprocess.run([sys.executable, "scripts/convert.py", el_path, gvz,
                "--num-vertices", str(v), "--compress", "zlib"], check=True)
assert read_snapshot(gvz).version == 2
check(gvz)
print("snapshot smoke: convert.py round-trip OK (raw + zlib .gvel v2)")
PY

# benchmark smoke: the e2e loader benchmark (incl. compressed + lazy
# rows) must still execute end to end — benchmark code can't rot
# unexecuted.  --json emits machine-readable {name, seconds, mb,
# speedup} rows; BENCH_e2e.json committed from a full (non-quick) run
# is the cross-PR perf trajectory.
python -m benchmarks.e2e_load_csr --quick --json /tmp/BENCH_e2e_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_e2e_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
print(f"benchmark json: {len(rows)} rows OK")
PY

# perf gate: the streaming engine must never fall back below the batch
# round-trip (speedup >= 1.0 even on the --quick graph, where fixed
# costs compress ratios), and the sharded streaming load at d=4 must
# stay on the same baseline axis (its speedup row is normalized through
# the same-split streaming re-timing; a retrace-per-load regression
# shows up here at ~0.14x).  Floors only — quick-run speedups are not
# comparable to the committed full-run rows, so tolerance mode is for
# full-vs-full diffs across PRs (see scripts/bench_diff.py).
# ...and the binned CSR build must stay at least as fast as the staged
# build it fronts (its speedup field is staged/binned, not the baseline
# axis — see benchmarks/e2e_load_csr.py).
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_e2e_quick.json \
    --require-only --require 'e2e.load_csr_streaming>=1.0' \
    --require 'e2e.load_csr_sharded_d4>=1.0' \
    --require 'e2e.csr_build_binned>=1.0'

# query-service smoke + gate: thousands of mixed point/range/full
# requests through the hot-graph cache (tests/test_query.py and
# tests/test_cache.py run in the main pytest lane above).  The floor
# pins serving a request to never cost more than the naive
# open-full-load-slice answer (speedup >= 1.0) — if the selective
# path rots back to full-section reads, it shows up here.
python -m benchmarks.query_service --quick --json /tmp/BENCH_query_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_query_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
names = {r["name"] for r in rows}
assert "e2e.query_mixed" in names, names
print(f"query benchmark json: {len(rows)} rows OK")
PY
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_query_quick.json \
    --require-only --require 'e2e.query_mixed>=1.0'

# serving smoke + gate: sustained walk-LM traffic through the
# ServeRuntime (snapshot corpus -> hot-graph cache -> continuous
# batching; tests/test_runtime.py and tests/test_corpus.py run in the
# main lane above).  The floor pins a served request to never cost
# more than the naive reload-per-request + solo-decode answer — if the
# runtime's cache/batching path rots, it shows up here.
python -m benchmarks.serve_walks --quick --json /tmp/BENCH_serve_quick.json
python - <<'PY'
import json
rows = json.load(open("/tmp/BENCH_serve_quick.json"))
assert rows and all(set(r) == {"name", "seconds", "mb", "speedup"}
                    for r in rows), rows
names = {r["name"] for r in rows}
assert {"e2e.serve_walks_tokens", "e2e.serve_resume"} <= names, names
print(f"serve benchmark json: {len(rows)} rows OK")
PY
python scripts/bench_diff.py BENCH_e2e.json /tmp/BENCH_serve_quick.json \
    --require-only --require 'e2e.serve_walks_tokens>=1.0'

# chaos lane: preempt the walk-corpus consumer mid-stream with a real
# SIGTERM (ft.coordinator flag -> clean checkpoint exit at the batch
# boundary), restart it from the persisted cursor, and require the
# stitched batch stream to be bitwise identical to an uninterrupted
# in-process run (the churn contract of docs/serving.md).
python - <<'PY'
import hashlib, os, signal, subprocess, sys, tempfile
import numpy as np
from repro.core import make_graph_file
from repro.core.source import open_graph
from repro.data.corpus import CorpusConfig, WalkCorpus

tmp = tempfile.mkdtemp(prefix="gvel_chaos_")
el = os.path.join(tmp, "g.el")
v, e = make_graph_file(el, "rmat", scale=7, edge_factor=4, seed=5)
gv = os.path.join(tmp, "g.gvel")
open_graph(el, engine="numpy", num_vertices=v).save(gv)
cursor, log, total = os.path.join(tmp, "cursor"), os.path.join(tmp, "log"), 12

CHILD = r'''
import hashlib, sys
import numpy as np
from repro.core.source import open_graph
from repro.data.corpus import CorpusConfig, WalkCorpus, load_cursor, save_cursor
from repro.ft.coordinator import Coordinator, FTConfig
gv, cursor, log, total = sys.argv[1], sys.argv[2], sys.argv[3], int(sys.argv[4])
cc = CorpusConfig(batch=4, seq=16, vocab_size=97, seed=13)
start = load_cursor(cursor) or 0
with Coordinator(FTConfig(handle_signals=True)) as coord:
    with WalkCorpus(open_graph(gv), cc).batches(start) as stream:
        while stream.next_step < total:
            step, batch = next(stream)
            h = hashlib.sha256(np.asarray(batch["tokens"]).tobytes()).hexdigest()
            with open(log, "a") as f:
                f.write(f"{step} {h}\n")
            save_cursor(cursor, stream.next_step)
            print(step, flush=True)
            if coord.should_stop():
                sys.exit(3)                 # preempted: clean cursor exit
sys.exit(0)
'''

def spawn():
    return subprocess.Popen([sys.executable, "-c", CHILD, gv, cursor, log,
                             str(total)], stdout=subprocess.PIPE, text=True,
                            env=dict(os.environ))

p = spawn()
for line in p.stdout:                       # SIGTERM mid-stream
    if int(line) >= 2:
        p.send_signal(signal.SIGTERM)
        break
p.wait(timeout=120)
assert p.returncode == 3, f"expected preempted exit 3, got {p.returncode}"
from repro.data.corpus import load_cursor
resumed_at = load_cursor(cursor)
assert resumed_at and resumed_at < total, resumed_at
p = spawn()                                 # restart resumes at the cursor
p.communicate(timeout=300)
assert p.returncode == 0, p.returncode

steps, hashes = zip(*(l.split() for l in open(log)))
assert [int(s) for s in steps] == list(range(total)), steps
cc = CorpusConfig(batch=4, seq=16, vocab_size=97, seed=13)
corpus = WalkCorpus(open_graph(gv), cc)
for step, h in zip(steps, hashes):          # vs uninterrupted reference
    want = hashlib.sha256(np.asarray(
        corpus.batch_at(int(step))["tokens"]).tobytes()).hexdigest()
    assert h == want, (step, h, want)
print(f"chaos lane: SIGTERM at step {resumed_at - 1}, resume at "
      f"{resumed_at}, {total}-batch stream bitwise identical OK")
PY

echo "verify: all green"
