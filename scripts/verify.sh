#!/usr/bin/env bash
# Tier-1 verify: the exact command from ROADMAP.md, run from any cwd.
#   scripts/verify.sh            # full tier-1
#   scripts/verify.sh -m 'not slow'   # quick loop (skips the 1M-edge test)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
