#!/usr/bin/env python
"""Convert a text edgelist or MatrixMarket file to a ``.gvel`` snapshot.

GVEL's "write once, load many": pay the text parse once here, then every
load on the output is a zero-parse mmap (and, with the default embedded
CSR, ``open_graph(out).csr()`` skips the build entirely).

  PYTHONPATH=src python scripts/convert.py graph.el graph.gvel
  PYTHONPATH=src python scripts/convert.py --weighted --base 0 g.el g.gvel
  PYTHONPATH=src python scripts/convert.py matrix.mtx matrix.gvel

A thin shell over the :class:`repro.core.source.GraphSource` API:
``open_graph(input, ...).save(output, ...)``.  Formats are sniffed by
magic (MTX banner through gzip/framed compression too); MTX
field/symmetry attributes are honored — the snapshot stores the
resolved graph.  See docs/snapshot-format.md for the container spec and
docs/api.md for the API.  Refuses to overwrite an existing output
unless ``--force`` is given.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Convert a text edgelist / MTX file to a .gvel snapshot")
    ap.add_argument("input", help="text edgelist or MatrixMarket file")
    ap.add_argument("output", help="output .gvel path")
    ap.add_argument("--weighted", action="store_true",
                    help="parse a third weight column (text inputs; MTX "
                    "weighting comes from the banner)")
    ap.add_argument("--symmetric", action="store_true",
                    help="materialize reverse edges (text inputs; MTX "
                    "symmetry comes from the banner)")
    ap.add_argument("--base", type=int, default=1, choices=(0, 1),
                    help="vertex-id base of the text input (default 1)")
    ap.add_argument("--num-vertices", type=int, default=None,
                    help="|V| override for text inputs (default max id + 1, "
                    "which drops isolated trailing vertices); MTX inputs "
                    "take |V| from the size line")
    ap.add_argument("--engine", default="numpy",
                    help="parse engine for the conversion read (default "
                    "numpy; see repro.core.available_engines())")
    ap.add_argument("--no-csr", action="store_true",
                    help="store only the packed edgelist, not a prebuilt CSR")
    ap.add_argument("--method", default="staged", choices=("staged", "global"),
                    help="CSR build strategy for the embedded CSR")
    ap.add_argument("--rho", type=int, default=4,
                    help="partitions for the staged CSR build")
    ap.add_argument("--compress", default=None, metavar="CODEC[:LEVEL]",
                    help="store sections compressed (.gvel v2): zlib always, "
                    "zstd when the zstandard package is installed; e.g. "
                    "--compress zlib or --compress zstd:9")
    ap.add_argument("--force", action="store_true",
                    help="overwrite an existing output file")
    args = ap.parse_args(argv)

    if os.path.exists(args.output) and not args.force:
        print(f"error: refusing to overwrite existing {args.output} "
              f"(pass --force to replace it)", file=sys.stderr)
        return 2

    from repro.core import open_graph

    try:
        t0 = time.perf_counter()
        # format probe only (validate=False: the real open below, with
        # the engine pinned, does the header validation once)
        src = open_graph(args.input, validate=False)
        if src.format == "mtx":
            ignored = [name for name, off_default in
                       [("--weighted", not args.weighted),
                        ("--symmetric", not args.symmetric),
                        ("--base", args.base == 1),
                        ("--num-vertices", args.num_vertices is None)]
                       if not off_default]
            if ignored:
                print(f"warning: {', '.join(ignored)} ignored for MTX input "
                      f"— field/symmetry/base/|V| come from the MTX header",
                      file=sys.stderr)
            src = open_graph(args.input, engine=args.engine)
        else:
            src = open_graph(args.input, engine=args.engine,
                             weighted=args.weighted,
                             symmetric=args.symmetric, base=args.base,
                             num_vertices=args.num_vertices)
        out = src.save(args.output, compress=args.compress,
                       csr=not args.no_csr, method=args.method, rho=args.rho)
        # eager re-read of what we just wrote: decompress + CRC-check
        # every section now, not lazily at some consumer's first access
        from repro.core import read_snapshot
        read_snapshot(args.output)
        t_convert = time.perf_counter() - t0
    except (OSError, ValueError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1

    info = out.info()
    in_sz = os.path.getsize(args.input)
    comp = f" codec={info.codec}" if info.codec else ""
    print(f"{args.input} ({in_sz / 1e6:.2f} MB) -> {args.output} "
          f"({info.size_bytes / 1e6:.2f} MB, "
          f"{info.size_bytes / max(in_sz, 1):.2f}x input)"
          f"{comp} in {t_convert * 1e3:.0f} ms")
    print(f"  |V|={info.num_vertices:,} |E|={info.num_edges:,} "
          f"v{info.version} weighted={info.weighted} "
          f"edgelist={info.has_edgelist} csr={info.has_csr}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
