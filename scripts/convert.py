#!/usr/bin/env python
"""Convert a text edgelist or MatrixMarket file to a ``.gvel`` snapshot.

GVEL's "write once, load many": pay the text parse once here, then every
``load_edgelist``/``load_csr`` on the output is a zero-parse mmap (and,
with the default embedded CSR, ``load_csr`` skips the build entirely).

  PYTHONPATH=src python scripts/convert.py graph.el graph.gvel
  PYTHONPATH=src python scripts/convert.py --weighted --base 0 g.el g.gvel
  PYTHONPATH=src python scripts/convert.py matrix.mtx matrix.gvel

MTX inputs are detected by their banner; field/symmetry attributes are
honored (the snapshot stores the resolved graph).  See
docs/snapshot-format.md for the container spec.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _is_mtx(path: str) -> bool:
    # sniff through gzip/framed compression so matrix.mtx.gz converts too
    from repro.core.codecs import peek_bytes
    return peek_bytes(path, 14) == b"%%MatrixMarket"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Convert a text edgelist / MTX file to a .gvel snapshot")
    ap.add_argument("input", help="text edgelist or MatrixMarket file")
    ap.add_argument("output", help="output .gvel path")
    ap.add_argument("--weighted", action="store_true",
                    help="parse a third weight column (text inputs; MTX "
                    "weighting comes from the banner)")
    ap.add_argument("--symmetric", action="store_true",
                    help="materialize reverse edges (text inputs; MTX "
                    "symmetry comes from the banner)")
    ap.add_argument("--base", type=int, default=1, choices=(0, 1),
                    help="vertex-id base of the text input (default 1)")
    ap.add_argument("--num-vertices", type=int, default=None,
                    help="|V| override for text inputs (default max id + 1, "
                    "which drops isolated trailing vertices); MTX inputs "
                    "take |V| from the size line")
    ap.add_argument("--engine", default="numpy",
                    help="parse engine for the conversion read (default "
                    "numpy; see repro.core.available_engines())")
    ap.add_argument("--no-csr", action="store_true",
                    help="store only the packed edgelist, not a prebuilt CSR")
    ap.add_argument("--method", default="staged", choices=("staged", "global"),
                    help="CSR build strategy for the embedded CSR")
    ap.add_argument("--rho", type=int, default=4,
                    help="partitions for the staged CSR build")
    ap.add_argument("--compress", default=None, metavar="CODEC[:LEVEL]",
                    help="store sections compressed (.gvel v2): zlib always, "
                    "zstd when the zstandard package is installed; e.g. "
                    "--compress zlib or --compress zstd:9")
    args = ap.parse_args(argv)

    from repro.core import (convert_to_csr, load_edgelist, mtx_to_snapshot,
                            read_snapshot, save_snapshot)
    from repro.core.codecs import parse_codec_spec
    from repro.core.loader import csr_convert_engine

    codec_name = level = None
    if args.compress is not None:
        codec, level = parse_codec_spec(args.compress)
        codec_name = codec.name

    t0 = time.perf_counter()
    if _is_mtx(args.input):
        ignored = [name for name, off_default in
                   [("--weighted", not args.weighted),
                    ("--symmetric", not args.symmetric),
                    ("--base", args.base == 1),
                    ("--num-vertices", args.num_vertices is None)]
                   if not off_default]
        if ignored:
            print(f"warning: {', '.join(ignored)} ignored for MTX input — "
                  f"field/symmetry/base/|V| come from the MTX header",
                  file=sys.stderr)
        mtx_to_snapshot(args.input, args.output, engine=args.engine,
                        csr=not args.no_csr, method=args.method, rho=args.rho,
                        compress=codec_name, compress_level=level)
    else:
        el = load_edgelist(args.input, engine=args.engine,
                           weighted=args.weighted, symmetric=args.symmetric,
                           base=args.base, num_vertices=args.num_vertices)
        csr = None
        if not args.no_csr:
            csr = convert_to_csr(el, method=args.method, rho=args.rho,
                                 engine=csr_convert_engine(args.engine))
        save_snapshot(args.output, edgelist=el, csr=csr,
                      compress=codec_name, compress_level=level)
    t_convert = time.perf_counter() - t0

    snap = read_snapshot(args.output)
    in_sz = os.path.getsize(args.input)
    out_sz = os.path.getsize(args.output)
    comp = f" codec={codec_name}" if codec_name else ""
    print(f"{args.input} ({in_sz / 1e6:.2f} MB) -> {args.output} "
          f"({out_sz / 1e6:.2f} MB, {out_sz / max(in_sz, 1):.2f}x input)"
          f"{comp} in {t_convert * 1e3:.0f} ms")
    print(f"  |V|={snap.num_vertices:,} |E|={snap.num_edges:,} v{snap.version} "
          f"weighted={snap.weighted} edgelist={snap.has_edgelist} "
          f"csr={snap.has_csr}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
