#!/usr/bin/env python
"""Compare two ``BENCH_e2e.json`` files row-by-row; nonzero exit on
perf regression.

Rows are matched by ``name`` and compared on their ``speedup`` field
(gain over the batch-roundtrip baseline row, so the comparison is
self-normalized against host speed).  Two modes, combinable:

* tolerance mode (default): every row of BASELINE present in CURRENT
  must keep ``current.speedup >= baseline.speedup * (1 - tol)``.
  Meaningful when both files come from the *same* benchmark
  configuration (two full runs across PRs).  ``--rows`` restricts the
  checked rows by glob.
* floor mode (``--require NAME>=X``, repeatable): absolute speedup
  floors on CURRENT rows.  This is the cross-configuration gate —
  quick-run speedups are compressed by fixed costs, so verify.sh
  checks the committed full-run baseline against a fresh ``--quick``
  run with ``--require-only`` floors (e.g. the streaming engine must
  never fall back below the batch round-trip: ``>=1.0``).

``--require-only`` skips tolerance comparisons entirely.  A row named
in ``--require`` (or matched by ``--rows``) that is missing from
CURRENT is a regression; other baseline rows missing from CURRENT are
warnings (benchmarks grow rows in full mode that --quick omits).

    scripts/bench_diff.py BENCH_e2e.json new.json --tol 0.25
    scripts/bench_diff.py BENCH_e2e.json quick.json \
        --require-only --require 'e2e.load_csr_streaming>=1.0'
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys


def _load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not all(
            isinstance(r, dict) and "name" in r and "speedup" in r
            for r in rows):
        sys.exit(f"{path}: expected a list of rows with name/speedup "
                 f"fields (benchmarks/e2e_load_csr.py --json output)")
    return {r["name"]: r for r in rows}


def _parse_require(spec: str) -> tuple[str, float]:
    name, _, floor = spec.partition(">=")
    if not name or not floor:
        sys.exit(f"--require expects NAME>=FLOOR, got {spec!r}")
    try:
        return name.strip(), float(floor)
    except ValueError:
        sys.exit(f"--require floor must be a number, got {floor!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_diff.py",
        description="Diff two benchmark JSON files; exit 1 on regression")
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed relative speedup drop per row "
                    "(default 0.25 = 25%%)")
    ap.add_argument("--rows", default="*",
                    help="comma-separated name globs to tolerance-check "
                    "(default: all)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME>=X", help="absolute speedup floor on a "
                    "CURRENT row (repeatable)")
    ap.add_argument("--require-only", action="store_true",
                    help="skip tolerance comparisons; only check --require "
                    "floors (cross-configuration mode)")
    args = ap.parse_args(argv)

    base, cur = _load(args.baseline), _load(args.current)
    globs = [g.strip() for g in args.rows.split(",") if g.strip()]
    requires = dict(_parse_require(s) for s in args.require)
    failures, lines = [], []

    for name, floor in requires.items():
        row = cur.get(name)
        if row is None:
            failures.append(f"{name}: required row missing from "
                            f"{args.current}")
            continue
        ok = row["speedup"] >= floor
        lines.append(f"  {name}: speedup {row['speedup']:.2f} "
                     f"(floor {floor:.2f}) {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{name}: speedup {row['speedup']:.2f} below "
                            f"required floor {floor:.2f}")

    if not args.require_only:
        for name, brow in base.items():
            if not any(fnmatch.fnmatch(name, g) for g in globs):
                continue
            crow = cur.get(name)
            if crow is None:
                if name in requires:
                    continue              # already reported above
                lines.append(f"  {name}: missing from current (warning)")
                continue
            limit = brow["speedup"] * (1.0 - args.tol)
            ok = crow["speedup"] >= limit
            lines.append(
                f"  {name}: {brow['speedup']:.2f} -> {crow['speedup']:.2f} "
                f"(min {limit:.2f}) {'ok' if ok else 'REGRESSION'}")
            if not ok:
                failures.append(
                    f"{name}: speedup fell {brow['speedup']:.2f} -> "
                    f"{crow['speedup']:.2f} (tolerance {args.tol:.0%})")

    print(f"bench_diff: {args.baseline} vs {args.current}")
    for ln in lines:
        print(ln)
    if failures:
        print("bench_diff: PERF REGRESSION", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench_diff: no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
