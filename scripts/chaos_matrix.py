#!/usr/bin/env python
"""Seeded chaos matrix: drive the fault-injection harness
(repro.core.faults) through the recovery paths verify.sh must prove
(docs/robustness.md) and fail loudly when any self-healing contract
regresses.

Scenarios (each seeded, each printing one OK line):

  transient-retry   streaming + cached loads under injected transient
                    OSErrors/latency retry to a bitwise-equal result
  stuck-reader      a stalled block source raises StageTimeout within
                    the (lowered) watchdog budget — never a hang
  quarantine-swap   a CRC-corrupt CSR frame on disk quarantines
                    (path, section) with structured CorruptGraphError
                    while sibling sections + other graphs serve, and a
                    swap on disk recovers
  sigterm-resume    SIGTERM mid-corpus-stream -> cursor checkpoint ->
                    restart stitches a bitwise-identical batch stream
  shard-reexec      a shard whose in-span retries exhaust re-executes
                    its byte span bitwise-equal to the fault-free load
                    (needs >= 2 devices: run under JAX_PLATFORMS=cpu
                    XLA_FLAGS=--xla_force_host_platform_device_count=4)

Usage:
  python scripts/chaos_matrix.py                  # all local scenarios
  python scripts/chaos_matrix.py --scenario stuck-reader
  python scripts/chaos_matrix.py --scenario shard-reexec   # device lane
"""
import argparse
import hashlib
import os
import signal
import struct
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import faults, load_edgelist, make_graph_file, open_graph, \
    save_snapshot  # noqa: E402
from repro.core import snapshot as snapmod  # noqa: E402
from repro.core.cache import SourceCache  # noqa: E402
from repro.core.csr import convert_to_csr  # noqa: E402
from repro.core.faults import (CorruptGraphError, FaultPlan, FaultSpec,
                               StageTimeout, fault_plan)  # noqa: E402

LOCAL_SCENARIOS = ("transient-retry", "stuck-reader", "quarantine-swap",
                   "sigterm-resume")
ALL_SCENARIOS = LOCAL_SCENARIOS + ("shard-reexec",)


def _graph(tmp, name, seed, *, scale=8, kind="rmat"):
    el = os.path.join(tmp, name + ".el")
    v, e = make_graph_file(el, kind, scale=scale, edge_factor=4, seed=seed)
    return el, v


def _zlib_snapshot(tmp, name, seed):
    """Small-frame zlib .gvel: one corrupt frame is a section-local
    event, so quarantine scope is observable."""
    el, v = _graph(tmp, name, seed, scale=7)
    elist = load_edgelist(el, engine="numpy", num_vertices=v, base=1)
    gv = os.path.join(tmp, name + ".gvel")
    save_snapshot(gv, edgelist=elist,
                  csr=convert_to_csr(elist, engine="numpy"),
                  compress="zlib", frame_beta=128)
    return gv, v


def _corrupt_section(path, section_name):
    """Flip one byte inside the named section's compressed payload."""
    with open(path, "rb") as f:
        hdr = f.read(snapmod.HEADER_LEN)
    _, version, _, _, _, nsec, _ = struct.unpack(snapmod.HEADER_FMT, hdr)
    assert version == snapmod.VERSION_COMPRESSED, version
    want = {v: k for k, v in snapmod.SECTION_NAMES.items()}[section_name]
    with open(path, "rb") as f:
        f.seek(snapmod.HEADER_LEN)
        table = f.read(nsec * snapmod.SECTION_LEN_V2)
    for i in range(nsec):
        sid, _, off, nbytes, _, _, _ = struct.unpack_from(
            snapmod.SECTION_FMT_V2, table, i * snapmod.SECTION_LEN_V2)
        if sid == want:
            pos = off + 12 + min(13, max(0, nbytes - 13))
            with open(path, "r+b") as f:
                f.seek(pos)
                b = f.read(1)
                f.seek(pos)
                f.write(bytes([b[0] ^ 0x40]))
            return
    raise AssertionError(f"{section_name} not found in {path}")


def _bitwise(a, b, what):
    assert np.array_equal(np.asarray(a.offsets), np.asarray(b.offsets)), \
        f"{what}: offsets differ"
    assert np.array_equal(np.asarray(a.targets), np.asarray(b.targets)), \
        f"{what}: targets differ"


# ---------------------------------------------------------------------------


def scenario_transient_retry(tmp, seed):
    """Injected transient faults at every hook site; the loads recover
    and the results are bitwise equal to the fault-free runs."""
    faults.reset_counters()
    el, v = _graph(tmp, "tr", seed)
    clean = open_graph(el, engine="device", num_vertices=v).csr()
    plan = FaultPlan([FaultSpec("block", "oserror", index=0, times=2),
                      FaultSpec("block", "latency", index=1, delay_s=0.005),
                      FaultSpec("mmap", "latency", times=1, delay_s=0.005)],
                     seed=seed)
    faulty = open_graph(el, engine="device", num_vertices=v,
                        faults=plan).csr()
    _bitwise(clean, faulty, "transient-retry streaming")
    assert plan.injected().get("block:oserror") == 2, plan.injected()
    c = faults.counters()
    assert c["io_retries"] >= 2, c

    # cache cold-open retry: same file serves through SourceCache while
    # its open is failing transiently
    gv, _ = _zlib_snapshot(tmp, "tr_snap", seed)
    cache = SourceCache(capacity=2)
    with fault_plan(FaultPlan([FaultSpec("open", "oserror", times=2)],
                              seed=seed)):
        got = cache.query(gv, "csr")
    st = cache.stats()["faults"]
    assert st["open_retries"] == 2, st
    assert got.num_vertices > 0
    print(f"chaos[transient-retry]: {c['io_retries']} IO retries + "
          f"{st['open_retries']} open retries, results bitwise equal OK")


def scenario_stuck_reader(tmp, seed):
    """A stalled block source trips the watchdog within its budget and
    surfaces as StageTimeout naming the byte span — never a hang."""
    faults.reset_counters()
    el, v = _graph(tmp, "stuck", seed)
    budget, saved = 0.4, faults.WATCHDOG_S
    faults.WATCHDOG_S = budget
    plan = FaultPlan([FaultSpec("block", "stall", index=0, delay_s=3.0)],
                     seed=seed)
    t0 = time.perf_counter()
    try:
        open_graph(el, engine="device", num_vertices=v, faults=plan).csr()
        raise AssertionError("stuck reader did not raise StageTimeout")
    except StageTimeout as exc:
        dt = time.perf_counter() - t0
        assert "byte span [" in str(exc), str(exc)
        assert dt < budget + 1.0, f"watchdog fired late: {dt:.2f}s"
    finally:
        faults.WATCHDOG_S = saved
    assert faults.counters()["stage_timeouts"] == 1, faults.counters()
    print(f"chaos[stuck-reader]: StageTimeout in {dt:.2f}s "
          f"(budget {budget}s) OK")


def scenario_quarantine_swap(tmp, seed):
    """Corrupt CSR frame -> structured quarantine; siblings serve;
    swap-on-disk recovers."""
    live, v = _zlib_snapshot(tmp, "live", seed)
    other, _ = _zlib_snapshot(tmp, "other", seed + 1)
    backup = live + ".bak"
    with open(live, "rb") as f, open(backup, "wb") as g:
        g.write(f.read())
    cache = SourceCache(capacity=4)
    deg = cache.query(live, "degree", vertex=1)
    cache.invalidate()

    _corrupt_section(live, "csr_indices")
    try:
        cache.query(live, "csr")
        raise AssertionError("corrupt section served")
    except CorruptGraphError as exc:
        assert exc.section == "csr_indices", exc.section
    try:
        cache.query(live, "neighbors", vertex=1)
        raise AssertionError("quarantined section served")
    except CorruptGraphError as exc:
        assert "quarantined" in str(exc), str(exc)
    # header-only + offsets-only ops and the other graph keep serving
    assert cache.query(live, "info").num_vertices == v
    assert cache.query(live, "degree", vertex=1) == deg
    assert cache.query(other, "csr").num_vertices > 0
    st = cache.stats()["faults"]
    assert st["quarantines"] == 1 and st["quarantined"], st

    os.replace(backup, live)                 # swap good bytes back
    os.utime(live)
    got = cache.query(live, "csr")
    assert got.num_vertices == v
    st = cache.stats()["faults"]
    assert st["recovered"] >= 1 and not st["quarantined"], st
    print(f"chaos[quarantine-swap]: csr_indices quarantined "
          f"({st['corrupt_errors']} structured errors), siblings served, "
          f"swap recovered OK")


_SIGTERM_CHILD = r'''
import hashlib, sys
import numpy as np
from repro.core.source import open_graph
from repro.data.corpus import CorpusConfig, WalkCorpus, load_cursor, save_cursor
from repro.ft.coordinator import Coordinator, FTConfig
gv, cursor, log, total, seed = (sys.argv[1], sys.argv[2], sys.argv[3],
                                int(sys.argv[4]), int(sys.argv[5]))
cc = CorpusConfig(batch=4, seq=16, vocab_size=97, seed=seed)
start = load_cursor(cursor) or 0
with Coordinator(FTConfig(handle_signals=True)) as coord:
    with WalkCorpus(open_graph(gv), cc).batches(start) as stream:
        while stream.next_step < total:
            step, batch = next(stream)
            h = hashlib.sha256(np.asarray(batch["tokens"]).tobytes()).hexdigest()
            with open(log, "a") as f:
                f.write(f"{step} {h}\n")
            save_cursor(cursor, stream.next_step)
            print(step, flush=True)
            if coord.should_stop():
                sys.exit(3)                 # preempted: clean cursor exit
sys.exit(0)
'''


def scenario_sigterm_resume(tmp, seed):
    """SIGTERM mid-stream -> durable cursor -> bitwise-stitched resume
    (the churn contract of docs/serving.md)."""
    from repro.data.corpus import CorpusConfig, WalkCorpus, load_cursor
    el, v = _graph(tmp, "sig", seed, scale=7)
    gv = os.path.join(tmp, "sig.gvel")
    open_graph(el, engine="numpy", num_vertices=v).save(gv)
    cursor = os.path.join(tmp, "cursor")
    log = os.path.join(tmp, "log")
    total = 12

    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + (":" + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")

    def spawn():
        return subprocess.Popen(
            [sys.executable, "-c", _SIGTERM_CHILD, gv, cursor, log,
             str(total), str(seed)],
            stdout=subprocess.PIPE, text=True, env=env)

    p = spawn()
    for line in p.stdout:                   # SIGTERM mid-stream
        if int(line) >= 2:
            p.send_signal(signal.SIGTERM)
            break
    p.wait(timeout=120)
    assert p.returncode == 3, f"expected preempted exit 3, got {p.returncode}"
    resumed_at = load_cursor(cursor)
    assert resumed_at and resumed_at < total, resumed_at
    p = spawn()                             # restart resumes at the cursor
    p.communicate(timeout=300)
    assert p.returncode == 0, p.returncode

    steps, hashes = zip(*(ln.split() for ln in open(log)))
    assert [int(s) for s in steps] == list(range(total)), steps
    corpus = WalkCorpus(open_graph(gv),
                        CorpusConfig(batch=4, seq=16, vocab_size=97,
                                     seed=seed))
    for step, h in zip(steps, hashes):      # vs uninterrupted reference
        want = hashlib.sha256(np.asarray(
            corpus.batch_at(int(step))["tokens"]).tobytes()).hexdigest()
        assert h == want, (step, h, want)
    print(f"chaos[sigterm-resume]: SIGTERM at step {resumed_at - 1}, "
          f"resume at {resumed_at}, {total}-batch stream bitwise "
          f"identical OK")


def scenario_shard_reexec(tmp, seed):
    """Exhausted in-span retries escalate to whole-shard re-execution;
    the recovered mesh load is bitwise equal to the fault-free one."""
    import jax
    from repro.core.compat import make_mesh
    d = len(jax.devices())
    assert d >= 2, (f"shard-reexec needs >= 2 devices, got {d}; run under "
                    f"JAX_PLATFORMS=cpu "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=4")
    faults.reset_counters()
    mesh = make_mesh((d,), ("data",))
    el, v = _graph(tmp, "shard", seed)
    clean = open_graph(el, engine="device", num_vertices=v,
                       beta=2048).csr_sharded(mesh)
    # 3 consecutive failures on block 0 exhaust the in-span budget
    # (REPRO_IO_RETRIES=3) and force one shard re-execution
    plan = FaultPlan([FaultSpec("block", "oserror", index=0, times=3)],
                     seed=seed)
    faulty = open_graph(el, engine="device", num_vertices=v, beta=2048,
                        faults=plan).csr_sharded(mesh)
    _bitwise(clean, faulty, "shard-reexec")
    c = faults.counters()
    assert c["shard_retries"] == 1, c
    assert plan.injected() == {"block:oserror": 3}, plan.injected()

    # a shard that never recovers fails with the per-attempt fault log
    with fault_plan(FaultPlan([FaultSpec("block", "oserror", index=0,
                                         times=-1)], seed=seed)):
        try:
            open_graph(el, engine="device", num_vertices=v,
                       beta=2048).csr_sharded(mesh)
            raise AssertionError("permanently-failing shard loaded")
        except faults.ShardLoadError as exc:
            assert exc.shard == 0 and exc.fault_log, exc
    print(f"chaos[shard-reexec]: d={d}, {c['shard_retries']} shard "
          f"re-execution bitwise equal, ShardLoadError carries "
          f"{faults.SHARD_RETRIES + 1}-line fault log OK")


SCENARIOS = {
    "transient-retry": scenario_transient_retry,
    "stuck-reader": scenario_stuck_reader,
    "quarantine-swap": scenario_quarantine_swap,
    "sigterm-resume": scenario_sigterm_resume,
    "shard-reexec": scenario_shard_reexec,
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", choices=ALL_SCENARIOS, action="append",
                    help="run only these (default: all local scenarios)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    names = args.scenario or list(LOCAL_SCENARIOS)
    tmp = tempfile.mkdtemp(prefix="gvel_chaos_")
    for name in names:
        SCENARIOS[name](tmp, args.seed)
    print(f"chaos matrix: {len(names)} scenario(s) green "
          f"(seed={args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
